"""Config system: typed, frozen dataclasses describing every architecture.

Every assigned architecture is a `ModelConfig` instance in its own module
(``src/repro/configs/<arch_id>.py``) citing its source. Full-size configs
are exercised only via the AOT dry-run; ``ModelConfig.reduced()`` yields
the CPU-smoke variant (<=2 pattern repeats, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN block."""

    num_experts: int
    top_k: int
    d_expert: int                 # hidden width of each expert
    num_shared: int = 0           # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3)."""

    kv_lora_rank: int
    q_lora_rank: Optional[int]    # None => full-rank q projection
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class MambaConfig:
    """Selective SSM (S6) mixer, Jamba-style."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256            # rank of the Δ projection

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" time-mix with data-dependent decay."""

    head_dim: int = 64
    decay_lora: int = 64          # rank of the data-dependent decay LoRA
    gate_lora: int = 64


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub that
    provides precomputed frame embeddings per the assignment carve-out."""

    n_layers: int
    n_frames: int = 1500          # whisper-large-v3 mel frames after conv


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend stub: input_specs() yields precomputed embeddings
    of shape (batch, num_tokens, d_model) instead of raw pixels/audio."""

    kind: str                     # "audio" | "vision"
    num_tokens: int               # patch/frame tokens prepended or encoded


# ---------------------------------------------------------------------------
# the model config
# ---------------------------------------------------------------------------

MIXERS = ("attn", "mamba", "rwkv")
FFNS = ("mlp", "moe")


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm | audio
    source: str                   # citation for the config values
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0              # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0
    # (mixer, ffn) per position of the repeating block pattern;
    # n_layers - len(prefix_pattern) must be a multiple of len(block_pattern).
    block_pattern: Tuple[Tuple[str, str], ...] = (("attn", "mlp"),)
    # unrolled unique layers before the scanned stack (deepseek: dense L0)
    prefix_pattern: Tuple[Tuple[str, str], ...] = ()
    attention: str = "full"       # full | swa | mla | none
    window: int = 0               # sliding-window size when attention == "swa"
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendStub] = None
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"             # silu (gated) | gelu (whisper)
    # long-context capability: True iff decode cache is sub-quadratic
    # (SSM state, SWA ring buffer, or hybrid).
    subquadratic: bool = False
    optimizer: str = "adamw"      # adamw | adafactor | sgdm (dry-run default)
    remat_policy: str = "minimal" # none | minimal | full
    # ---- beyond-paper optimization levers (EXPERIMENTS.md §Perf) ----
    # group-local MoE dispatch: routing cumsum/scatter stays within each
    # sequence row, eliminating cross-device prefix collectives
    moe_group_dispatch: bool = False
    # pad attention heads so they divide the TP axis (zero-output-init);
    # 0 = off. Trades +pad/n_heads attention FLOPs for n_model-way TP.
    pad_heads_to: int = 0
    # expert parallelism: True shards experts over the model axis; False
    # replicates expert compute data-parallel (FSDP-sharded weights) —
    # wins when experts are small (granite: d_expert=512)
    moe_expert_parallel: bool = True
    # decode: partial-softmax combine over the model-sharded KV cache
    # (shard_map) instead of letting XLA all-gather the cache per step
    decode_partial_softmax: bool = False

    @property
    def eff_heads(self) -> int:
        return self.pad_heads_to or self.n_heads

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        scanned = self.n_layers - len(self.prefix_pattern)
        if scanned <= 0 or scanned % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.arch_id}: n_layers={self.n_layers} minus prefix "
                f"{len(self.prefix_pattern)} not a multiple of pattern "
                f"length {len(self.block_pattern)}")
        for mixer, ffn in self.prefix_pattern + self.block_pattern:
            if mixer not in MIXERS or ffn not in FFNS:
                raise ValueError(f"bad block pattern entry ({mixer},{ffn})")
        needs_moe = any(f == "moe" for _, f in
                        self.prefix_pattern + self.block_pattern)
        if needs_moe and self.moe is None:
            raise ValueError(f"{self.arch_id}: moe pattern without MoEConfig")

    @property
    def n_repeats(self) -> int:
        return (self.n_layers - len(self.prefix_pattern)) \
            // len(self.block_pattern)

    @property
    def uses_attention(self) -> bool:
        return any(m == "attn" for m, _ in self.block_pattern)

    def param_count(self) -> int:
        """Analytic total parameter count (for roofline MODEL_FLOPS)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)

    # -- reduced smoke variant ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU-runnable variant of the same family: one pattern repeat
        (2 layers for simple patterns), d_model<=256, <=4 experts."""
        pat = self.block_pattern
        n_layers = len(self.prefix_pattern) + (len(pat) if len(pat) > 1 else 2)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2)) if self.n_kv_heads else 0
        head_dim = min(self.head_dim, 64) if self.head_dim else 0
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                num_shared=min(self.moe.num_shared, 1))
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=64,
                            q_lora_rank=64 if self.mla.q_lora_rank else None,
                            rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
        mamba = None
        if self.mamba is not None:
            mamba = dataclasses.replace(self.mamba, d_state=8, dt_rank=16)
        rwkv = None
        if self.rwkv is not None:
            rwkv = RWKVConfig(head_dim=32, decay_lora=16, gate_lora=16)
        enc = None
        if self.encoder is not None:
            enc = EncoderConfig(n_layers=2, n_frames=16)
        fe = None
        if self.frontend is not None:
            fe = dataclasses.replace(self.frontend, num_tokens=8)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, head_dim=head_dim, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512), moe=moe, mla=mla, mamba=mamba,
            rwkv=rwkv, encoder=enc, frontend=fe, remat_policy="none")


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab * d                       # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab * d                  # lm head

    per_pattern = 0
    for mixer, ffn in cfg.prefix_pattern + cfg.block_pattern * cfg.n_repeats:
        if mixer == "attn":
            if cfg.attention == "mla" and cfg.mla is not None:
                m = cfg.mla
                qdim = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
                if m.q_lora_rank:
                    per_pattern += d * m.q_lora_rank + m.q_lora_rank * qdim
                else:
                    per_pattern += d * qdim
                per_pattern += d * (m.kv_lora_rank + m.rope_head_dim)
                per_pattern += m.kv_lora_rank * cfg.n_heads * (
                    m.nope_head_dim + m.v_head_dim)
                per_pattern += cfg.n_heads * m.v_head_dim * d
            else:
                hd = cfg.head_dim
                per_pattern += d * cfg.n_heads * hd          # q
                per_pattern += 2 * d * cfg.n_kv_heads * hd   # k, v
                per_pattern += cfg.n_heads * hd * d          # o
        elif mixer == "mamba" and cfg.mamba is not None:
            mb = cfg.mamba
            di = mb.d_inner(d)
            per_pattern += d * 2 * di                        # in_proj
            per_pattern += di * mb.d_conv                    # conv
            per_pattern += di * (mb.dt_rank + 2 * mb.d_state)  # x_proj
            per_pattern += mb.dt_rank * di                   # dt_proj
            per_pattern += di * mb.d_state                   # A
            per_pattern += di * d                            # out
        elif mixer == "rwkv" and cfg.rwkv is not None:
            per_pattern += 4 * d * d                         # r,k,v,o
            per_pattern += 2 * d * cfg.rwkv.decay_lora       # decay lora
            per_pattern += 2 * d * cfg.rwkv.gate_lora        # gate lora
        if ffn == "moe" and cfg.moe is not None:
            n_e = (cfg.moe.num_shared + cfg.moe.top_k) if active_only \
                else (cfg.moe.num_shared + cfg.moe.num_experts)
            per_pattern += n_e * 3 * d * cfg.moe.d_expert    # gated mlp
            per_pattern += d * cfg.moe.num_experts           # router
        else:
            per_pattern += 3 * d * cfg.d_ff                  # gated mlp
    total += per_pattern  # loop above already covers all n_layers
    if cfg.encoder is not None:
        # encoder layers: MHA + (non-gated) mlp, whisper style
        enc_layer = 4 * d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.d_ff
        # decoder additionally has cross-attention per layer
        total += cfg.encoder.n_layers * enc_layer
        total += cfg.n_layers * 4 * d * cfg.n_heads * cfg.head_dim
    return total


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch, shape) a valid dry-run combination? Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (see DESIGN.md)"
    return True, ""
