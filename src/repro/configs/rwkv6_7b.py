"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892]

Time-mix state is O(heads * head_dim^2) per layer regardless of sequence
length => runs long_500k. Channel-mix is modeled as the gated MLP with the
assigned d_ff.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch)",
    n_layers=32,
    d_model=4096,
    n_heads=64,                      # d_model / head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    block_pattern=(("rwkv", "mlp"),),
    attention="none",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=64),
    rope=False,
    subquadratic=True,
    optimizer="adamw",
)
