"""vfl-recsys — the paper's own demo workload (Stalactite §4).

A two-party vertical split over an SBOL-like dataset (190 439 users,
19 banking products, 1 345 extra user features) joined with a
MegaMarket-like feature silo. The master holds labels + its feature
slice; the member holds the second silo's features. Models: VFL
logistic regression (arbitered + arbiterless) and a split-NN
recommender. Data is generated synthetically with the published
statistics (Table 1) since the real datasets are not redistributable.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class VFLRecsysConfig:
    arch_id: str = "vfl-recsys"
    source: str = "Stalactite (RecSys'24), Table 1 + §4"
    # SBOL statistics (Table 1)
    n_users: int = 190_439
    n_items: int = 19
    n_interactions: int = 1_056_889
    n_other_features: int = 1_345
    # vertical split: master silo (SBOL) + member silos (MegaMarket-like)
    n_parties: int = 2
    # fraction of master users present in each member silo (ID overlap)
    id_overlap: float = 0.6
    member_features: Tuple[int, ...] = (381,)   # MegaMarket-like silo width
    # split-NN dims — DEPRECATED: these layer-width tuples predate the
    # TowerSpec model factory (repro.models.tower, DESIGN.md §12). They
    # keep working through bottom_tower()/top_tower() below, which map
    # them onto an equivalent one-block MLP tower (warns once).
    bottom_dims: Tuple[int, ...] = (256, 128)
    top_dims: Tuple[int, ...] = (128, 64)
    embedding_dim: int = 128

    def bottom_tower(self, in_dim: int):
        """Deprecated ``bottom_dims`` as an equivalent MLP
        :class:`~repro.models.tower.TowerSpec` mapping ``in_dim``
        features to ``embedding_dim`` (bit-identical params/math to
        the legacy ``mlp_init``/``mlp_apply`` path)."""
        from repro.models.tower import legacy_dims_tower
        return legacy_dims_tower(
            (int(in_dim),) + tuple(self.bottom_dims[:-1])
            + (self.embedding_dim,), final_act=True)

    def top_tower(self):
        """Deprecated ``top_dims`` as an equivalent MLP
        :class:`~repro.models.tower.TowerSpec` mapping the summed
        ``embedding_dim`` to ``n_items`` logits (no final activation,
        as the legacy top model)."""
        from repro.models.tower import legacy_dims_tower
        return legacy_dims_tower(
            (self.embedding_dim,) + tuple(self.top_dims)
            + (self.n_items,), final_act=False)

    def reduced(self) -> "VFLRecsysConfig":
        """CI-sized variant for smoke tests."""
        return VFLRecsysConfig(
            n_users=512, n_items=19, n_interactions=4_096,
            n_other_features=64, member_features=(32,),
            bottom_dims=(32, 16), top_dims=(16, 8), embedding_dim=16)


CONFIG = VFLRecsysConfig()
