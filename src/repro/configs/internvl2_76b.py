"""internvl2-76b [vlm] — InternViT + InternLM2 backbone. [arXiv:2404.16821]

Backbone only per the assignment: the InternViT vision encoder + MLP
projector are a stub — input_specs() provides precomputed patch embeddings
(256 tokens, d_model) prepended to the text sequence. The language model is
the Llama-architecture InternLM2 / Hermes-2-Theta-Llama-3 70B-class stack.
"""
from repro.configs.base import FrontendStub, ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); LLM backbone per assignment",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    block_pattern=(("attn", "mlp"),),
    attention="full",
    rope=True,
    rope_theta=500_000.0,
    frontend=FrontendStub(kind="vision", num_tokens=256),
    subquadratic=False,
    optimizer="adafactor",            # 76B: Adam states would not fit 16GB/chip
)
