"""granite-moe-3b-a800m [moe] — 40 experts top-8.
[hf:ibm-granite/granite-3.0-3b-a800m-base]

Assignment line says both "MoE 40e top-8" and "32 experts top-8"; the
granite-3.0-3b-a800m card has 40 experts, top-8 — we use 40 (DESIGN.md §4).
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                        # per-expert hidden width
    vocab=49155,
    block_pattern=(("attn", "moe"),),
    attention="full",
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    rope=True,
    rope_theta=10_000.0,
    subquadratic=False,
    optimizer="adamw",
)
