"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.
[arXiv:2405.04434]

Assignment line is self-contradictory ("MoE 64e top-6" vs "160 routed");
the DeepSeek-V2-Lite model card has 64 routed + 2 shared experts, top-6,
moe intermediate 1408, dense first layer (d_ff 10944). We follow the card
and note the discrepancy in DESIGN.md §4. The d_ff=1408 in the assignment
is the per-expert hidden width.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434 (DeepSeek-V2); hf:deepseek-ai/DeepSeek-V2-Lite",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,                   # MLA: per-head latent, kv grouping n/a
    head_dim=128,                    # nominal (nope+rope = 192 qk, 128 v)
    d_ff=10944,                      # dense layers' ffn width
    vocab=102400,
    # first layer dense (unrolled prefix), remaining 26 scanned MoE layers
    prefix_pattern=(("attn", "mlp"),),
    block_pattern=(("attn", "moe"),),
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    rope=True,
    rope_theta=10_000.0,
    subquadratic=False,
    optimizer="adamw",
)
