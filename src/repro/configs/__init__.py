"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture (plus the paper's own vfl-recsys workload)
is registered here and selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    EncoderConfig, FrontendStub, InputShape, MLAConfig, MambaConfig,
    ModelConfig, MoEConfig, RWKVConfig, SHAPES, shape_applicable,
)

_ARCH_MODULES: Dict[str, str] = {
    "glm4-9b":               "repro.configs.glm4_9b",
    "whisper-large-v3":      "repro.configs.whisper_large_v3",
    "internvl2-76b":         "repro.configs.internvl2_76b",
    "deepseek-v2-lite-16b":  "repro.configs.deepseek_v2_lite_16b",
    "jamba-1.5-large-398b":  "repro.configs.jamba_1_5_large_398b",
    "minicpm3-4b":           "repro.configs.minicpm3_4b",
    "granite-moe-3b-a800m":  "repro.configs.granite_moe_3b_a800m",
    "h2o-danube-1.8b":       "repro.configs.h2o_danube_1_8b",
    "qwen3-14b":             "repro.configs.qwen3_14b",
    "rwkv6-7b":              "repro.configs.rwkv6_7b",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(list_archs())}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_vfl_recsys_config():
    from repro.configs.vfl_recsys import CONFIG
    return CONFIG
