"""glm4-9b [dense] — RoPE, GQA kv=2. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=151552,
    block_pattern=(("attn", "mlp"),),
    attention="full",
    rope=True,
    rope_theta=10_000.0,
    subquadratic=False,
    optimizer="adamw",
)
