"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356]

The assignment specifies the TRANSFORMER BACKBONE only; the mel-spectrogram
+ conv feature extractor is a stub — input_specs() provides precomputed
frame embeddings (1500, d_model) for the encoder. Decoder is the 32-layer
text decoder with cross-attention. Whisper uses MHA (kv == heads) and
non-gated GELU MLPs, absolute positions (no RoPE).
"""
from repro.configs.base import EncoderConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356 (Whisper); hf:openai/whisper-large-v3",
    n_layers=32,                      # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,                    # MHA
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    block_pattern=(("attn", "mlp"),),
    attention="full",
    rope=False,                       # learned absolute positions
    act="gelu",
    encoder=EncoderConfig(n_layers=32, n_frames=1500),
    frontend=FrontendStub(kind="audio", num_tokens=1500),
    subquadratic=False,               # decoder ctx bounded; long_500k skipped
    optimizer="adamw",
)
