"""minicpm3-4b [dense] — MLA attention. [hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,                     # nominal (nope 64 + rope 32)
    d_ff=6400,
    vocab=73448,
    block_pattern=(("attn", "mlp"),),
    attention="mla",
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  rope_head_dim=32, nope_head_dim=64, v_head_dim=64),
    rope=True,
    rope_theta=10_000.0,
    subquadratic=False,
    optimizer="adamw",
)
