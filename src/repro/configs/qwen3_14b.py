"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (family card, 14B variant per assignment)",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    block_pattern=(("attn", "mlp"),),
    attention="full",
    qk_norm=True,
    rope=True,
    rope_theta=1_000_000.0,
    subquadratic=False,
    optimizer="adamw",
)
