"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window
attention. [arXiv:2401.16818]

SWA window 4096 => decode cache is bounded (ring buffer), so this dense
arch DOES run long_500k per the assignment's sliding-window carve-out.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818 (H2O-Danube)",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    block_pattern=(("attn", "mlp"),),
    attention="swa",
    window=4096,
    rope=True,
    rope_theta=10_000.0,
    subquadratic=True,               # SWA ring cache: runs long_500k
    optimizer="adamw",
)
