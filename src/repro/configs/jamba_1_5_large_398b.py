"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer. [arXiv:2403.19887]

Period-8 block: attention at position 3 of each 8-layer group (1 attn per
7 mamba), MoE on every second layer. Decode is sub-quadratic: Mamba layers
carry O(1) state; the 9 attention layers carry a model-axis-sharded KV.
"""
from repro.configs.base import MambaConfig, MoEConfig, ModelConfig

_PATTERN = tuple(
    ("attn" if i == 3 else "mamba", "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba); hf:ai21labs/AI21-Jamba-1.5-Large",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    block_pattern=_PATTERN,
    attention="full",
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=512),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    rope=False,                      # Jamba has no positional embeddings
    subquadratic=True,               # hybrid: runs long_500k
    optimizer="adafactor",           # 398B: must fit 16GB/chip
)
