"""gRPC-style framed transport: HTTP/2-like wire format, stdlib only.

The paper ships gRPC + Protobuf + Safetensors. This transport
reproduces the gRPC *wire shape* — an HTTP/2 connection preface, a
SETTINGS frame, HPACK-encoded HEADERS opening one stream per message,
and the payload chunked into DATA frames behind the 5-byte gRPC
message prefix — over plain TCP with no third-party dependency, while
speaking the exact same safetensors channel payloads as the socket
transport (``comm/sock.py``): the two are interchangeable under every
protocol, and the seed-trace bit-identity suite runs on both. When the
real ``grpcio`` package is available it can be slotted behind the same
interface, but nothing here imports it.

Scope (documented in docs/transports.md, internals in DESIGN.md §8):

* Each direction of each agent pair is its own client connection
  (mirroring the socket transport's lazy outbound links); the server
  side is write-silent — no SETTINGS ack, WINDOW_UPDATE or trailers.
  Flow control is TCP's.
* HEADERS use HPACK *literal without indexing* representations only
  (no dynamic table, no Huffman) — valid HPACK, trivially decodable.
* Stream 1 is the connection hello (``:path /repro.Party/Hello`` +
  ``grpc-agent``), so a peer dying inside its very first data stream
  is still attributable and fails waiters fast.
* ``CommCfg.tls`` applies here exactly as on the socket framing — the
  shared ``_TcpCommunicator`` base wraps every connection in mutual
  TLS before any frame moves, so ``mode="grpc"``/``"grpc_proc"`` run
  encrypted with no change to the framing (docs/deploy.md).
* Messages ride one stream each (odd ids, ascending): HEADERS
  (END_HEADERS) then DATA frames of at most 16384 bytes, the last
  flagged END_STREAM. The DATA body is the gRPC length-prefixed
  message: 1 compressed-flag byte (always 0 — compression happens at
  the schema layer), a 4-byte big-endian length, then the safetensors
  blob whose ``__metadata__`` carries sender/tag exactly as on the
  socket transport.
"""
from __future__ import annotations

import socket
import struct
from typing import Dict, List, Optional, Tuple

from repro.comm import codec
from repro.comm.base import Message
from repro.comm.sock import (_MidFrameClose, _TcpCommunicator,
                             _recv_exact, local_addresses)

__all__ = ["GrpcCommunicator", "local_addresses"]

PREFACE = b"PRI * HTTP/2.0\r\n\r\nSM\r\n\r\n"
MAX_FRAME = 16384                      # HTTP/2 default SETTINGS_MAX_FRAME_SIZE

# frame types
FT_DATA = 0x0
FT_HEADERS = 0x1
FT_SETTINGS = 0x4

# frame flags
FLAG_END_STREAM = 0x1
FLAG_END_HEADERS = 0x4

_HELLO_PATH = "/repro.Party/Hello"
_SEND_PATH = "/repro.Party/Exchange"


def _hp_int(n: int, prefix_bits: int, first: int = 0) -> bytes:
    """HPACK integer encoding (RFC 7541 §5.1) with ``first`` carrying
    the representation's pattern bits above the prefix."""
    limit = (1 << prefix_bits) - 1
    if n < limit:
        return bytes([first | n])
    out = [first | limit]
    n -= limit
    while n >= 128:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)
    return bytes(out)


def _hp_read_int(buf: bytes, pos: int, prefix_bits: int
                 ) -> Tuple[int, int]:
    limit = (1 << prefix_bits) - 1
    n = buf[pos] & limit
    pos += 1
    if n < limit:
        return n, pos
    shift = 0
    while True:
        b = buf[pos]
        pos += 1
        n += (b & 0x7F) << shift
        shift += 7
        if not b & 0x80:
            return n, pos


def hpack_encode(headers: List[Tuple[str, str]]) -> bytes:
    """Literal-without-indexing representations only (pattern 0000)."""
    out = bytearray()
    for k, v in headers:
        kb, vb = k.encode(), v.encode()
        out += b"\x00"                       # literal, name not indexed
        out += _hp_int(len(kb), 7) + kb      # H bit 0: raw octets
        out += _hp_int(len(vb), 7) + vb
    return bytes(out)


def hpack_decode(block: bytes) -> Dict[str, str]:
    out: Dict[str, str] = {}
    pos = 0
    try:
        while pos < len(block):
            if block[pos] != 0x00:
                raise ValueError(
                    f"unsupported HPACK representation "
                    f"0x{block[pos]:02x} (this transport emits "
                    f"literal-without-indexing only)")
            pos += 1
            klen, pos = _hp_read_int(block, pos, 7)
            k = block[pos:pos + klen].decode()
            pos += klen
            vlen, pos = _hp_read_int(block, pos, 7)
            out[k] = block[pos:pos + vlen].decode()
            pos += vlen
    except (IndexError, UnicodeDecodeError) as e:
        # normalize so _serve_conn's except clause attributes the drop
        # instead of the listener thread dying unhandled
        raise ValueError(f"truncated/garbled HPACK block: {e}") from e
    return out


def _frame(ftype: int, flags: int, stream: int, body: bytes) -> bytes:
    return (len(body).to_bytes(3, "big") + bytes((ftype, flags))
            + (stream & 0x7FFFFFFF).to_bytes(4, "big") + body)


def _read_frame(conn: socket.socket) -> Tuple[int, int, int, bytes]:
    hdr = _recv_exact(conn, 9)
    length = int.from_bytes(hdr[:3], "big")
    ftype, flags = hdr[3], hdr[4]
    stream = int.from_bytes(hdr[5:9], "big") & 0x7FFFFFFF
    body = _recv_exact(conn, length) if length else b""
    return ftype, flags, stream, body


class GrpcCommunicator(_TcpCommunicator):
    """gRPC-framed transport; a drop-in peer of ``SocketCommunicator``.

    Registers as ``mode="grpc"`` (agents as threads) and
    ``mode="grpc_proc"`` (one OS process per agent) in
    :class:`~repro.core.party.VFLJob`.

    Example::

        from repro.comm.grpc import GrpcCommunicator, local_addresses

        addrs = local_addresses(["master", "member0"])
        cm = GrpcCommunicator("master", addrs)
        c0 = GrpcCommunicator("member0", addrs)
        c0.send("master", "t", {"x": np.arange(4.0)})
        assert cm.recv("member0", "t").tensor("x")[1] == 1.0
    """

    def __init__(self, me, addresses, timeout: float = 120.0,
                 nodelay: bool = True, comm_cfg=None):
        super().__init__(me, addresses, timeout=timeout,
                         nodelay=nodelay, comm_cfg=comm_cfg)
        self._next_stream = 3              # stream 1 is the hello

    # -- client side ---------------------------------------------------------
    def _greet(self, conn: socket.socket) -> None:
        hello = hpack_encode([
            (":method", "POST"), (":scheme", "http"),
            (":path", _HELLO_PATH), (":authority", "party"),
            ("grpc-agent", self.me),
        ])
        conn.sendall(PREFACE + _frame(FT_SETTINGS, 0, 0, b"")
                     + _frame(FT_HEADERS,
                              FLAG_END_HEADERS | FLAG_END_STREAM, 1,
                              hello))

    def _send(self, msg: Message, raw: bytes) -> None:
        stream = self._next_stream         # sender-thread serialized
        self._next_stream += 2
        headers = hpack_encode([
            (":method", "POST"), (":scheme", "http"),
            (":path", _SEND_PATH), (":authority", msg.recipient),
            ("content-type", "application/grpc+safetensors"),
            ("grpc-agent", self.me),
        ])
        grpc_msg = b"\x00" + struct.pack(">I", len(raw)) + raw
        bufs = [_frame(FT_HEADERS, FLAG_END_HEADERS, stream, headers)]
        for lo in range(0, len(grpc_msg), MAX_FRAME):
            chunk = grpc_msg[lo:lo + MAX_FRAME]
            last = lo + MAX_FRAME >= len(grpc_msg)
            bufs.append(_frame(FT_DATA, FLAG_END_STREAM if last else 0,
                               stream, chunk))
        # small messages coalesce into one sendall (one packet under
        # NODELAY), mirroring the socket transport's inline-frame path
        if len(grpc_msg) <= MAX_FRAME:
            self._write_frames(msg.recipient, b"".join(bufs))
        else:
            self._write_frames(msg.recipient, *bufs)

    # -- server side ---------------------------------------------------------
    def _serve_conn(self, conn: socket.socket) -> None:
        sender: Optional[str] = None
        streams: Dict[int, bytearray] = {}
        try:
            if _recv_exact(conn, len(PREFACE)) != PREFACE:
                raise ConnectionError("bad HTTP/2 connection preface")
            while True:
                ftype, flags, stream, body = _read_frame(conn)
                if ftype == FT_SETTINGS:
                    continue               # write-silent server: no ack
                if ftype == FT_HEADERS:
                    hdrs = hpack_decode(body)
                    agent = hdrs.get("grpc-agent")
                    if agent:
                        sender = agent
                    if hdrs.get(":path") == _HELLO_PATH:
                        continue
                    streams[stream] = bytearray()
                elif ftype == FT_DATA:
                    buf = streams.get(stream)
                    if buf is None:
                        raise ConnectionError(
                            f"DATA on unopened stream {stream}")
                    buf += body
                    if flags & FLAG_END_STREAM:
                        # deliver BEFORE closing the stream ledger: a
                        # corrupt gRPC prefix raises with the stream
                        # still open, so the drop is attributed below
                        # instead of hanging waiters to the timeout
                        self._deliver_stream(sender, bytes(buf))
                        del streams[stream]
                # unknown frame types are ignored (HTTP/2 §4.1 says
                # implementations must discard frames they don't know)
        except (ConnectionError, OSError, ValueError) as e:
            # a clean close lands between frames with no stream open;
            # anything else (mid-frame partial read, an open stream,
            # bad preface/HPACK) means the peer died with a message on
            # the wire — attribute it and fail waiters fast. strict_eof
            # (elastic clusters) attributes even the clean close: a
            # SIGKILL'd peer's kernel closes its sockets tidily.
            if streams or isinstance(e, (_MidFrameClose, ValueError)) \
                    or (self._strict_eof and sender is not None):
                self._mark_down(sender)
            return

    def _deliver_stream(self, sender: Optional[str], buf: bytes) -> None:
        if len(buf) < 5:
            raise ConnectionError("short gRPC message prefix")
        (n,) = struct.unpack(">I", buf[1:5])
        if len(buf) - 5 != n:
            raise ConnectionError(
                f"gRPC length prefix {n} != body {len(buf) - 5}")
        payload, meta = codec.decode(buf[5:])
        sender = meta.pop("sender", sender)
        tag = meta.pop("tag")
        self._deliver(Message(sender, self.me, tag, payload, meta))
