"""gRPC-style framed transport: HTTP/2-like wire format, stdlib only.

The paper ships gRPC + Protobuf + Safetensors. This transport
reproduces the gRPC *wire shape* — an HTTP/2 connection preface, a
SETTINGS frame, HPACK-encoded HEADERS opening one stream per message,
and the payload chunked into DATA frames behind the 5-byte gRPC
message prefix — over plain TCP with no third-party dependency, while
speaking the exact same safetensors channel payloads as the socket
transport (``comm/sock.py``): the two are interchangeable under every
protocol, and the seed-trace bit-identity suite runs on both. When the
real ``grpcio`` package is available it can be slotted behind the same
interface, but nothing here imports it.

Scope (documented in docs/transports.md, internals in DESIGN.md §8):

* Each direction of each agent pair is its own client connection
  (mirroring the socket transport's lazy outbound links). The server
  answers with HTTP/2 flow control: it advertises
  ``SETTINGS_INITIAL_WINDOW_SIZE``, acks the client's SETTINGS, grows
  the connection window with an immediate WINDOW_UPDATE, and
  replenishes connection/stream windows as it consumes DATA. The
  client honors both windows — every DATA frame waits for credit
  (RFC 7540 §6.9), so a long-lived serving stream pushing a large
  response interops with real gRPC peers instead of relying on TCP
  backpressure alone. A send stalled on a closed window fails
  attributed after the transport timeout.
* HEADERS use HPACK *literal without indexing* representations only
  (no dynamic table, no Huffman) — valid HPACK, trivially decodable.
* Stream 1 is the connection hello (``:path /repro.Party/Hello`` +
  ``grpc-agent``), so a peer dying inside its very first data stream
  is still attributable and fails waiters fast.
* ``CommCfg.tls`` applies here exactly as on the socket framing — the
  shared ``_TcpCommunicator`` base wraps every connection in mutual
  TLS before any frame moves, so ``mode="grpc"``/``"grpc_proc"`` run
  encrypted with no change to the framing (docs/deploy.md).
* Messages ride one stream each (odd ids, ascending): HEADERS
  (END_HEADERS) then DATA frames of at most 16384 bytes, the last
  flagged END_STREAM. The DATA body is the gRPC length-prefixed
  message: 1 compressed-flag byte (always 0 — compression happens at
  the schema layer), a 4-byte big-endian length, then the safetensors
  blob whose ``__metadata__`` carries sender/tag exactly as on the
  socket transport.
"""
from __future__ import annotations

import socket
import struct
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.comm import codec
from repro.comm.base import Message
from repro.comm.sock import (_MidFrameClose, _TcpCommunicator,
                             _recv_exact, local_addresses)

__all__ = ["GrpcCommunicator", "local_addresses"]

PREFACE = b"PRI * HTTP/2.0\r\n\r\nSM\r\n\r\n"
MAX_FRAME = 16384                      # HTTP/2 default SETTINGS_MAX_FRAME_SIZE

# frame types
FT_DATA = 0x0
FT_HEADERS = 0x1
FT_SETTINGS = 0x4
FT_WINDOW_UPDATE = 0x8

# frame flags
FLAG_END_STREAM = 0x1
FLAG_END_HEADERS = 0x4
FLAG_ACK = 0x1                         # on SETTINGS frames

# flow control (RFC 7540 §6.9): both connection and stream windows
# start at the protocol default; our server immediately advertises a
# large initial stream window via SETTINGS and grows the connection
# window via WINDOW_UPDATE so bulk activations/ciphertexts stream
# without per-64KiB round trips, then replenishes as it consumes.
SETTINGS_INITIAL_WINDOW_SIZE = 0x4
DEFAULT_WINDOW = 65535
RECV_WINDOW = 1 << 24                  # 16 MiB advertised by the server

_HELLO_PATH = "/repro.Party/Hello"
_SEND_PATH = "/repro.Party/Exchange"


def _hp_int(n: int, prefix_bits: int, first: int = 0) -> bytes:
    """HPACK integer encoding (RFC 7541 §5.1) with ``first`` carrying
    the representation's pattern bits above the prefix."""
    limit = (1 << prefix_bits) - 1
    if n < limit:
        return bytes([first | n])
    out = [first | limit]
    n -= limit
    while n >= 128:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)
    return bytes(out)


def _hp_read_int(buf: bytes, pos: int, prefix_bits: int
                 ) -> Tuple[int, int]:
    limit = (1 << prefix_bits) - 1
    n = buf[pos] & limit
    pos += 1
    if n < limit:
        return n, pos
    shift = 0
    while True:
        b = buf[pos]
        pos += 1
        n += (b & 0x7F) << shift
        shift += 7
        if not b & 0x80:
            return n, pos


def hpack_encode(headers: List[Tuple[str, str]]) -> bytes:
    """Literal-without-indexing representations only (pattern 0000)."""
    out = bytearray()
    for k, v in headers:
        kb, vb = k.encode(), v.encode()
        out += b"\x00"                       # literal, name not indexed
        out += _hp_int(len(kb), 7) + kb      # H bit 0: raw octets
        out += _hp_int(len(vb), 7) + vb
    return bytes(out)


def hpack_decode(block: bytes) -> Dict[str, str]:
    out: Dict[str, str] = {}
    pos = 0
    try:
        while pos < len(block):
            if block[pos] != 0x00:
                raise ValueError(
                    f"unsupported HPACK representation "
                    f"0x{block[pos]:02x} (this transport emits "
                    f"literal-without-indexing only)")
            pos += 1
            klen, pos = _hp_read_int(block, pos, 7)
            k = block[pos:pos + klen].decode()
            pos += klen
            vlen, pos = _hp_read_int(block, pos, 7)
            out[k] = block[pos:pos + vlen].decode()
            pos += vlen
    except (IndexError, UnicodeDecodeError) as e:
        # normalize so _serve_conn's except clause attributes the drop
        # instead of the listener thread dying unhandled
        raise ValueError(f"truncated/garbled HPACK block: {e}") from e
    return out


def _frame(ftype: int, flags: int, stream: int, body: bytes) -> bytes:
    return (len(body).to_bytes(3, "big") + bytes((ftype, flags))
            + (stream & 0x7FFFFFFF).to_bytes(4, "big") + body)


def _read_frame(conn: socket.socket) -> Tuple[int, int, int, bytes]:
    hdr = _recv_exact(conn, 9)
    length = int.from_bytes(hdr[:3], "big")
    ftype, flags = hdr[3], hdr[4]
    stream = int.from_bytes(hdr[5:9], "big") & 0x7FFFFFFF
    body = _recv_exact(conn, length) if length else b""
    return ftype, flags, stream, body


def _settings_body(entries: Dict[int, int]) -> bytes:
    return b"".join(k.to_bytes(2, "big") + v.to_bytes(4, "big")
                    for k, v in entries.items())


def _parse_settings(body: bytes) -> Dict[int, int]:
    out: Dict[int, int] = {}
    for i in range(0, len(body) - 5, 6):
        out[int.from_bytes(body[i:i + 2], "big")] = \
            int.from_bytes(body[i + 2:i + 6], "big")
    return out


def _window_update(stream: int, inc: int) -> bytes:
    return _frame(FT_WINDOW_UPDATE, 0, stream,
                  (inc & 0x7FFFFFFF).to_bytes(4, "big"))


class _FlowState:
    """Client-side send windows for one outbound connection: the
    connection window plus one window per open stream, replenished by
    the peer's SETTINGS / WINDOW_UPDATE frames (read by the per-
    connection reader thread). DATA writes block in :meth:`consume`
    until both windows have credit."""

    def __init__(self):
        self.cv = threading.Condition()
        self.conn_window = DEFAULT_WINDOW
        self.initial_window = DEFAULT_WINDOW
        self.streams: Dict[int, int] = {}
        self.closed = False

    def open_stream(self, stream: int) -> None:
        with self.cv:
            self.streams[stream] = self.initial_window

    def close_stream(self, stream: int) -> None:
        with self.cv:
            self.streams.pop(stream, None)

    def consume(self, stream: int, n: int, timeout: float,
                who: str) -> None:
        """Block until ``n`` bytes of credit exist on both the
        connection and ``stream`` windows, then take them."""
        deadline = time.monotonic() + timeout
        with self.cv:
            while not self.closed and (
                    self.conn_window < n
                    or self.streams.get(stream, 0) < n):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ConnectionError(
                        f"{who}: flow-control stall — peer advanced "
                        f"no window for {timeout}s (conn "
                        f"{self.conn_window}, stream {stream} "
                        f"{self.streams.get(stream, 0)}, need {n})")
                self.cv.wait(remaining)
            if self.closed:
                raise ConnectionError(
                    f"{who}: connection lost while awaiting "
                    f"flow-control window")
            self.conn_window -= n
            self.streams[stream] -= n

    def window_update(self, stream: int, inc: int) -> None:
        with self.cv:
            if stream == 0:
                self.conn_window += inc
            elif stream in self.streams:
                self.streams[stream] += inc
            self.cv.notify_all()

    def apply_settings(self, new_initial: int) -> None:
        # RFC 7540 §6.9.2: a changed SETTINGS_INITIAL_WINDOW_SIZE
        # adjusts every open stream window by the delta (possibly
        # driving it negative); the connection window is untouched
        with self.cv:
            delta = new_initial - self.initial_window
            self.initial_window = new_initial
            for s in self.streams:
                self.streams[s] += delta
            self.cv.notify_all()

    def close(self) -> None:
        with self.cv:
            self.closed = True
            self.cv.notify_all()


class GrpcCommunicator(_TcpCommunicator):
    """gRPC-framed transport; a drop-in peer of ``SocketCommunicator``.

    Registers as ``mode="grpc"`` (agents as threads) and
    ``mode="grpc_proc"`` (one OS process per agent) in
    :class:`~repro.core.party.VFLJob`.

    Example::

        from repro.comm.grpc import GrpcCommunicator, local_addresses

        addrs = local_addresses(["master", "member0"])
        cm = GrpcCommunicator("master", addrs)
        c0 = GrpcCommunicator("member0", addrs)
        c0.send("master", "t", {"x": np.arange(4.0)})
        assert cm.recv("member0", "t").tensor("x")[1] == 1.0
    """

    def __init__(self, me, addresses, timeout: float = 120.0,
                 nodelay: bool = True, comm_cfg=None):
        super().__init__(me, addresses, timeout=timeout,
                         nodelay=nodelay, comm_cfg=comm_cfg)
        self._next_stream = 3              # stream 1 is the hello
        # per-outbound-connection flow control + write serialization
        # (the sender thread and the reader thread's SETTINGS ack both
        # write on the same socket)
        self._fc: Dict[socket.socket, _FlowState] = {}
        self._wl: Dict[socket.socket, threading.Lock] = {}

    # -- client side ---------------------------------------------------------
    def _greet(self, conn: socket.socket) -> None:
        hello = hpack_encode([
            (":method", "POST"), (":scheme", "http"),
            (":path", _HELLO_PATH), (":authority", "party"),
            ("grpc-agent", self.me),
        ])
        conn.sendall(PREFACE + _frame(FT_SETTINGS, 0, 0, b"")
                     + _frame(FT_HEADERS,
                              FLAG_END_HEADERS | FLAG_END_STREAM, 1,
                              hello))
        fc = _FlowState()
        self._fc[conn] = fc
        self._wl[conn] = threading.Lock()
        t = threading.Thread(target=self._client_reader,
                             args=(conn, fc),
                             name=f"grpc-fc-{self.me}", daemon=True)
        t.start()

    def _client_reader(self, conn: socket.socket,
                       fc: _FlowState) -> None:
        """Consume the server's control frames on an outbound
        connection: SETTINGS (initial window size; acked), WINDOW_UPDATE
        (credit). Exits — releasing any window-blocked sender — when the
        connection dies."""
        try:
            while True:
                ftype, flags, stream, body = _read_frame(conn)
                if ftype == FT_SETTINGS:
                    if flags & FLAG_ACK:
                        continue
                    iw = _parse_settings(body).get(
                        SETTINGS_INITIAL_WINDOW_SIZE)
                    if iw is not None:
                        fc.apply_settings(iw)
                    lock = self._wl.get(conn)
                    if lock is not None:
                        with lock:
                            conn.sendall(
                                _frame(FT_SETTINGS, FLAG_ACK, 0, b""))
                elif ftype == FT_WINDOW_UPDATE:
                    inc = int.from_bytes(body[:4], "big") & 0x7FFFFFFF
                    fc.window_update(stream, inc)
                # other server frames (trailers etc.) are ignored
        except (ConnectionError, OSError, ValueError):
            pass
        finally:
            fc.close()
            self._fc.pop(conn, None)
            self._wl.pop(conn, None)

    def _write_frames(self, recipient: str, *bufs: bytes) -> None:
        conn = self._conn_to(recipient)
        lock = self._wl.get(conn)
        if lock is None:
            super()._write_frames(recipient, *bufs)
        else:
            with lock:
                super()._write_frames(recipient, *bufs)

    def _send(self, msg: Message, raw: bytes) -> None:
        stream = self._next_stream         # sender-thread serialized
        self._next_stream += 2
        headers = hpack_encode([
            (":method", "POST"), (":scheme", "http"),
            (":path", _SEND_PATH), (":authority", msg.recipient),
            ("content-type", "application/grpc+safetensors"),
            ("grpc-agent", self.me),
        ])
        grpc_msg = b"\x00" + struct.pack(">I", len(raw)) + raw
        conn = self._conn_to(msg.recipient)
        fc = self._fc.get(conn)
        bufs = [_frame(FT_HEADERS, FLAG_END_HEADERS, stream, headers)]
        if fc is None:
            # reader already tore the state down — surface the drop via
            # the normal write path (which closes the cached conn)
            raise ConnectionError(
                f"{self.me}: connection to {msg.recipient!r} lost "
                f"before stream {stream} opened")
        fc.open_stream(stream)
        try:
            for lo in range(0, len(grpc_msg), MAX_FRAME):
                chunk = grpc_msg[lo:lo + MAX_FRAME]
                last = lo + MAX_FRAME >= len(grpc_msg)
                bufs.append(_frame(FT_DATA,
                                   FLAG_END_STREAM if last else 0,
                                   stream, chunk))
                try:
                    fc.consume(stream, len(chunk), self._timeout,
                               self.me)
                except ConnectionError:
                    # a stalled window is a dead link: drop the cached
                    # conn so no later write corrupts peer framing
                    self._out.pop(msg.recipient, None)
                    try:
                        conn.close()
                    except OSError:
                        pass
                    raise
                # small messages coalesce HEADERS+DATA into one sendall
                # (one packet under NODELAY), mirroring the socket
                # transport's inline-frame path; larger ones flush as
                # window credit arrives
                if last and len(bufs) == 2:
                    self._write_frames(msg.recipient, b"".join(bufs))
                else:
                    self._write_frames(msg.recipient, *bufs)
                bufs = []
        finally:
            fc.close_stream(stream)

    # -- server side ---------------------------------------------------------
    def _serve_conn(self, conn: socket.socket) -> None:
        sender: Optional[str] = None
        streams: Dict[int, bytearray] = {}
        # receive-side flow-control ledger: how much consumed credit we
        # owe the peer, per connection and per open stream. Replenished
        # lazily at half-window so bulk streams cost O(size/8MiB)
        # WINDOW_UPDATE frames, not one per DATA frame.
        conn_owed = 0
        stream_owed: Dict[int, int] = {}
        try:
            if _recv_exact(conn, len(PREFACE)) != PREFACE:
                raise ConnectionError("bad HTTP/2 connection preface")
            # advertise our receive windows up front: SETTINGS grows
            # every (current and future) stream window, WINDOW_UPDATE
            # grows the connection window, which SETTINGS cannot touch
            conn.sendall(
                _frame(FT_SETTINGS, 0, 0, _settings_body(
                    {SETTINGS_INITIAL_WINDOW_SIZE: RECV_WINDOW}))
                + _window_update(0, RECV_WINDOW - DEFAULT_WINDOW))
            while True:
                ftype, flags, stream, body = _read_frame(conn)
                if ftype == FT_SETTINGS:
                    if not flags & FLAG_ACK:
                        conn.sendall(
                            _frame(FT_SETTINGS, FLAG_ACK, 0, b""))
                    continue
                if ftype == FT_HEADERS:
                    hdrs = hpack_decode(body)
                    agent = hdrs.get("grpc-agent")
                    if agent:
                        sender = agent
                    if hdrs.get(":path") == _HELLO_PATH:
                        continue
                    streams[stream] = bytearray()
                elif ftype == FT_DATA:
                    buf = streams.get(stream)
                    if buf is None:
                        raise ConnectionError(
                            f"DATA on unopened stream {stream}")
                    buf += body
                    conn_owed += len(body)
                    if flags & FLAG_END_STREAM:
                        # deliver BEFORE closing the stream ledger: a
                        # corrupt gRPC prefix raises with the stream
                        # still open, so the drop is attributed below
                        # instead of hanging waiters to the timeout
                        self._deliver_stream(sender, bytes(buf))
                        del streams[stream]
                        stream_owed.pop(stream, None)
                    else:
                        owed = stream_owed.get(stream, 0) + len(body)
                        if owed >= RECV_WINDOW // 2:
                            conn.sendall(_window_update(stream, owed))
                            owed = 0
                        stream_owed[stream] = owed
                    if conn_owed >= RECV_WINDOW // 2:
                        conn.sendall(_window_update(0, conn_owed))
                        conn_owed = 0
                # unknown frame types are ignored (HTTP/2 §4.1 says
                # implementations must discard frames they don't know)
        except (ConnectionError, OSError, ValueError) as e:
            # a clean close lands between frames with no stream open;
            # anything else (mid-frame partial read, an open stream,
            # bad preface/HPACK) means the peer died with a message on
            # the wire — attribute it and fail waiters fast. strict_eof
            # (elastic clusters) attributes even the clean close: a
            # SIGKILL'd peer's kernel closes its sockets tidily.
            if streams or isinstance(e, (_MidFrameClose, ValueError)) \
                    or (self._strict_eof and sender is not None):
                self._mark_down(sender)
            return

    def _deliver_stream(self, sender: Optional[str], buf: bytes) -> None:
        if len(buf) < 5:
            raise ConnectionError("short gRPC message prefix")
        (n,) = struct.unpack(">I", buf[1:5])
        if len(buf) - 5 != n:
            raise ConnectionError(
                f"gRPC length prefix {n} != body {len(buf) - 5}")
        payload, meta = codec.decode(buf[5:])
        sender = meta.pop("sender", sender)
        tag = meta.pop("tag")
        self._deliver(Message(sender, self.me, tag, payload, meta))
