"""Safetensors-compatible tensor serialization.

The paper ships gRPC + Protobuf + Safetensors; offline we reproduce the
wire format itself: an 8-byte little-endian header length, a JSON header
mapping tensor names to {dtype, shape, data_offsets}, then the raw
buffers. This is byte-compatible with the safetensors spec (plus a
"__metadata__" entry for message routing), so payloads produced here
could be read by the reference implementation.
"""
from __future__ import annotations

import json
import struct
from typing import Any, Dict, Optional, Tuple

import numpy as np

_DTYPES = {
    "F64": np.float64, "F32": np.float32, "F16": np.float16,
    "I64": np.int64, "I32": np.int32, "I16": np.int16, "I8": np.int8,
    "U64": np.uint64, "U32": np.uint32, "U16": np.uint16, "U8": np.uint8,
    "BOOL": np.bool_,
}
_DTYPE_NAMES = {np.dtype(v): k for k, v in _DTYPES.items()}


def encode(tensors: Dict[str, np.ndarray],
           metadata: Optional[Dict[str, str]] = None) -> bytes:
    header: Dict[str, Any] = {}
    if metadata:
        header["__metadata__"] = {str(k): str(v) for k, v in metadata.items()}
    buffers = []
    offset = 0
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype.kind in ("S", "V"):
            # byte-string tensors (ids, digests, ciphertexts) ride as U8
            # with the item size recorded in metadata
            itemsize = arr.dtype.itemsize
            header.setdefault("__metadata__", {})[f"bytes:{name}"] = \
                str(itemsize)
            arr = np.frombuffer(arr.tobytes(), np.uint8).reshape(
                arr.shape + (itemsize,))
        key = _DTYPE_NAMES.get(arr.dtype)
        if key is None:
            raise TypeError(f"unsupported dtype {arr.dtype} for {name}")
        raw = arr.tobytes()
        header[name] = {"dtype": key, "shape": list(arr.shape),
                        "data_offsets": [offset, offset + len(raw)]}
        buffers.append(raw)
        offset += len(raw)
    hjson = json.dumps(header, separators=(",", ":")).encode()
    pad = (8 - len(hjson) % 8) % 8          # spec: header padded with spaces
    hjson += b" " * pad
    return struct.pack("<Q", len(hjson)) + hjson + b"".join(buffers)


def decode(blob: bytes) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    (hlen,) = struct.unpack_from("<Q", blob, 0)
    header = json.loads(blob[8:8 + hlen].decode())
    base = 8 + hlen
    metadata = header.pop("__metadata__", {})
    out: Dict[str, np.ndarray] = {}
    for name, info in header.items():
        lo, hi = info["data_offsets"]
        arr = np.frombuffer(blob[base + lo:base + hi],
                            dtype=_DTYPES[info["dtype"]])
        arr = arr.reshape(info["shape"]).copy()
        bkey = f"bytes:{name}"
        if bkey in metadata:
            itemsize = int(metadata[bkey])
            arr = np.frombuffer(arr.tobytes(), dtype=f"S{itemsize}"
                                ).reshape(info["shape"][:-1]).copy()
        out[name] = arr
    return out, metadata


def nbytes(tensors: Dict[str, np.ndarray]) -> int:
    return sum(np.ascontiguousarray(a).nbytes for a in tensors.values())


# ---------------------------------------------------------------------------
# big-int transport (ciphertexts, blinded PSI points)
# ---------------------------------------------------------------------------
# Widths are *derived from the key size* by the sender and carried in
# message metadata — nothing on the wire is hardcoded, so 2048-bit+
# Paillier ciphertexts transport without truncation.


def int_width(n: int) -> int:
    """Bytes needed for non-negative ints < n (e.g. n = modulus)."""
    return max(1, ((n - 1).bit_length() + 7) // 8)


def ints_to_u8(vals, width: int) -> np.ndarray:
    """Non-negative big ints -> (len, width) uint8 big-endian rows."""
    buf = b"".join(int(v).to_bytes(width, "big") for v in vals)
    return np.frombuffer(buf, np.uint8).reshape(len(vals), width)


def u8_to_ints(arr: np.ndarray) -> list:
    """Inverse of ints_to_u8 for any trailing-dim width."""
    flat = np.ascontiguousarray(arr).reshape(-1, arr.shape[-1])
    data = flat.tobytes()
    w = arr.shape[-1]
    return [int.from_bytes(data[i * w:(i + 1) * w], "big")
            for i in range(flat.shape[0])]
