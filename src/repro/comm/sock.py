"""Distributed TCP communicator — the offline stand-in for the paper's
gRPC transport (gRPC adds framing/auth on top of the same safetensors
payloads; semantics are identical for protocol purposes).

Every agent runs a listener thread; messages are length-prefixed
safetensors blobs. Agents connect lazily and reuse sockets. Works across
hosts; in tests everything binds to 127.0.0.1.

Latency engineering (DESIGN.md §7): ``TCP_NODELAY`` is set on both the
connecting and the accepted side (small control messages used to sit in
Nagle's buffer waiting for the peer's delayed ACK), and small frames go
out as ONE ``sendall`` buffer (prefix + body) so a frame never straddles
a Nagle boundary; large bodies skip the concat copy. A connection that
drops mid-frame marks its sender as down and wakes every waiter —
``recv`` from a dead peer raises ``ConnectionError`` immediately instead
of hanging until the timeout.
"""
from __future__ import annotations

import socket
import struct
import threading
import time
from typing import Dict, Optional, Sequence, Set, Tuple

from repro.comm import codec
from repro.comm.base import Message, PartyCommunicator

# below this, prefix+body are concatenated into one buffer (one packet
# under NODELAY); above it, the concat copy costs more than it saves
_INLINE_FRAME_BYTES = 1 << 16


class _MidFrameClose(ConnectionError):
    """The peer closed with a partially-delivered read outstanding."""


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = conn.recv(n - got)
        if not chunk:
            if got:
                raise _MidFrameClose(
                    f"socket closed mid-frame ({got}/{n} bytes)")
            raise ConnectionError("socket closed")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class SocketCommunicator(PartyCommunicator):
    def __init__(self, me: str, addresses: Dict[str, Tuple[str, int]],
                 timeout: float = 120.0, nodelay: bool = True):
        """addresses: agent id -> (host, port) for EVERY agent.

        ``timeout`` bounds every blocking wait (connect + recv);
        ``nodelay`` disables Nagle (keep True — the flag exists so the
        benchmark can measure the before/after honestly).
        """
        super().__init__(me, list(addresses), timeout=timeout)
        self._addr = dict(addresses)
        self._pending: Dict[Tuple[str, str], list] = {}
        self._cv = threading.Condition()
        self._out: Dict[str, socket.socket] = {}
        self._down: Set[str] = set()
        self._nodelay = nodelay
        host, port = self._addr[me]
        # pre-allocated ports can be sniped between allocation and bind
        # (socket_proc: the bind happens seconds later in a spawned
        # child) — retry transient EADDRINUSE briefly before giving up
        deadline = time.monotonic() + min(self._timeout, 10.0)
        while True:
            try:
                self._server = socket.create_server((host, port),
                                                    backlog=16)
                break
            except OSError:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.1)
        self._alive = True
        self._listener = threading.Thread(target=self._listen, daemon=True)
        self._listener.start()

    # -- server side ---------------------------------------------------------
    def _listen(self):
        while self._alive:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return
            if self._nodelay:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        sender: Optional[str] = None
        mid_frame = False
        try:
            # connection hello: the first frame is the peer's agent id,
            # so even a drop during the peer's FIRST data frame is
            # attributable and fails waiters instead of hanging
            (n,) = struct.unpack("<Q", _recv_exact(conn, 8))
            sender = _recv_exact(conn, n).decode()
            while True:
                mid_frame = False
                (n,) = struct.unpack("<Q", _recv_exact(conn, 8))
                mid_frame = True
                raw = _recv_exact(conn, n)
                payload, meta = codec.decode(raw)
                sender = meta.pop("sender", sender)
                tag = meta.pop("tag")
                msg = Message(sender, self.me, tag, payload, meta)
                with self._cv:
                    self._pending.setdefault((sender, tag),
                                             []).append(msg)
                    self._cv.notify_all()
        except (ConnectionError, OSError) as e:
            # a clean close lands exactly between frames; a drop with
            # bytes outstanding (inside the body — mid_frame — or even
            # inside the next length prefix, _MidFrameClose) means the
            # peer died with a message on the wire. The sender delivers
            # nothing further: mark it down and wake waiters so they
            # error instead of hanging out the timeout.
            if sender is not None and self._alive \
                    and (mid_frame or isinstance(e, _MidFrameClose)):
                with self._cv:
                    self._down.add(sender)
                    self._cv.notify_all()
            return

    # -- client side ---------------------------------------------------------
    def _conn_to(self, to: str) -> socket.socket:
        if to not in self._out:
            # peers boot independently (one process per agent): retry
            # refused connects until the peer's listener is up, bounded
            # by the configured timeout
            deadline = time.monotonic() + self._timeout
            while True:
                try:
                    conn = socket.create_connection(
                        self._addr[to], timeout=self._timeout)
                    break
                except ConnectionRefusedError:
                    if time.monotonic() > deadline:
                        raise
                    time.sleep(0.05)
            if self._nodelay:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            me = self.me.encode()
            conn.sendall(struct.pack("<Q", len(me)) + me)   # hello
            self._out[to] = conn
        return self._out[to]

    def _send(self, msg: Message, raw: bytes) -> None:
        conn = self._conn_to(msg.recipient)
        prefix = struct.pack("<Q", len(raw))
        try:
            if len(raw) <= _INLINE_FRAME_BYTES:
                conn.sendall(prefix + raw)  # one buffer -> one packet
            else:
                conn.sendall(prefix)
                conn.sendall(raw)
        except BaseException:
            # the stream may be mid-frame: drop the connection so no
            # later write can corrupt the peer's length-prefix parse
            self._out.pop(msg.recipient, None)
            try:
                conn.close()
            except OSError:
                pass
            raise

    def _recv_any(self, frm: str, tags: Sequence[str],
                  timeout: Optional[float] = None) -> Message:
        timeout = self._timeout if timeout is None else timeout
        keys = [(frm, t) for t in tags]

        def ready():
            return any(self._pending.get(k) for k in keys) \
                or frm in self._down

        with self._cv:
            ok = self._cv.wait_for(ready, timeout=timeout)
            for k in keys:
                lst = self._pending.get(k)
                if lst:
                    msg = lst.pop(0)
                    if not lst:     # delete drained stepped-tag entries
                        del self._pending[k]
                    return msg
            if frm in self._down:
                raise ConnectionError(
                    f"{self.me}: connection from {frm!r} dropped "
                    f"mid-frame with no message {list(tags)} pending")
            if not ok:
                raise TimeoutError(f"{self.me}: no message "
                                   f"{frm}/{list(tags)}")
            raise AssertionError("unreachable")   # pragma: no cover

    def _peek(self, frm: str, tags: Sequence[str]) -> bool:
        with self._cv:
            return any(self._pending.get((frm, t)) for t in tags)

    def close(self) -> None:
        super().close()                  # drain + stop the sender thread
        self._alive = False
        try:
            self._server.close()
        except OSError:
            pass
        for c in self._out.values():
            try:
                c.close()
            except OSError:
                pass


def local_addresses(world: Sequence[str], base_port: int = 0
                    ) -> Dict[str, Tuple[str, int]]:
    """Allocate loopback addresses with OS-assigned free ports."""
    addrs: Dict[str, Tuple[str, int]] = {}
    for w in world:
        s = socket.socket()
        s.bind(("127.0.0.1", base_port))
        addrs[w] = ("127.0.0.1", s.getsockname()[1])
        s.close()
    return addrs
