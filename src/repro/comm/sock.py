"""Distributed TCP communicator: length-prefixed safetensors frames.

Every agent runs a listener thread; messages are length-prefixed
safetensors blobs. Agents connect lazily and reuse sockets. Works across
hosts; in tests everything binds to 127.0.0.1. The gRPC-style framed
transport (``comm/grpc.py``) shares this module's server/connection
machinery (:class:`_TcpCommunicator`) and differs only in the wire
framing — see docs/transports.md for both wire formats. With
``CommCfg.tls = TLSSpec(...)`` every connection (both framings, thread
and ``*_proc`` modes) is wrapped in mutually-authenticated TLS; the
frame/payload contract above the wire is unchanged, so TLS'd depth-1
runs stay bit-identical to plaintext traces (docs/deploy.md covers
certificate generation and the cluster launcher).

Latency engineering (DESIGN.md §7): ``TCP_NODELAY`` is set on both the
connecting and the accepted side (small control messages used to sit in
Nagle's buffer waiting for the peer's delayed ACK), and small frames go
out as ONE ``sendall`` buffer (prefix + body) so a frame never straddles
a Nagle boundary; large bodies skip the concat copy. A connection that
drops mid-frame marks its sender as down and wakes every waiter —
``recv`` from a dead peer raises ``ConnectionError`` immediately instead
of hanging until the timeout.
"""
from __future__ import annotations

import random
import socket
import ssl
import struct
import threading
import time
from typing import Dict, Optional, Sequence, Set, Tuple

from repro.comm import codec
from repro.comm.base import CommCfg, Message, PartyCommunicator

# below this, prefix+body are concatenated into one buffer (one packet
# under NODELAY); above it, the concat copy costs more than it saves
_INLINE_FRAME_BYTES = 1 << 16


class _MidFrameClose(ConnectionError):
    """The peer closed with a partially-delivered read outstanding."""


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = conn.recv(n - got)
        if not chunk:
            if got:
                raise _MidFrameClose(
                    f"socket closed mid-frame ({got}/{n} bytes)")
            raise ConnectionError("socket closed")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class _TcpCommunicator(PartyCommunicator):
    """Shared TCP server/connection machinery for framed transports.

    Owns the listener socket (bind retries transient EADDRINUSE — a
    pre-allocated port can be sniped before a spawned child binds it),
    the accept loop, lazy outbound connections with connect retries
    (independently booting agents link up in any order), the pending
    message store with mid-frame-drop attribution, and close().

    Subclasses provide the wire format:

    * ``_greet(conn)`` — write the connection opening (hello frame /
      HTTP/2 preface) right after connect.
    * ``_serve_conn(conn)`` — per-connection read loop; deliver parsed
      messages via ``_deliver`` and attribute drops via ``_mark_down``.
    * ``_send(msg, raw)`` — frame and write one message.
    """

    def __init__(self, me: str, addresses: Dict[str, Tuple[str, int]],
                 timeout: float = 120.0, nodelay: bool = True,
                 comm_cfg: Optional[CommCfg] = None):
        """``addresses``: agent id -> (host, port) for EVERY agent.

        ``timeout`` bounds every blocking wait (connect + recv);
        ``nodelay`` disables Nagle (keep True — the flag exists so the
        benchmark can measure the before/after honestly). Both are
        superseded by ``comm_cfg`` when one is passed.
        """
        super().__init__(me, list(addresses), timeout=timeout,
                         comm_cfg=comm_cfg)
        self._addr = dict(addresses)
        self._pending: Dict[Tuple[str, str], list] = {}
        self._cv = threading.Condition()
        self._out: Dict[str, socket.socket] = {}
        self._in: Set[socket.socket] = set()
        self._in_lock = threading.Lock()
        self._down: Set[str] = set()
        # elastic clusters: any EOF from an identified peer is a drop
        # (SIGKILL's kernel-closed sockets look like clean closes)
        self._strict_eof = self.cfg.strict_eof
        self._nodelay = self.cfg.nodelay if comm_cfg is not None \
            else nodelay
        # TLS (DESIGN.md §9): both framings (length-prefix and gRPC)
        # ride the same ssl.SSLContext wrapping — the wire bytes change,
        # the frame/payload contract above them does not
        self._tls = self.cfg.tls.resolve(me) \
            if self.cfg.tls is not None else None
        self._srv_ctx = self._tls.server_context() if self._tls else None
        self._cli_ctx = self._tls.client_context() if self._tls else None
        host, port = self._addr[me]
        deadline = time.monotonic() + min(self._timeout, 10.0)
        while True:
            try:
                self._server = socket.create_server((host, port),
                                                    backlog=16)
                break
            except OSError:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.1)
        self._alive = True
        self._listener = threading.Thread(target=self._listen, daemon=True)
        self._listener.start()

    # -- server side ---------------------------------------------------------
    def _listen(self):
        while self._alive:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return
            if self._nodelay:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_entry, args=(conn,),
                             daemon=True).start()

    def _serve_entry(self, conn: socket.socket) -> None:
        """Per-connection thread: TLS-wrap (when configured), then hand
        off to the framing's read loop. A failed handshake — plaintext
        client against a TLS server, or an untrusted certificate — only
        rejects THIS connection; the listener keeps serving."""
        if self._srv_ctx is not None:
            try:
                # bound the handshake so a silent client can't wedge
                # this thread forever; restore blocking mode after
                conn.settimeout(min(self._timeout, 30.0))
                conn = self._srv_ctx.wrap_socket(conn, server_side=True)
                conn.settimeout(None)
            except (OSError, ssl.SSLError):
                try:
                    conn.close()
                except OSError:
                    pass
                return
        # track the accepted socket so close() can tear it down: an
        # agent that exits (or restarts, freeing its port for the
        # respawn to rebind) must not leave inbound connections open
        with self._in_lock:
            self._in.add(conn)
        try:
            self._serve_conn(conn)
        finally:
            with self._in_lock:
                self._in.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _serve_conn(self, conn: socket.socket) -> None:
        raise NotImplementedError

    def _deliver(self, msg: Message) -> None:
        with self._cv:
            self._pending.setdefault((msg.sender, msg.tag),
                                     []).append(msg)
            self._cv.notify_all()

    def _mark_down(self, sender: Optional[str]) -> None:
        """A connection from ``sender`` died with bytes outstanding:
        nothing further will be delivered — wake waiters so they error
        instead of hanging out the timeout."""
        if sender is not None and self._alive:
            with self._cv:
                self._down.add(sender)
                self._cv.notify_all()

    # -- client side ---------------------------------------------------------
    def _greet(self, conn: socket.socket) -> None:
        raise NotImplementedError

    def _conn_to(self, to: str) -> socket.socket:
        if to not in self._out:
            # peers boot independently (one process per agent): retry
            # refused connects until the peer's listener is up, bounded
            # by the configured timeout. Exponential backoff with
            # jitter, not a fixed busy-loop — a rejoin storm of agents
            # reconnecting to a peer that stays down for seconds must
            # not hammer it 20x/s each, and the jitter de-synchronizes
            # the herd.
            deadline = time.monotonic() + self._timeout
            delay, attempts = 0.05, 0
            while True:
                try:
                    conn = socket.create_connection(
                        self._addr[to], timeout=self._timeout)
                    break
                except ConnectionRefusedError as e:
                    attempts += 1
                    now = time.monotonic()
                    if now >= deadline:
                        raise ConnectionError(
                            f"{self.me}: could not connect to {to!r} at "
                            f"{self._addr[to]} within {self._timeout}s "
                            f"({attempts} attempts): {e}") from e
                    # full jitter in [delay/2, delay], capped to both
                    # the growth ceiling and the remaining deadline
                    time.sleep(min(delay * (0.5 + 0.5 * random.random()),
                                   max(deadline - now, 0.0)))
                    delay = min(delay * 2.0, 2.0)
            if self._nodelay:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self._cli_ctx is not None:
                # handshake failures do NOT retry: a reachable peer that
                # rejects our certificate (or presents an untrusted one)
                # stays rejected — surface it immediately, attributed
                sni = self._tls.server_hostname or self._addr[to][0]
                try:
                    conn = self._cli_ctx.wrap_socket(
                        conn, server_hostname=sni)
                except (OSError, ssl.SSLError) as e:
                    try:
                        conn.close()
                    except OSError:
                        pass
                    raise ConnectionError(
                        f"{self.me}: TLS handshake with {to!r} at "
                        f"{self._addr[to]} failed: {e}") from e
            self._greet(conn)
            self._out[to] = conn
        return self._out[to]

    def _write_frames(self, recipient: str, *bufs: bytes) -> None:
        """Write buffers to ``recipient``; on any error drop the
        connection so no later write can corrupt the peer's framing."""
        conn = self._conn_to(recipient)
        try:
            for b in bufs:
                conn.sendall(b)
        except BaseException:
            self._out.pop(recipient, None)
            try:
                conn.close()
            except OSError:
                pass
            raise

    # -- receive side --------------------------------------------------------
    def _recv_any(self, frm: str, tags: Sequence[str],
                  timeout: Optional[float] = None) -> Message:
        timeout = self._timeout if timeout is None else timeout
        keys = [(frm, t) for t in tags]

        def ready():
            return any(self._pending.get(k) for k in keys) \
                or frm in self._down

        with self._cv:
            ok = self._cv.wait_for(ready, timeout=timeout)
            for k in keys:
                lst = self._pending.get(k)
                if lst:
                    msg = lst.pop(0)
                    if not lst:     # delete drained stepped-tag entries
                        del self._pending[k]
                    return msg
            if frm in self._down:
                raise ConnectionError(
                    f"{self.me}: connection from {frm!r} dropped "
                    f"mid-frame with no message {list(tags)} pending")
            if not ok:
                raise TimeoutError(f"{self.me}: no message "
                                   f"{frm}/{list(tags)}")
            raise AssertionError("unreachable")   # pragma: no cover

    def _peek(self, frm: str, tags: Sequence[str]) -> bool:
        with self._cv:
            return any(self._pending.get((frm, t)) for t in tags)

    def suspects(self) -> Set[str]:
        with self._cv:
            down = set(self._down)
        return down | super().suspects()

    def reset_peer(self, peer: str,
                   keep_tags: Sequence[str] = ()) -> None:
        """Forget one peer entirely so its restarted process can
        re-handshake: clear the sticky send error and down-mark, close
        the cached outbound socket (the next send reconnects to the new
        listener), and drop undelivered inbound messages except
        control-plane tags (``keep_tags`` prefixes) a rejoiner's hello
        may already ride on."""
        with self._send_lock:
            self._send_errs.pop(peer, None)
            if self._suspect == peer:
                self._suspect = None
        out = self._out.pop(peer, None)
        if out is not None:
            try:
                out.close()
            except OSError:
                pass
        with self._cv:
            self._down.discard(peer)
            for key in list(self._pending):
                if key[0] == peer and not any(
                        key[1].startswith(k) for k in keep_tags):
                    del self._pending[key]

    def close(self) -> None:
        super().close()                  # drain + stop the sender thread
        self._alive = False
        try:
            # shutdown() before close(): the listener thread is blocked
            # in accept(), which (on Linux) pins the kernel socket — a
            # bare close() would leave the port in LISTEN until that
            # accept returned, so a restarted agent could never rebind
            self._server.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._server.close()
        except OSError:
            pass
        self._listener.join(timeout=5)
        for c in self._out.values():
            try:
                c.close()
            except OSError:
                pass
        with self._in_lock:
            pending_in = list(self._in)
        for c in pending_in:
            try:
                c.close()
            except OSError:
                pass


class SocketCommunicator(_TcpCommunicator):
    """Length-prefix framing: each message is an 8-byte little-endian
    length followed by the safetensors blob; a connection opens with a
    hello frame naming the connecting agent (so even a drop during the
    peer's FIRST data frame is attributable).

    Example::

        addrs = local_addresses(["master", "member0"])
        cm = SocketCommunicator("master", addrs)
        # ... on the other host/thread/process:
        c0 = SocketCommunicator("member0", addrs)
        c0.send("master", "hello", {"x": np.zeros(3)})
        msg = cm.recv("member0", "hello")
    """

    def _serve_conn(self, conn: socket.socket):
        sender: Optional[str] = None
        mid_frame = False
        try:
            # connection hello: the first frame is the peer's agent id
            (n,) = struct.unpack("<Q", _recv_exact(conn, 8))
            sender = _recv_exact(conn, n).decode()
            while True:
                mid_frame = False
                (n,) = struct.unpack("<Q", _recv_exact(conn, 8))
                mid_frame = True
                raw = _recv_exact(conn, n)
                payload, meta = codec.decode(raw)
                sender = meta.pop("sender", sender)
                tag = meta.pop("tag")
                self._deliver(Message(sender, self.me, tag, payload,
                                      meta))
        except (ConnectionError, OSError) as e:
            # a clean close lands exactly between frames; a drop with
            # bytes outstanding (inside the body — mid_frame — or even
            # inside the next length prefix, _MidFrameClose) means the
            # peer died with a message on the wire. strict_eof (elastic
            # clusters) treats even the clean close as a drop: a
            # SIGKILL'd peer's kernel closes its sockets tidily.
            if mid_frame or isinstance(e, _MidFrameClose) \
                    or (self._strict_eof and sender is not None):
                self._mark_down(sender)
            return

    def _greet(self, conn: socket.socket) -> None:
        me = self.me.encode()
        conn.sendall(struct.pack("<Q", len(me)) + me)   # hello

    def _send(self, msg: Message, raw: bytes) -> None:
        prefix = struct.pack("<Q", len(raw))
        if len(raw) <= _INLINE_FRAME_BYTES:
            self._write_frames(msg.recipient, prefix + raw)
        else:
            self._write_frames(msg.recipient, prefix, raw)


def local_addresses(world: Sequence[str], base_port: int = 0
                    ) -> Dict[str, Tuple[str, int]]:
    """Allocate loopback addresses with OS-assigned free ports."""
    addrs: Dict[str, Tuple[str, int]] = {}
    for w in world:
        s = socket.socket()
        s.bind(("127.0.0.1", base_port))
        addrs[w] = ("127.0.0.1", s.getsockname()[1])
        s.close()
    return addrs
