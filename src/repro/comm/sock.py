"""Distributed TCP communicator — the offline stand-in for the paper's
gRPC transport (gRPC adds framing/auth on top of the same safetensors
payloads; semantics are identical for protocol purposes).

Every agent runs a listener thread; messages are length-prefixed
safetensors blobs. Agents connect lazily and reuse sockets. Works across
hosts; in tests everything binds to 127.0.0.1.
"""
from __future__ import annotations

import socket
import struct
import threading
from collections import defaultdict
from typing import Dict, Sequence, Tuple

from repro.comm import codec
from repro.comm.base import Message, PartyCommunicator


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = conn.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("socket closed")
        buf += chunk
    return buf


class SocketCommunicator(PartyCommunicator):
    def __init__(self, me: str, addresses: Dict[str, Tuple[str, int]]):
        """addresses: agent id -> (host, port) for EVERY agent."""
        super().__init__(me, list(addresses))
        self._addr = dict(addresses)
        self._pending: Dict[Tuple[str, str], list] = defaultdict(list)
        self._inbox: "list" = []
        self._cv = threading.Condition()
        self._out: Dict[str, socket.socket] = {}
        self._timeout = 120.0
        host, port = self._addr[me]
        self._server = socket.create_server((host, port), backlog=16)
        self._alive = True
        self._listener = threading.Thread(target=self._listen, daemon=True)
        self._listener.start()

    # -- server side ---------------------------------------------------------
    def _listen(self):
        while self._alive:
            try:
                conn, _ = self._server.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        try:
            while True:
                (n,) = struct.unpack("<Q", _recv_exact(conn, 8))
                raw = _recv_exact(conn, n)
                payload, meta = codec.decode(raw)
                sender = meta.pop("sender")
                tag = meta.pop("tag")
                msg = Message(sender, self.me, tag, payload, meta)
                with self._cv:
                    self._pending[(sender, tag)].append(msg)
                    self._cv.notify_all()
        except (ConnectionError, OSError):
            return

    # -- client side ---------------------------------------------------------
    def _conn_to(self, to: str) -> socket.socket:
        if to not in self._out:
            self._out[to] = socket.create_connection(self._addr[to],
                                                     timeout=self._timeout)
        return self._out[to]

    def _send(self, msg: Message, raw: bytes) -> None:
        conn = self._conn_to(msg.recipient)
        conn.sendall(struct.pack("<Q", len(raw)) + raw)

    def _recv(self, frm: str, tag: str) -> Message:
        key = (frm, tag)
        with self._cv:
            ok = self._cv.wait_for(lambda: bool(self._pending[key]),
                                   timeout=self._timeout)
            if not ok:
                raise TimeoutError(f"{self.me}: no message {key}")
            return self._pending[key].pop(0)

    def close(self) -> None:
        self._alive = False
        try:
            self._server.close()
        except OSError:
            pass
        for c in self._out.values():
            try:
                c.close()
            except OSError:
                pass


def local_addresses(world: Sequence[str], base_port: int = 0
                    ) -> Dict[str, Tuple[str, int]]:
    """Allocate loopback addresses with OS-assigned free ports."""
    addrs: Dict[str, Tuple[str, int]] = {}
    for w in world:
        s = socket.socket()
        s.bind(("127.0.0.1", base_port))
        addrs[w] = ("127.0.0.1", s.getsockname()[1])
        s.close()
    return addrs
