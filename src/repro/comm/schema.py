"""Typed message schema for the VFL wire protocol.

Replaces stringly-typed tags (``f"logreg/z/{step}"``) and ad-hoc
``meta`` string dicts with a declared registry: every message type names
its payload fields (dtype / rank / width constraints) once, and a
:class:`TypedChannel` stamps sequence numbers onto stepped tags
automatically — protocol code says ``ch.send("linreg/z", {...})`` and
never hand-threads a step counter again.

Validation runs on BOTH ends: the sender can't emit a payload that
doesn't match the declaration (catches producer bugs at the source) and
the receiver re-checks after decode (catches version/key-size skew
between parties — e.g. a peer framing Paillier ciphertexts with a
different key width is rejected before it decodes to garbage).

Wire compatibility: a stepped message named ``linreg/z`` with sequence
number 7 rides the existing transports under the tag ``linreg/z/7`` —
the same tag the hand-rolled protocols produced, so per-tag byte
accounting and captured traces stay comparable across the redesign.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.comm.base import Message, PartyCommunicator, Payload


class SchemaError(ValueError):
    """A message violated its declared schema."""


@dataclass(frozen=True)
class Field:
    """Constraint on one payload tensor.

    ``dtype``: numpy dtype name ("float64", "uint8", ...), "bytes" for
    fixed-width byte strings (kind 'S'), or None for any.
    ``ndim``: required rank, or None.
    ``width_meta``: name of a metadata key that declares the trailing
    dim (big-int rows: ciphertexts, blinded PSI points); when the key is
    present the tensor's last axis must match it exactly.
    """

    dtype: Optional[str] = None
    ndim: Optional[int] = None
    width_meta: Optional[str] = None


@dataclass(frozen=True)
class MsgType:
    name: str
    fields: Optional[Mapping[str, Field]]   # None = free-form payload
    stepped: bool = False
    doc: str = ""


MESSAGES: Dict[str, MsgType] = {}


def message(name: str, fields: Optional[Mapping[str, Field]] = None,
            stepped: bool = False, doc: str = "") -> MsgType:
    """Declare (or idempotently re-declare) a message type."""
    mt = MsgType(name, dict(fields) if fields is not None else None,
                 stepped, doc)
    prev = MESSAGES.get(name)
    if prev is not None and (prev.fields, prev.stepped) != (mt.fields,
                                                            mt.stepped):
        raise SchemaError(f"conflicting redeclaration of {name!r}")
    MESSAGES[name] = mt
    return mt


def _check(mt: MsgType, payload: Payload, meta: Mapping[str, str],
           end: str) -> None:
    if mt.fields is None:
        return
    missing = set(mt.fields) - set(payload)
    extra = set(payload) - set(mt.fields)
    if missing or extra:
        raise SchemaError(
            f"{mt.name} ({end}): payload fields {sorted(payload)} != "
            f"declared {sorted(mt.fields)}")
    for fname, f in mt.fields.items():
        arr = np.asarray(payload[fname])
        if f.dtype == "bytes":
            if arr.dtype.kind != "S":
                raise SchemaError(f"{mt.name}.{fname} ({end}): dtype "
                                  f"{arr.dtype} is not a byte string")
        elif f.dtype is not None and arr.dtype != np.dtype(f.dtype):
            raise SchemaError(f"{mt.name}.{fname} ({end}): dtype "
                              f"{arr.dtype} != declared {f.dtype}")
        if f.ndim is not None and arr.ndim != f.ndim:
            raise SchemaError(f"{mt.name}.{fname} ({end}): rank "
                              f"{arr.ndim} != declared {f.ndim}")
        if f.width_meta is not None and f.width_meta in meta:
            want = int(meta[f.width_meta])
            if arr.ndim == 0 or arr.shape[-1] != want:
                raise SchemaError(
                    f"{mt.name}.{fname} ({end}): width "
                    f"{arr.shape[-1] if arr.ndim else 0} != declared "
                    f"{want} (key-size mismatch between parties?)")


def lookup(name: str) -> MsgType:
    mt = MESSAGES.get(name)
    if mt is None:
        raise SchemaError(f"unregistered message type {name!r}")
    return mt


class TypedChannel:
    """Schema-enforcing facade over a :class:`PartyCommunicator`.

    Sequence numbers for stepped message types are kept per
    (peer, message-type) pair and advanced automatically on every
    send/recv, so both ends stay in lock-step without protocol code
    ever formatting a tag.
    """

    def __init__(self, comm: PartyCommunicator):
        self.comm = comm
        self._send_seq: Dict[tuple, int] = defaultdict(int)
        self._recv_seq: Dict[tuple, int] = defaultdict(int)

    # mirror the communicator's identity surface so match/protocol code
    # can treat a TypedChannel as "the comm with types"
    @property
    def me(self) -> str:
        return self.comm.me

    @property
    def world(self) -> List[str]:
        return self.comm.world

    @property
    def members(self) -> List[str]:
        return self.comm.members

    @property
    def stats(self):
        return self.comm.stats

    def _wire_tag(self, mt: MsgType, seq: int) -> str:
        return f"{mt.name}/{seq}" if mt.stepped else mt.name

    def send(self, to: str, name: str, payload: Payload,
             meta: Optional[Dict[str, str]] = None) -> None:
        mt = lookup(name)
        _check(mt, payload, meta or {}, "send")
        seq = self._send_seq[(to, name)]
        if mt.stepped:
            self._send_seq[(to, name)] = seq + 1
        self.comm.send(to, self._wire_tag(mt, seq), payload, meta=meta)

    def recv(self, frm: str, name: str) -> Message:
        mt = lookup(name)
        seq = self._recv_seq[(frm, name)]
        msg = self.comm.recv(frm, self._wire_tag(mt, seq))
        # advance only after the transport delivered: a timed-out recv
        # must be retryable without skipping a sequence number
        if mt.stepped:
            self._recv_seq[(frm, name)] = seq + 1
        _check(mt, msg.payload, msg.meta, "recv")
        return msg

    def broadcast(self, name: str, payload: Payload,
                  targets: Optional[Sequence[str]] = None,
                  meta: Optional[Dict[str, str]] = None) -> None:
        for t in (targets if targets is not None else self.world):
            if t != self.me:
                self.send(t, name, payload, meta=meta)

    def gather(self, frm: Sequence[str], name: str) -> List[Message]:
        return [self.recv(f, name) for f in frm]
