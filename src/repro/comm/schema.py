"""Typed message schema for the VFL wire protocol.

Replaces stringly-typed tags (``f"logreg/z/{step}"``) and ad-hoc
``meta`` string dicts with a declared registry: every message type names
its payload fields (dtype / rank / width constraints) once, and a
:class:`TypedChannel` stamps sequence numbers onto stepped tags
automatically — protocol code says ``ch.send("linreg/z", {...})`` and
never hand-threads a step counter again.

Validation runs on BOTH ends: the sender can't emit a payload that
doesn't match the declaration (catches producer bugs at the source) and
the receiver re-checks after decode (catches version/key-size skew
between parties — e.g. a peer framing Paillier ciphertexts with a
different key width is rejected before it decodes to garbage).

Stream awareness (DESIGN.md §7): a channel is the (peer, message-type)
pair. Receives are addressed by sequence number, and anything that
arrives early — a later frame racing a bare message, sub-messages of a
coalesced frame — is parked in a per-channel reorder buffer and
delivered in order. ``ch.frame(to)`` coalesces every send inside the
``with`` block into ONE wire message (one length prefix, one syscall,
one packet for small control rounds); the receiving channel unpacks it
transparently. Declaring a message with ``compress=True`` lets the
channel quantize its float payloads to int8 (+per-column scale) with
error feedback when the channel was built with ``compress=True`` —
protocols opt in per message type; HE ciphertext channels simply never
declare it.

Wire compatibility: a stepped message named ``linreg/z`` with sequence
number 7 rides the existing transports under the tag ``linreg/z/7`` —
the same tag the hand-rolled protocols produced, so per-tag byte
accounting and captured traces stay comparable across the redesign.
"""
from __future__ import annotations

import json
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.comm.base import (Message, PartyCommunicator, Payload,
                             RecvFuture, SendFuture)


class SchemaError(ValueError):
    """A message violated its declared schema."""


@dataclass(frozen=True)
class Field:
    """Constraint on one payload tensor.

    ``dtype``: numpy dtype name ("float64", "uint8", ...), "bytes" for
    fixed-width byte strings (kind 'S'), or None for any.
    ``ndim``: required rank, or None.
    ``width_meta``: name of a metadata key that declares the trailing
    dim (big-int rows: ciphertexts, blinded PSI points); when the key is
    present the tensor's last axis must match it exactly.
    """

    dtype: Optional[str] = None
    ndim: Optional[int] = None
    width_meta: Optional[str] = None


@dataclass(frozen=True)
class MsgType:
    name: str
    fields: Optional[Mapping[str, Field]]   # None = free-form payload
    stepped: bool = False
    compress: bool = False
    doc: str = ""


MESSAGES: Dict[str, MsgType] = {}

# channel-internal meta keys (never user-set)
_COMP_META = "comp"            # json: [[field, orig_dtype], ...]
_FRAME_META = "frame"          # json: [[name, seq, fields, meta], ...]
_FRAME_TYPE = "frame"          # wire tag prefix for coalesced frames


def message(name: str, fields: Optional[Mapping[str, Field]] = None,
            stepped: bool = False, compress: bool = False,
            doc: str = "") -> MsgType:
    """Declare (or idempotently re-declare) a message type.

    ``fields`` maps payload tensor names to :class:`Field` constraints
    (None = free-form payload); ``stepped`` auto-threads a sequence
    number per (peer, type) channel; ``compress`` opts the type's float
    payloads into int8 error-feedback compression on compressing
    channels (HE ciphertext types simply never declare it).

    Example::

        schema.message("linreg/z", {"z": Field("float64", 2)},
                       stepped=True,
                       doc="member partial predictions, one per step")
        ch.send("master", "linreg/z", {"z": zb})   # no step threading
    """
    mt = MsgType(name, dict(fields) if fields is not None else None,
                 stepped, compress, doc)
    prev = MESSAGES.get(name)
    if prev is not None and \
            (prev.fields, prev.stepped, prev.compress) != \
            (mt.fields, mt.stepped, mt.compress):
        raise SchemaError(f"conflicting redeclaration of {name!r}")
    MESSAGES[name] = mt
    return mt


def _check(mt: MsgType, payload: Payload, meta: Mapping[str, str],
           end: str) -> None:
    if mt.fields is None:
        return
    missing = set(mt.fields) - set(payload)
    extra = set(payload) - set(mt.fields)
    if missing or extra:
        raise SchemaError(
            f"{mt.name} ({end}): payload fields {sorted(payload)} != "
            f"declared {sorted(mt.fields)}")
    for fname, f in mt.fields.items():
        arr = np.asarray(payload[fname])
        if f.dtype == "bytes":
            if arr.dtype.kind != "S":
                raise SchemaError(f"{mt.name}.{fname} ({end}): dtype "
                                  f"{arr.dtype} is not a byte string")
        elif f.dtype is not None and arr.dtype != np.dtype(f.dtype):
            raise SchemaError(f"{mt.name}.{fname} ({end}): dtype "
                              f"{arr.dtype} != declared {f.dtype}")
        if f.ndim is not None and arr.ndim != f.ndim:
            raise SchemaError(f"{mt.name}.{fname} ({end}): rank "
                              f"{arr.ndim} != declared {f.ndim}")
        if f.width_meta is not None and f.width_meta in meta:
            want = int(meta[f.width_meta])
            if arr.ndim == 0 or arr.shape[-1] != want:
                raise SchemaError(
                    f"{mt.name}.{fname} ({end}): width "
                    f"{arr.shape[-1] if arr.ndim else 0} != declared "
                    f"{want} (key-size mismatch between parties?)")


def lookup(name: str) -> MsgType:
    mt = MESSAGES.get(name)
    if mt is None:
        raise SchemaError(f"unregistered message type {name!r}")
    return mt


class _FrameBuffer:
    """Sends buffered inside a ``ch.frame(to)`` block."""

    __slots__ = ("to", "parts")

    def __init__(self, to: str):
        self.to = to
        self.parts: List[Tuple[str, int, Payload, Dict[str, str]]] = []


class TypedChannel:
    """Schema-enforcing facade over a :class:`PartyCommunicator`.

    Sequence numbers for stepped message types are kept per
    (peer, message-type) pair and advanced automatically on every
    send/recv, so both ends stay in lock-step without protocol code
    ever formatting a tag. Out-of-order arrivals (frames racing bare
    messages) are reordered per channel before delivery.

    Example::

        ch = TypedChannel(comm, compress=cfg.compress)
        with ch.frame("member0"):          # one wire message
            ch.send("member0", "ctrl/step", step_payload)
            ch.send("member0", "predict/rows", {"rows": rows})
        msg = ch.recv("member0", "splitnn/pred_u")
    """

    def __init__(self, comm: PartyCommunicator, compress: bool = False):
        self.comm = comm
        self.compress = compress
        self._send_seq: Dict[tuple, int] = defaultdict(int)
        self._recv_seq: Dict[tuple, int] = defaultdict(int)
        # (frm, name) -> {seq or None: [Message, ...]} delivered early;
        # inner keys are deleted once drained (a long fit would
        # otherwise leak one entry per step per channel)
        self._reorder: Dict[tuple, Dict[Optional[int], list]] = \
            defaultdict(dict)
        self._frame_send_seq: Dict[str, int] = defaultdict(int)
        self._frame_recv_seq: Dict[str, int] = defaultdict(int)
        self._framing: Optional[_FrameBuffer] = None
        self.error_feedback = None       # lazily built ErrorFeedback
        # elastic / straggler machinery — inert until the driver arms
        # it. ``elastic_roles``: peers whose crashes are recoverable
        # (their ConnectionErrors are converted into down-marks +
        # stale substitution instead of propagating). ``down``: peers
        # currently skipped — sends are dropped, gathers substitute the
        # last delivered message. ``round_deadline``: per-round gather
        # bound; a member that misses it is a straggler and its stale
        # contribution is used (bounded-staleness semantics).
        self.down: set = set()
        self.elastic_roles: set = set()
        self.round_deadline: Optional[float] = None
        self._last_msg: Dict[tuple, Message] = {}
        self._stale_futs: Dict[tuple, list] = {}
        # adversarial exchange capture (docs/privacy.md): the driver
        # installs an ExchangeCapture here when cfg.capture_exchanges
        # is on. None (the default) keeps every hot path at a single
        # is-None check — capture-off runs are bit-identical (tested).
        self.capture = None

    # mirror the communicator's identity surface so match/protocol code
    # can treat a TypedChannel as "the comm with types"
    @property
    def me(self) -> str:
        return self.comm.me

    @property
    def world(self) -> List[str]:
        return self.comm.world

    @property
    def members(self) -> List[str]:
        return self.comm.members

    @property
    def stats(self):
        return self.comm.stats

    def _wire_tag(self, mt: MsgType, seq: int) -> str:
        return f"{mt.name}/{seq}" if mt.stepped else mt.name

    # -- compression ---------------------------------------------------------
    def _compress_payload(self, mt: MsgType, payload: Payload,
                          meta: Dict[str, str], to: str
                          ) -> Tuple[Payload, Dict[str, str]]:
        from repro.core import compression
        if self.error_feedback is None:
            self.error_feedback = compression.ErrorFeedback()
        out: Payload = {}
        comp: List[List[str]] = []
        for k, v in payload.items():
            arr = np.asarray(v)
            if arr.dtype.kind == "f" and arr.ndim >= 1 and arr.size:
                q, scale = self.error_feedback.compress(
                    f"{to}/{mt.name}/{k}", arr.astype(np.float32))
                out[f"{k}.q"] = q
                out[f"{k}.scale"] = scale
                comp.append([k, arr.dtype.name])
            else:
                out[k] = arr
        if comp:
            meta = dict(meta)
            meta[_COMP_META] = json.dumps(comp)
        return out, meta

    @staticmethod
    def _decompress(msg: Message) -> Message:
        from repro.core import compression
        spec = msg.meta.pop(_COMP_META, None)
        if spec is None:
            return msg
        payload = dict(msg.payload)
        for k, dtype in json.loads(spec):
            q = payload.pop(f"{k}.q")
            scale = payload.pop(f"{k}.scale")
            payload[k] = compression.dequantize_int8(q, scale) \
                .astype(dtype)
        msg.payload = payload
        return msg

    # -- send side -----------------------------------------------------------
    def _prepare(self, to: str, name: str, payload: Payload,
                 meta: Optional[Dict[str, str]]
                 ) -> Tuple[MsgType, int, Payload, Dict[str, str]]:
        mt = lookup(name)
        payload = {k: np.asarray(v) for k, v in payload.items()}
        meta = dict(meta or {})
        _check(mt, payload, meta, "send")
        if self.compress and mt.compress:
            payload, meta = self._compress_payload(mt, payload, meta, to)
        seq = self._send_seq[(to, name)]
        if mt.stepped:
            self._send_seq[(to, name)] = seq + 1
        return mt, seq, payload, meta

    def send(self, to: str, name: str, payload: Payload,
             meta: Optional[Dict[str, str]] = None) -> None:
        if to in self.down:
            return          # dropped before seq/EF advance: the peer's
        #                     whole channel state resets at rejoin
        if self.capture is not None:
            # pre-_prepare: the plaintext this party emits, before
            # compression/masking bookkeeping mutates the payload
            self.capture.record("send", to, name, payload)
        try:
            mt, seq, payload, meta = self._prepare(to, name, payload,
                                                   meta)
            if self._framing is not None and self._framing.to == to:
                self._framing.parts.append((name, seq, payload, meta))
                return
            self.comm.send(to, self._wire_tag(mt, seq), payload,
                           meta=meta)
        except ConnectionError:
            if to not in self.elastic_roles:
                raise
            self.down.add(to)

    def isend(self, to: str, name: str, payload: Payload,
              meta: Optional[Dict[str, str]] = None
              ) -> Optional[SendFuture]:
        """Non-blocking typed send; returns the transport future (or
        None when buffered into an open frame)."""
        if to in self.down:
            return None
        if self.capture is not None:
            self.capture.record("send", to, name, payload)
        try:
            mt, seq, payload, meta = self._prepare(to, name, payload,
                                                   meta)
            if self._framing is not None and self._framing.to == to:
                self._framing.parts.append((name, seq, payload, meta))
                return None
            return self.comm.isend(to, self._wire_tag(mt, seq), payload,
                                   meta=meta)
        except ConnectionError:
            if to not in self.elastic_roles:
                raise
            self.down.add(to)
            return None

    def frame(self, to: str, wait: bool = True) -> "_FrameContext":
        """Coalesce every send to ``to`` inside the block into one wire
        message (single prefix+body buffer; one packet for small
        control rounds). Sends to other peers pass through unchanged."""
        return _FrameContext(self, to, wait)

    def _flush_frame(self, fb: _FrameBuffer, wait: bool) -> None:
        if not fb.parts:
            return
        if len(fb.parts) == 1:           # no coalescing win: send bare
            name, seq, payload, meta = fb.parts[0]
            tag = self._wire_tag(lookup(name), seq)
            if wait:
                self.comm.send(fb.to, tag, payload, meta=meta)
            else:
                self.comm.isend(fb.to, tag, payload, meta=meta)
            return
        merged: Payload = {}
        spec = []
        for i, (name, seq, payload, meta) in enumerate(fb.parts):
            for k, v in payload.items():
                merged[f"{i}.{k}"] = v
            spec.append([name, seq, sorted(payload), meta])
        fseq = self._frame_send_seq[fb.to]
        self._frame_send_seq[fb.to] = fseq + 1
        tag = f"{_FRAME_TYPE}/{fseq}"
        meta = {_FRAME_META: json.dumps(spec)}
        if wait:
            self.comm.send(fb.to, tag, merged, meta=meta)
        else:
            self.comm.isend(fb.to, tag, merged, meta=meta)

    def flush(self, timeout: Optional[float] = None) -> None:
        """Wait until every queued async send hit the wire."""
        self.comm.flush_sends(timeout)

    # -- recv side -----------------------------------------------------------
    def _unpack_frame(self, frm: str, msg: Message) -> None:
        spec = json.loads(msg.meta[_FRAME_META])
        for i, (name, seq, fields, meta) in enumerate(spec):
            payload = {k: msg.payload[f"{i}.{k}"] for k in fields}
            sub = Message(frm, self.comm.me,
                          self._wire_tag(lookup(name), seq),
                          payload, dict(meta))
            mt = lookup(name)
            key = seq if mt.stepped else None
            self._reorder[(frm, name)].setdefault(key, []).append(sub)

    def _pull(self, frm: str, mt: MsgType, seq: int,
              timeout: Optional[float] = None) -> Message:
        """Deliver (frm, mt, seq): from the reorder buffer if it arrived
        early (inside a frame), else from the transport — unpacking any
        interleaved frames along the way."""
        key = seq if mt.stepped else None
        buf = self._reorder[(frm, mt.name)]
        while True:
            lst = buf.get(key)
            if lst:
                msg = lst.pop(0)
                if not lst:
                    del buf[key]
                return self._decompress(msg)
            tags = (self._wire_tag(mt, seq),
                    f"{_FRAME_TYPE}/{self._frame_recv_seq[frm]}")
            msg = self.comm.recv_any(frm, tags, timeout)
            if msg.tag == tags[1]:
                self._frame_recv_seq[frm] += 1
                self._unpack_frame(frm, msg)
                continue
            return self._decompress(msg)

    def recv(self, frm: str, name: str,
             timeout: Optional[float] = None) -> Message:
        mt = lookup(name)
        seq = self._recv_seq[(frm, name)]
        msg = self._pull(frm, mt, seq, timeout)
        # advance only after the transport delivered: a timed-out recv
        # must be retryable without skipping a sequence number
        if mt.stepped:
            self._recv_seq[(frm, name)] = seq + 1
        _check(mt, msg.payload, msg.meta, "recv")
        if self.capture is not None:
            # post-decompress/post-check: exactly the plaintext this
            # party observes (so e.g. int8 quantization error is part
            # of what a captured-exchange adversary sees)
            self.capture.record("recv", frm, name, msg.payload)
        return msg

    def irecv(self, frm: str, name: str) -> RecvFuture:
        """Deferred typed receive. The returned future owns this
        channel position (the sequence number advances now); resolve it
        from the agent's own thread."""
        mt = lookup(name)
        seq = self._recv_seq[(frm, name)]
        if mt.stepped:
            self._recv_seq[(frm, name)] = seq + 1

        def _resolve(timeout: Optional[float]) -> Message:
            msg = self._pull(frm, mt, seq, timeout)
            _check(mt, msg.payload, msg.meta, "recv")
            if self.capture is not None:
                self.capture.record("recv", frm, name, msg.payload)
            return msg

        def _peek() -> bool:
            key = seq if mt.stepped else None
            return bool(self._reorder[(frm, mt.name)].get(key)) or \
                self.comm._peek(frm, (self._wire_tag(mt, seq),))

        return RecvFuture(_resolve, _peek)

    def recv_parts(self, frm: str, name: str,
                   timeout: Optional[float] = None):
        """Receive one logically streamed payload sent as N consecutive
        chunk messages of the same stepped type (DESIGN.md §10.2): the
        first chunk's ``meta["parts"]`` declares the stream length
        (absent = a plain single message). Yields each chunk as it
        arrives — sequence numbering already orders the stream — so the
        consumer overlaps its per-chunk work (e.g. ciphertext
        decryption) with later chunks still on the wire."""
        first = self.recv(frm, name, timeout=timeout)
        yield first
        for _ in range(int(first.meta.get("parts", "1")) - 1):
            yield self.recv(frm, name, timeout=timeout)

    # -- collectives ---------------------------------------------------------
    def broadcast(self, name: str, payload: Payload,
                  targets: Optional[Sequence[str]] = None,
                  meta: Optional[Dict[str, str]] = None,
                  wait: bool = True) -> List[SendFuture]:
        futs = []
        for t in (targets if targets is not None else self.world):
            if t == self.me:
                continue
            if wait:
                self.send(t, name, payload, meta=meta)
            else:
                f = self.isend(t, name, payload, meta=meta)
                if f is not None:
                    futs.append(f)
        return futs

    def gather(self, frm: Sequence[str], name: str,
               timeout: Optional[float] = None,
               stale_ok: bool = False) -> List[Message]:
        """Collect one message per peer. Plain behavior (no deadline,
        no elastic roles armed) is the classic blocking gather.

        With ``self.round_deadline`` set (or an explicit ``timeout`` +
        ``stale_ok``), a peer that misses the deadline is recorded as a
        straggler and its LAST delivered message is substituted — the
        bounded-staleness contribution; its late message is drained
        opportunistically on a later gather. A peer whose connection
        dropped (and is in ``elastic_roles``) is marked down and
        likewise substituted until it rejoins."""
        if timeout is None and self.round_deadline is not None:
            timeout, stale_ok = self.round_deadline, True
        self._drain_stale()
        pairs = [(f, None if f in self.down else self.irecv(f, name))
                 for f in frm]
        out = []
        for f, fut in pairs:
            msg = None
            if fut is not None:
                try:
                    msg = fut.result(
                        self.comm._timeout if timeout is None
                        else timeout)
                except ConnectionError:
                    if f not in self.elastic_roles:
                        raise
                    self.down.add(f)
                    self._stale_futs.setdefault((f, name),
                                                []).append(fut)
                except TimeoutError:
                    if not stale_ok:
                        raise
                    if (f, name) in self._last_msg:
                        self.stats.record_straggle(f)
                        self._stale_futs.setdefault((f, name),
                                                    []).append(fut)
                    else:
                        # nothing cached yet (first round, process
                        # cold start): bounded staleness can only
                        # degrade to a contribution that exists, so
                        # wait out the full transport timeout instead
                        msg = fut.result(self.comm._timeout)
            if msg is None:
                msg = self._last_msg.get((f, name))
                if msg is None:
                    raise ConnectionError(
                        f"{self.me}: {f!r} is down with no stale "
                        f"{name!r} contribution cached to substitute")
            elif stale_ok or f in self.elastic_roles:
                self._last_msg[(f, name)] = msg
            out.append(msg)
        return out

    def _drain_stale(self) -> None:
        """Consume stragglers' late messages once they finally arrive
        (their futures own channel positions that must be drained, or
        the transport's pending store grows one entry per straggle)."""
        for key, futs in list(self._stale_futs.items()):
            left = []
            for fut in futs:
                if fut.done():
                    try:
                        self._last_msg[key] = fut.result(0.0)
                    except Exception:        # noqa: BLE001
                        pass
                else:
                    left.append(fut)
            if left:
                self._stale_futs[key] = left
            else:
                del self._stale_futs[key]

    def reset_peer(self, peer: str, keep: Sequence[str] = ()) -> None:
        """Zero all channel state for one peer so a restarted process
        (whose counters start at 0) can re-handshake: sequence numbers,
        reorder buffers, frame counters, stale caches, parked straggler
        futures, and compression error-feedback residuals — except
        message types listed in ``keep``."""
        for d in (self._send_seq, self._recv_seq):
            for key in list(d):
                if key[0] == peer and key[1] not in keep:
                    del d[key]
        for key in list(self._reorder):
            if key[0] == peer and key[1] not in keep:
                del self._reorder[key]
        for store in (self._last_msg, self._stale_futs):
            for key in list(store):
                if key[0] == peer:
                    del store[key]
        self._frame_send_seq.pop(peer, None)
        self._frame_recv_seq.pop(peer, None)
        if self.error_feedback is not None:
            for k in list(self.error_feedback.residuals):
                if k.startswith(f"{peer}/"):
                    del self.error_feedback.residuals[k]


class _FrameContext:
    def __init__(self, ch: TypedChannel, to: str, wait: bool = True):
        self.ch = ch
        self.to = to
        self.wait = wait

    def __enter__(self) -> TypedChannel:
        if self.ch._framing is not None:
            raise SchemaError("nested frame() blocks are not supported")
        self.ch._framing = _FrameBuffer(self.to)
        return self.ch

    def __exit__(self, exc_type, exc, tb) -> None:
        # flush even when the block raised: the buffered sends already
        # consumed their channel sequence numbers in _prepare, so
        # dropping them would desync the peer forever
        fb, self.ch._framing = self.ch._framing, None
        self.ch._flush_frame(fb, self.wait)
