"""In-process thread-based communicator (the paper's local debug mode).

A shared :class:`ThreadBus` holds one mailbox per agent; messages go
through the safetensors codec round-trip anyway so payload sizes and
(de)serialization behaviour match the distributed modes exactly — only
the transport differs. This is what makes "debug in the IDE, deploy on
the cluster" seamless.
"""
from __future__ import annotations

import queue
import threading
from collections import defaultdict
from typing import Dict, Sequence, Tuple

from repro.comm import codec
from repro.comm.base import Message, PartyCommunicator


class ThreadBus:
    def __init__(self, world: Sequence[str]):
        self.world = list(world)
        self._boxes: Dict[str, "queue.Queue[bytes]"] = {
            w: queue.Queue() for w in world}

    def communicator(self, me: str) -> "ThreadCommunicator":
        return ThreadCommunicator(me, self)


class ThreadCommunicator(PartyCommunicator):
    def __init__(self, me: str, bus: ThreadBus):
        super().__init__(me, bus.world)
        self._bus = bus
        self._pending: Dict[Tuple[str, str], list] = defaultdict(list)
        self._timeout = 120.0

    def _send(self, msg: Message, raw: bytes) -> None:
        self._bus._boxes[msg.recipient].put(raw)

    def _recv(self, frm: str, tag: str) -> Message:
        key = (frm, tag)
        while True:
            if self._pending[key]:
                return self._pending[key].pop(0)
            raw = self._bus._boxes[self.me].get(timeout=self._timeout)
            payload, meta = codec.decode(raw)
            sender = meta.pop("sender")
            mtag = meta.pop("tag")
            msg = Message(sender, self.me, mtag, payload, meta)
            if (sender, mtag) == key:
                return msg
            self._pending[(sender, mtag)].append(msg)
