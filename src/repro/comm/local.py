"""In-process thread-based communicator (the paper's local debug mode).

A shared :class:`ThreadBus` holds one mailbox per agent; messages go
through the safetensors codec round-trip anyway so payload sizes and
(de)serialization behaviour match the distributed modes exactly — only
the transport differs. This is what makes "debug in the IDE, deploy on
the cluster" seamless.

Delivery is mailbox-ordered: ``_recv_any`` drains the agent's queue
into per-(sender, tag) pending lists until a wanted tag shows up, so
out-of-order tags (async frames racing data messages) are parked, not
lost. One consumer thread per agent is assumed (the driver model).
"""
from __future__ import annotations

import queue
import time
from typing import Dict, Optional, Sequence, Tuple

from repro.comm import codec
from repro.comm.base import Message, PartyCommunicator


class ThreadBus:
    def __init__(self, world: Sequence[str]):
        self.world = list(world)
        self._boxes: Dict[str, "queue.Queue[bytes]"] = {
            w: queue.Queue() for w in world}

    def communicator(self, me: str, timeout: float = 120.0,
                     comm_cfg=None) -> "ThreadCommunicator":
        return ThreadCommunicator(me, self, timeout=timeout,
                                  comm_cfg=comm_cfg)


class _MailboxCommunicator(PartyCommunicator):
    """Shared drain logic for queue-mailbox transports (thread + mp)."""

    def _box_get(self, timeout: float):
        raise NotImplementedError

    def _decode_one(self, raw: bytes) -> Message:
        payload, meta = codec.decode(raw)
        sender = meta.pop("sender")
        tag = meta.pop("tag")
        return Message(sender, self.me, tag, payload, meta)

    def _pop_pending(self, key) -> Optional[Message]:
        lst = self._pending.get(key)
        if not lst:
            return None
        msg = lst.pop(0)
        if not lst:                 # keyed by stepped tags: delete on
            del self._pending[key]  # drain or a long fit leaks entries
        return msg

    def _recv_any(self, frm: str, tags: Sequence[str],
                  timeout: Optional[float] = None) -> Message:
        timeout = self._timeout if timeout is None else timeout
        keys = [(frm, t) for t in tags]
        deadline = time.monotonic() + timeout
        while True:
            for key in keys:
                msg = self._pop_pending(key)
                if msg is not None:
                    return msg
            left = deadline - time.monotonic()
            if left <= 0:
                raise TimeoutError(f"{self.me}: no message "
                                   f"{frm}/{list(tags)}")
            msg = self._decode_one(self._box_get(left))
            if (msg.sender, msg.tag) in keys:
                return msg
            self._pending.setdefault((msg.sender, msg.tag),
                                     []).append(msg)

    def _peek(self, frm: str, tags: Sequence[str]) -> bool:
        # single-consumer: safe to opportunistically drain the mailbox
        while True:
            try:
                raw = self._box_get(0.0)
            except (queue.Empty, TimeoutError):
                break
            msg = self._decode_one(raw)
            self._pending.setdefault((msg.sender, msg.tag),
                                     []).append(msg)
        return any(self._pending.get((frm, t)) for t in tags)


class ThreadCommunicator(_MailboxCommunicator):
    def __init__(self, me: str, bus: ThreadBus, timeout: float = 120.0,
                 comm_cfg=None):
        super().__init__(me, bus.world, timeout=timeout,
                         comm_cfg=comm_cfg)
        self._bus = bus
        self._pending: Dict[Tuple[str, str], list] = {}

    def _send(self, msg: Message, raw: bytes) -> None:
        self._bus._boxes[msg.recipient].put(raw)

    def _box_get(self, timeout: float) -> bytes:
        try:
            return self._bus._boxes[self.me].get(
                timeout=max(timeout, 1e-4))
        except queue.Empty:
            raise TimeoutError(f"{self.me}: mailbox empty") from None
