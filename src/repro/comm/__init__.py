from repro.comm.base import (Message, PartyCommunicator,            # noqa: F401
                             CommCfg, CommStats, LinkSpec,
                             RecvFuture, SendFuture)
from repro.comm.local import ThreadBus, ThreadCommunicator          # noqa: F401
from repro.comm.schema import (Field, MsgType, SchemaError,         # noqa: F401
                               TypedChannel, message)
