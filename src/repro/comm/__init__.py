from repro.comm.base import Message, PartyCommunicator, CommStats  # noqa: F401
from repro.comm.local import ThreadBus, ThreadCommunicator          # noqa: F401
from repro.comm.schema import (Field, MsgType, SchemaError,         # noqa: F401
                               TypedChannel, message)
