from repro.comm.base import (Message, PartyCommunicator,            # noqa: F401
                             CommStats, RecvFuture, SendFuture)
from repro.comm.local import ThreadBus, ThreadCommunicator          # noqa: F401
from repro.comm.schema import (Field, MsgType, SchemaError,         # noqa: F401
                               TypedChannel, message)
