"""Communication layer: the MPI-like ``PartyCommunicator`` interface.

The paper's central abstraction (§2): agents exchange tensors through a
send/recv interface whose *implementation* (thread queue, process pipe,
TCP socket, TPU collective) is swapped without touching protocol code.
Every send is metered (payload bytes via the safetensors codec, wall
time) — the paper's "comprehensive logging of payload, exchange time".

Non-blocking engine (DESIGN.md §7): every communicator owns one
background sender thread draining a FIFO queue, so ``isend`` returns a
:class:`SendFuture` immediately — encode happens on the caller thread
(the payload is snapshotted, safe to mutate afterwards), the wire write
happens off it. The blocking ``send`` is a thin wrapper (``isend`` +
wait) with a fast path that writes inline when nothing is queued, so
the synchronous protocols pay no thread handoff. ``irecv`` returns a
:class:`RecvFuture` that resolves lazily: message *arrival* already
progresses in the background on every transport (listener threads /
mailbox queues), so resolving is just the matching wait.
``CommStats`` splits queued-time (waiting behind earlier sends) from
wire-time (inside the transport write).
"""
from __future__ import annotations

import abc
import queue as queue_mod
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.comm import codec

Payload = Dict[str, np.ndarray]


@dataclass
class Message:
    sender: str
    recipient: str
    tag: str
    payload: Payload
    meta: Dict[str, str] = field(default_factory=dict)

    def tensor(self, name: str = "x") -> np.ndarray:
        return self.payload[name]


@dataclass
class CommStats:
    sent_messages: int = 0
    sent_bytes: int = 0
    recv_messages: int = 0
    recv_wait_s: float = 0.0
    send_s: float = 0.0
    # async-engine split: time a message sat behind earlier sends in the
    # outbound queue vs time inside the transport write itself. For the
    # blocking fast path queued_s is ~0 and wire_s ≈ send_s.
    queued_s: float = 0.0
    wire_s: float = 0.0
    async_sends: int = 0
    per_tag_bytes: Dict[str, int] = field(default_factory=dict)
    # lifecycle phase the agent is currently in ("match" / "fit" /
    # "predict" / ...); the driver updates it at phase transitions so
    # payload accounting splits by phase with zero protocol involvement
    phase: str = "init"
    per_phase_bytes: Dict[str, int] = field(default_factory=dict)

    def record_send(self, tag: str, nbytes: int, dt: float):
        self.sent_messages += 1
        self.sent_bytes += nbytes
        self.send_s += dt
        self.per_tag_bytes[tag] = self.per_tag_bytes.get(tag, 0) + nbytes
        self.per_phase_bytes[self.phase] = \
            self.per_phase_bytes.get(self.phase, 0) + nbytes

    def record_wire(self, queued: float, wire: float, was_async: bool):
        # called under the communicator's send lock (sender thread or
        # the inline fast path), so += updates never interleave
        self.queued_s += queued
        self.wire_s += wire
        if was_async:
            self.async_sends += 1

    def record_recv(self, wait: float):
        self.recv_messages += 1
        self.recv_wait_s += wait

    def as_dict(self) -> Dict[str, Any]:
        return {
            "sent_messages": self.sent_messages,
            "sent_bytes": self.sent_bytes,
            "recv_messages": self.recv_messages,
            "recv_wait_s": round(self.recv_wait_s, 4),
            "send_s": round(self.send_s, 4),
            "queued_s": round(self.queued_s, 4),
            "wire_s": round(self.wire_s, 4),
            "async_sends": self.async_sends,
            "per_tag_bytes": dict(self.per_tag_bytes),
            "per_phase_bytes": dict(self.per_phase_bytes),
        }


class SendFuture:
    """Completion handle for one outbound message.

    Resolves once the transport write finished (thread/process: queue
    put; socket: ``sendall`` returned). ``result`` re-raises the
    transport error, if any.
    """

    def __init__(self, msg: Message):
        self.msg = msg
        self._done = threading.Event()
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> None:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"send of {self.msg.tag!r} to {self.msg.recipient!r} "
                f"did not complete within {timeout}s")
        if self._exc is not None:
            raise self._exc

    # -- engine side ---------------------------------------------------------
    def _resolve(self, exc: Optional[BaseException] = None) -> None:
        self._exc = exc
        self._done.set()


class RecvFuture:
    """Deferred receive: arrival progresses in the background (listener
    threads / mailboxes); ``result`` performs the matching wait. ``done``
    peeks without blocking."""

    def __init__(self, resolve: Callable[[Optional[float]], Message],
                 peek: Callable[[], bool]):
        self._resolve = resolve
        self._peek = peek
        self._msg: Optional[Message] = None

    def done(self) -> bool:
        return self._msg is not None or self._peek()

    def result(self, timeout: Optional[float] = None) -> Message:
        if self._msg is None:
            self._msg = self._resolve(timeout)
        return self._msg


class _SendItem:
    __slots__ = ("msg", "raw", "future", "t_enq")

    def __init__(self, msg: Message, raw: bytes, future: SendFuture):
        self.msg = msg
        self.raw = raw
        self.future = future
        self.t_enq = time.perf_counter()


class PartyCommunicator(abc.ABC):
    """MPI-like send/recv among named agents.

    ``world`` lists every agent id ("master", "member0", ..., "arbiter").
    """

    def __init__(self, me: str, world: Sequence[str],
                 timeout: float = 120.0):
        self.me = me
        self.world = list(world)
        self.stats = CommStats()
        self._timeout = timeout
        # async sender engine: FIFO queue + lazily started drain thread.
        # _submitted/_completed (guarded by _send_lock) let the blocking
        # fast path prove nothing is queued OR in flight before writing
        # inline, which preserves per-transport FIFO order.
        self._sendq: "queue_mod.Queue[Optional[_SendItem]]" = \
            queue_mod.Queue()
        self._send_lock = threading.Lock()
        self._send_done = threading.Condition(self._send_lock)
        self._submitted = 0
        self._completed = 0
        self._sender: Optional[threading.Thread] = None
        self._send_exc: Optional[BaseException] = None

    # -- implementation hooks ------------------------------------------------
    @abc.abstractmethod
    def _send(self, msg: Message, raw: bytes) -> None:
        ...

    @abc.abstractmethod
    def _recv_any(self, frm: str, tags: Sequence[str],
                  timeout: Optional[float] = None) -> Message:
        """Block until a message from ``frm`` with any of ``tags``
        arrives; return it (earliest-arrived wins on ties)."""

    def _peek(self, frm: str, tags: Sequence[str]) -> bool:
        """Non-blocking: is a matching message already delivered?"""
        return False                     # pragma: no cover - overridden

    def _recv(self, frm: str, tag: str,
              timeout: Optional[float] = None) -> Message:
        return self._recv_any(frm, (tag,), timeout)

    # -- sender engine -------------------------------------------------------
    def _sender_loop(self) -> None:
        while True:
            item = self._sendq.get()
            if item is None:
                return
            with self._send_lock:
                # after a write error the wire may be mid-frame: never
                # write again — fail queued sends fast instead of
                # corrupting the length-prefixed stream
                if self._send_exc is not None:
                    item.future._resolve(self._send_exc)
                    self._completed += 1
                    self._send_done.notify_all()
                    continue
                t0 = time.perf_counter()
                try:
                    self._send(item.msg, item.raw)
                except BaseException as e:          # noqa: BLE001
                    self._send_exc = e
                    item.future._resolve(e)
                else:
                    t1 = time.perf_counter()
                    self.stats.record_wire(t0 - item.t_enq, t1 - t0,
                                           was_async=True)
                    item.future._resolve()
                finally:
                    self._completed += 1
                    self._send_done.notify_all()

    def _ensure_sender(self) -> None:
        if self._sender is None:
            self._sender = threading.Thread(target=self._sender_loop,
                                            daemon=True,
                                            name=f"sender-{self.me}")
            self._sender.start()

    def _raise_pending_send_error(self) -> None:
        # sticky by design: after a wire error the stream may be
        # mid-frame, so the engine never writes again — every further
        # send on this communicator fails with the original error
        with self._send_lock:
            if self._send_exc is not None:
                raise self._send_exc

    # -- public API ----------------------------------------------------------
    def _make(self, to: str, tag: str, payload: Payload,
              meta: Optional[Dict[str, str]]) -> "tuple[Message, bytes]":
        payload = {k: np.asarray(v) for k, v in payload.items()}
        msg = Message(self.me, to, tag, payload, dict(meta or {}))
        raw = codec.encode(payload, {"sender": self.me, "tag": tag,
                                     **msg.meta})
        return msg, raw

    def isend(self, to: str, tag: str, payload: Payload,
              meta: Optional[Dict[str, str]] = None) -> SendFuture:
        """Non-blocking send: encode now (payload snapshot), write on
        the background sender thread, FIFO with every other send."""
        self._raise_pending_send_error()
        t0 = time.perf_counter()
        msg, raw = self._make(to, tag, payload, meta)
        fut = SendFuture(msg)
        self._ensure_sender()
        with self._send_lock:
            self._submitted += 1
        self._sendq.put(_SendItem(msg, raw, fut))
        self.stats.record_send(tag, len(raw), time.perf_counter() - t0)
        return fut

    def send(self, to: str, tag: str, payload: Payload,
             meta: Optional[Dict[str, str]] = None) -> None:
        """Blocking send. Fast path: when no async send is queued or in
        flight, write inline on the caller thread (no handoff)."""
        self._raise_pending_send_error()
        t0 = time.perf_counter()
        msg, raw = self._make(to, tag, payload, meta)
        with self._send_lock:
            if self._submitted == self._completed:
                t1 = time.perf_counter()
                self._send(msg, raw)
                self.stats.record_wire(0.0, time.perf_counter() - t1,
                                       was_async=False)
                self.stats.record_send(tag, len(raw),
                                       time.perf_counter() - t0)
                return
        # async sends outstanding: join the FIFO behind them
        fut = SendFuture(msg)
        with self._send_lock:
            self._submitted += 1
        self._sendq.put(_SendItem(msg, raw, fut))
        self.stats.record_send(tag, len(raw), time.perf_counter() - t0)
        fut.result(self._timeout)

    def flush_sends(self, timeout: Optional[float] = None) -> None:
        """Block until every queued send hit the wire."""
        with self._send_done:
            ok = self._send_done.wait_for(
                lambda: self._submitted == self._completed, timeout)
            if not ok:
                raise TimeoutError("unflushed sends remain")
            if self._send_exc is not None:
                raise self._send_exc

    def recv(self, frm: str, tag: str,
             timeout: Optional[float] = None) -> Message:
        t0 = time.perf_counter()
        msg = self._recv(frm, tag, timeout)
        self.stats.record_recv(time.perf_counter() - t0)
        return msg

    def recv_any(self, frm: str, tags: Sequence[str],
                 timeout: Optional[float] = None) -> Message:
        """Blocking wait for the first message from ``frm`` carrying any
        of ``tags`` (stream-aware receives: data or a coalesced frame)."""
        t0 = time.perf_counter()
        msg = self._recv_any(frm, tuple(tags), timeout)
        self.stats.record_recv(time.perf_counter() - t0)
        return msg

    def irecv(self, frm: str, tag: str) -> RecvFuture:
        """Non-blocking receive handle for (frm, tag). Arrival already
        progresses in the background; ``result()`` is the matching wait
        and MUST be called from the agent's own thread (transports hold
        one mailbox per agent)."""
        def _resolve(timeout: Optional[float]) -> Message:
            return self.recv(frm, tag, timeout)
        return RecvFuture(_resolve, lambda: self._peek(frm, (tag,)))

    def broadcast(self, tag: str, payload: Payload,
                  targets: Optional[Sequence[str]] = None,
                  meta: Optional[Dict[str, str]] = None,
                  wait: bool = True) -> List[SendFuture]:
        """Send to every target; with ``wait=False`` the writes stay on
        the sender thread and the returned futures track completion."""
        futs = [self.isend(t, tag, payload, meta=meta)
                for t in (targets if targets is not None else self.world)
                if t != self.me]
        if wait:
            for f in futs:
                f.result(self._timeout)
        return futs

    def gather(self, frm: Sequence[str], tag: str) -> List[Message]:
        futs = [self.irecv(f, tag) for f in frm]
        return [f.result(self._timeout) for f in futs]

    def scatter(self, tag: str, payloads: Dict[str, Payload]) -> None:
        for to, payload in payloads.items():
            self.send(to, tag, payload)

    def close(self) -> None:
        """Stop the sender thread after draining queued writes."""
        if self._sender is not None:
            self._sendq.put(None)
            self._sender.join(timeout=10)
            self._sender = None

    @property
    def members(self) -> List[str]:
        return [w for w in self.world if w.startswith("member")]
