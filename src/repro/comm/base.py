"""Communication layer: the MPI-like ``PartyCommunicator`` interface.

The paper's central abstraction (§2): agents exchange tensors through a
send/recv interface whose *implementation* (thread queue, process pipe,
TCP socket, TPU collective) is swapped without touching protocol code.
Every send is metered (payload bytes via the safetensors codec, wall
time) — the paper's "comprehensive logging of payload, exchange time".

Non-blocking engine (DESIGN.md §7): every communicator owns one
background sender thread draining a FIFO queue, so ``isend`` returns a
:class:`SendFuture` immediately. Encode (safetensors serialization)
runs on the *sender thread* by default (DESIGN.md §8.3): the caller
only snapshots the payload — arrays whose buffers are writeable are
copied on enqueue, read-only arrays (e.g. jax exports) ride as-is — so
protocols may update weights in place the moment ``isend`` returns
while the master's critical path no longer pays serialization.
``CommCfg(encode_offload=False)`` restores caller-side encode. The
blocking ``send`` is a thin wrapper (``isend`` + wait) with a fast path
that encodes and writes inline when nothing is queued, so the
synchronous protocols pay no thread handoff. ``irecv`` returns a
:class:`RecvFuture` that resolves lazily: message *arrival* already
progresses in the background on every transport (listener threads /
mailbox queues), so resolving is just the matching wait.
``CommStats`` splits queued-time (waiting behind earlier sends) from
wire-time (inside the transport write).

WAN emulation (DESIGN.md §8.2): ``CommCfg.link = LinkSpec(...)``
shapes every outbound message in the sender thread — bandwidth
serializes messages on a virtual link clock, latency (plus optional
jitter) delays delivery *in parallel* across in-flight messages, the
way real propagation delay does — so loopback benchmarks and tests can
reproduce the cross-silo regimes the VFL-in-practice literature warns
about without leaving one host.
"""
from __future__ import annotations

import abc
import queue as queue_mod
import random
import ssl
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm import codec

Payload = Dict[str, np.ndarray]


@dataclass(frozen=True)
class LinkSpec:
    """Emulated WAN link applied to every outbound message.

    ``latency_ms`` is one-way propagation delay (an RTT of 40 ms means
    ``latency_ms=20`` on both parties' links); ``bandwidth_mbps`` is
    the serialization rate in megabits/s (0 = unlimited);
    ``jitter_ms`` adds uniform-random extra delay in ``[0, jitter_ms]``
    per message (FIFO order is preserved — a jittered message never
    overtakes an earlier one). ``loss`` is a per-message drop
    probability in ``[0, 1]``: dropped messages resolve their send
    future normally (the sender believes the write succeeded, like a
    blackholed IP route) and are counted in ``CommStats.link_dropped``;
    ``loss=1.0`` blackholes the link entirely — the chaos ``partition``
    scenario. Deliveries that do survive keep FIFO order.

    Latency is modeled as *propagation*: two messages enqueued
    back-to-back both arrive ~``latency_ms`` later, not 2x. Bandwidth
    is modeled as *serialization*: each message occupies the link for
    ``nbytes * 8 / bandwidth`` seconds before the next may enter.

    Example::

        from repro.comm.base import CommCfg, LinkSpec

        wan = CommCfg(link=LinkSpec(latency_ms=20, bandwidth_mbps=100))
        job = VFLJob(cfg, master, members, mode="grpc", comm_cfg=wan)
    """

    latency_ms: float = 0.0
    bandwidth_mbps: float = 0.0
    jitter_ms: float = 0.0
    loss: float = 0.0


@dataclass(frozen=True)
class TLSSpec:
    """Mutual-TLS material for the TCP transports (``sock``/``grpc``
    framings and their ``*_proc`` modes).

    ``cert``/``key`` are this agent's PEM certificate chain and private
    key; ``ca`` is the bundle used to verify *peers* (both directions —
    the server requires a client certificate signed by the same CA, so
    every connection is mutually authenticated, the deployment model
    cross-organization VFL needs). ``server_hostname`` overrides the
    name checked against the server certificate (default: the ``host``
    from the address map); ``check_hostname=False`` skips the name
    check while keeping chain verification.

    Paths may contain an ``{agent}`` placeholder, resolved to the
    communicator's own agent id — so one shared :class:`CommCfg` can
    hand every agent its own certificate::

        tls = TLSSpec(cert="certs/{agent}.crt", key="certs/{agent}.key",
                      ca="certs/ca.crt")
        job = VFLJob(cfg, master, members, mode="grpc_proc",
                     comm_cfg=CommCfg(tls=tls))

    Generate a repo-local test CA + per-agent certificates with
    ``python -m repro.launch.certs`` (see docs/deploy.md). TLS wraps
    the wire only — payload bytes are unchanged, so depth-1 runs over
    TLS stay bit-identical to plaintext runs.
    """

    cert: str
    key: str
    ca: str
    server_hostname: Optional[str] = None
    check_hostname: bool = True

    def resolve(self, agent: str) -> "TLSSpec":
        """Substitute the ``{agent}`` placeholder in the paths."""
        from dataclasses import replace
        return replace(self,
                       cert=self.cert.format(agent=agent),
                       key=self.key.format(agent=agent),
                       ca=self.ca.format(agent=agent))

    def server_context(self) -> ssl.SSLContext:
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
        ctx.load_cert_chain(self.cert, self.key)
        ctx.load_verify_locations(self.ca)
        ctx.verify_mode = ssl.CERT_REQUIRED      # mutual TLS
        return ctx

    def client_context(self) -> ssl.SSLContext:
        ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_CLIENT)
        ctx.load_cert_chain(self.cert, self.key)
        ctx.load_verify_locations(self.ca)
        ctx.check_hostname = self.check_hostname
        return ctx


@dataclass(frozen=True)
class CommCfg:
    """Transport-independent communicator settings.

    ``timeout``: default bound for every blocking wait (connect, recv,
    blocking-send completion); per-call ``timeout=`` overrides it.
    ``None`` (the default) keeps each transport's own default (120 s;
    240 s for process mailboxes, sized for slow spawn imports) — so a
    CommCfg passed only for, say, link shaping never silently tightens
    a transport's deliberate timeout.
    ``nodelay``: disable Nagle on TCP transports (keep True; the flag
    exists so benchmarks can measure the before/after honestly).
    ``link``: optional :class:`LinkSpec` WAN emulation, applied in the
    sender thread of every transport.
    ``encode_offload``: serialize ``isend`` payloads on the sender
    thread instead of the caller (True, the default, shaves the
    caller's critical path; the payload is snapshotted on enqueue
    either way).
    ``tls``: optional :class:`TLSSpec` — wrap every TCP connection
    (``sock`` and ``grpc`` framings, thread and ``*_proc`` modes) in
    mutually-authenticated TLS. Ignored by the in-memory transports.
    ``strict_eof``: treat *any* EOF from an identified peer as a drop
    (mark the sender down), not just mid-frame closes. Off by default —
    the PR 5 attribution semantics, where a clean close between frames
    is a normal shutdown — and switched on by elastic clusters, where a
    SIGKILL'd agent's kernel-closed sockets often look like clean
    closes and must still be detected within milliseconds. Only
    meaningful when the master does no receives after its shutdown
    broadcast (our drivers' discipline).
    ``peer_overrides``: optional per-edge settings, keyed by peer agent
    id — the cluster spec's ``[comm.master.member0]`` tables resolve
    here (``ClusterSpec.comm_for``). Only the **edge-scoped** fields of
    an override are honored: ``link`` (each overridden peer gets its
    own emulated uplink with an independent bandwidth clock) and
    ``timeout`` (bounds blocking sends to and receives from that
    peer). Connection-level fields (``tls``, ``nodelay``,
    ``encode_offload``, ``strict_eof``) stay world-level — a socket is
    configured before the engine knows which VFL edge it serves — and
    the spec validator rejects them per-edge. Each field pins its edge
    only when the override actually sets it: a non-None ``link`` pins
    that edge's shaping (chaos-scripted
    :meth:`PartyCommunicator.set_link` does not touch it), while a
    timeout-only override (``link=None``) keeps riding the shared
    world link — the "*" bandwidth clock and runtime ``set_link``
    swaps — exactly like peers with no entry at all.

    Example::

        from repro.comm.base import CommCfg, LinkSpec

        cfg = CommCfg(timeout=60.0,
                      link=LinkSpec(latency_ms=40, jitter_ms=5))
        job = VFLJob(vfl_cfg, master, members, mode="socket",
                     comm_cfg=cfg)
    """

    timeout: Optional[float] = None
    nodelay: bool = True
    link: Optional[LinkSpec] = None
    encode_offload: bool = True
    tls: Optional[TLSSpec] = None
    strict_eof: bool = False
    peer_overrides: Optional[Dict[str, "CommCfg"]] = None


@dataclass
class Message:
    sender: str
    recipient: str
    tag: str
    payload: Payload
    meta: Dict[str, str] = field(default_factory=dict)

    def tensor(self, name: str = "x") -> np.ndarray:
        return self.payload[name]


@dataclass
class CommStats:
    sent_messages: int = 0
    sent_bytes: int = 0
    recv_messages: int = 0
    recv_wait_s: float = 0.0
    send_s: float = 0.0
    # async-engine split: time a message sat behind earlier sends in the
    # outbound queue vs time inside the transport write itself. For the
    # blocking fast path queued_s is ~0 and wire_s ≈ send_s.
    queued_s: float = 0.0
    wire_s: float = 0.0
    async_sends: int = 0
    per_tag_bytes: Dict[str, int] = field(default_factory=dict)
    # lifecycle phase the agent is currently in ("match" / "fit" /
    # "predict" / ...); the driver updates it at phase transitions so
    # payload accounting splits by phase with zero protocol involvement
    phase: str = "init"
    per_phase_bytes: Dict[str, int] = field(default_factory=dict)
    # robustness accounting: rounds where the master proceeded with a
    # stale contribution because a member missed its per-round deadline
    # (keyed by the straggling peer), and messages the emulated link
    # dropped (LinkSpec.loss / chaos partition)
    straggles: Dict[str, int] = field(default_factory=dict)
    link_dropped: int = 0

    def record_send(self, tag: str, nbytes: int, dt: float,
                    phase: Optional[str] = None):
        # ``phase`` pins deferred-encode sends to the lifecycle phase
        # they were *enqueued* in (the sender thread may only get to
        # them after a phase transition)
        phase = self.phase if phase is None else phase
        self.sent_messages += 1
        self.sent_bytes += nbytes
        self.send_s += dt
        self.per_tag_bytes[tag] = self.per_tag_bytes.get(tag, 0) + nbytes
        self.per_phase_bytes[phase] = \
            self.per_phase_bytes.get(phase, 0) + nbytes

    def record_wire(self, queued: float, wire: float, was_async: bool):
        # called under the communicator's send lock (sender thread or
        # the inline fast path), so += updates never interleave
        self.queued_s += queued
        self.wire_s += wire
        if was_async:
            self.async_sends += 1

    def record_recv(self, wait: float):
        self.recv_messages += 1
        self.recv_wait_s += wait

    def record_straggle(self, peer: str):
        self.straggles[peer] = self.straggles.get(peer, 0) + 1

    def as_dict(self) -> Dict[str, Any]:
        return {
            "sent_messages": self.sent_messages,
            "sent_bytes": self.sent_bytes,
            "recv_messages": self.recv_messages,
            "recv_wait_s": round(self.recv_wait_s, 4),
            "send_s": round(self.send_s, 4),
            "queued_s": round(self.queued_s, 4),
            "wire_s": round(self.wire_s, 4),
            "async_sends": self.async_sends,
            "per_tag_bytes": dict(self.per_tag_bytes),
            "per_phase_bytes": dict(self.per_phase_bytes),
            "straggles": dict(self.straggles),
            "link_dropped": self.link_dropped,
        }


class SendFuture:
    """Completion handle for one outbound message.

    Resolves once the transport write finished (thread/process: queue
    put; socket: ``sendall`` returned). ``result`` re-raises the
    transport error, if any.
    """

    def __init__(self, msg: Message):
        self.msg = msg
        self._done = threading.Event()
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> None:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"send of {self.msg.tag!r} to {self.msg.recipient!r} "
                f"did not complete within {timeout}s")
        if self._exc is not None:
            raise self._exc

    # -- engine side ---------------------------------------------------------
    def _resolve(self, exc: Optional[BaseException] = None) -> None:
        self._exc = exc
        self._done.set()


class RecvFuture:
    """Deferred receive: arrival progresses in the background (listener
    threads / mailboxes); ``result`` performs the matching wait. ``done``
    peeks without blocking."""

    def __init__(self, resolve: Callable[[Optional[float]], Message],
                 peek: Callable[[], bool]):
        self._resolve = resolve
        self._peek = peek
        self._msg: Optional[Message] = None

    def done(self) -> bool:
        return self._msg is not None or self._peek()

    def result(self, timeout: Optional[float] = None) -> Message:
        if self._msg is None:
            self._msg = self._resolve(timeout)
        return self._msg


def _buffer_mutable(a: np.ndarray) -> bool:
    """Could this array's bytes still change under the caller's feet?
    A read-only *view* of a writeable array is mutable through its
    base, so the snapshot must walk the whole ndarray ancestry; a
    chain ending in None or a foreign buffer (jax exports) is only as
    mutable as its read-only flags say."""
    while isinstance(a, np.ndarray):
        if a.flags.writeable:
            return True
        a = a.base
    return False


class _SendItem:
    """One queued outbound message. ``raw`` is the encoded blob, or
    None when encode is offloaded to the sender thread (the message's
    payload is already a snapshot, so late encode sees frozen bytes)."""

    __slots__ = ("msg", "raw", "future", "t_enq", "phase")

    def __init__(self, msg: Message, raw: Optional[bytes],
                 future: SendFuture, phase: str):
        self.msg = msg
        self.raw = raw
        self.future = future
        self.t_enq = time.perf_counter()
        self.phase = phase

    def encode(self) -> bytes:
        if self.raw is None:
            m = self.msg
            self.raw = codec.encode(
                m.payload, {"sender": m.sender, "tag": m.tag, **m.meta})
        return self.raw


class PartyCommunicator(abc.ABC):
    """MPI-like send/recv among named agents.

    ``world`` lists every agent id ("master", "member0", ..., "arbiter").
    """

    def __init__(self, me: str, world: Sequence[str],
                 timeout: float = 120.0,
                 comm_cfg: Optional[CommCfg] = None):
        self.me = me
        self.world = list(world)
        self.stats = CommStats()
        self.cfg = comm_cfg if comm_cfg is not None \
            else CommCfg(timeout=timeout)
        # CommCfg.timeout=None defers to the transport's constructor
        # default (process mode deliberately runs 240 s, not 120 s)
        self._timeout = self.cfg.timeout \
            if self.cfg.timeout is not None else timeout
        self._link = self.cfg.link
        if self._link is not None and self._link == LinkSpec():
            self._link = None            # all-zero spec: no shaping
        # per-edge overrides (CommCfg.peer_overrides): link and timeout
        # register independently, each only when the override sets it —
        # a timeout-only override must NOT pin a private copy of the
        # world link (it would get its own bandwidth clock and be
        # exempt from runtime set_link chaos swaps). An explicit
        # all-zero link pins the edge as unshaped.
        self._peer_links: Dict[str, Optional[LinkSpec]] = {}
        self._peer_timeouts: Dict[str, float] = {}
        for peer, ov in (self.cfg.peer_overrides or {}).items():
            if ov.link is not None:
                self._peer_links[peer] = \
                    None if ov.link == LinkSpec() else ov.link
            if ov.timeout is not None:
                self._peer_timeouts[peer] = ov.timeout
        # link-shaping clocks (sender thread only), one per uplink:
        # time the last byte of the previous message entered the
        # emulated link, and the latest delivery stamp handed out
        # (enforces FIFO under jitter). Default-link peers share the
        # "*" clock (one uplink serializes them, the PR 4 semantics);
        # an overridden edge is its own physical link with its own
        # bandwidth clock.
        self._link_busy: Dict[str, float] = {}
        self._link_last: Dict[str, float] = {}
        # stable per-agent seed (hash() is salted per interpreter — a
        # spawned agent process would jitter differently every run)
        self._link_rng = random.Random(zlib.crc32(me.encode()))
        # async sender engine: FIFO queue + lazily started drain thread.
        # _submitted/_completed (guarded by _send_lock) let the blocking
        # fast path prove nothing is queued OR in flight before writing
        # inline, which preserves per-transport FIFO order.
        self._sendq: "queue_mod.Queue[Optional[_SendItem]]" = \
            queue_mod.Queue()
        self._send_lock = threading.Lock()
        self._send_done = threading.Condition(self._send_lock)
        self._submitted = 0
        self._completed = 0
        self._sender: Optional[threading.Thread] = None
        # wire errors are sticky PER PEER: after a partial write the
        # stream to *that* peer may be mid-frame (each peer is its own
        # connection/mailbox), so the engine never writes to it again —
        # but streams to other peers stay healthy, which is what lets
        # an elastic master keep serving survivors while one member is
        # down. _suspect names the last peer whose write failed (crash
        # attribution for the rejoin machinery).
        self._send_errs: Dict[str, BaseException] = {}
        self._suspect: Optional[str] = None

    # -- implementation hooks ------------------------------------------------
    @abc.abstractmethod
    def _send(self, msg: Message, raw: bytes) -> None:
        ...

    @abc.abstractmethod
    def _recv_any(self, frm: str, tags: Sequence[str],
                  timeout: Optional[float] = None) -> Message:
        """Block until a message from ``frm`` with any of ``tags``
        arrives; return it (earliest-arrived wins on ties)."""

    def _peek(self, frm: str, tags: Sequence[str]) -> bool:
        """Non-blocking: is a matching message already delivered?"""
        return False                     # pragma: no cover - overridden

    def _recv(self, frm: str, tag: str,
              timeout: Optional[float] = None) -> Message:
        return self._recv_any(frm, (tag,), timeout)

    # -- sender engine -------------------------------------------------------
    def _link_for(self, to: str) -> Optional[LinkSpec]:
        """The emulated link shaping sends to ``to``: the per-edge
        override when one exists, else the world-level link."""
        if to in self._peer_links:
            return self._peer_links[to]
        return self._link

    def _timeout_for(self, to: str) -> float:
        return self._peer_timeouts.get(to, self._timeout)

    def _shape_delay(self, t_enq: float, nbytes: int,
                     link: LinkSpec, ckey: str) -> None:
        """Sleep (sender thread, no locks held) until the emulated link
        would deliver this message. Bandwidth serializes on a virtual
        clock keyed to *enqueue* time, so latency overlaps across
        in-flight messages like real propagation delay; the delivery
        stamp is monotonic so jitter never reorders the FIFO. ``ckey``
        names the uplink clock: "*" for the shared default link, the
        peer id for a per-edge override (its own physical link)."""
        tx = nbytes * 8.0 / (link.bandwidth_mbps * 1e6) \
            if link.bandwidth_mbps else 0.0
        busy = max(self._link_busy.get(ckey, 0.0), t_enq) + tx
        self._link_busy[ckey] = busy
        extra = self._link_rng.uniform(0.0, link.jitter_ms) * 1e-3 \
            if link.jitter_ms else 0.0
        deliver = busy + link.latency_ms * 1e-3 + extra
        last = max(self._link_last.get(ckey, 0.0), deliver)
        self._link_last[ckey] = last
        dt = last - time.perf_counter()
        if dt > 0:
            time.sleep(dt)

    def _finish_item(self, item: _SendItem,
                     exc: Optional[BaseException]) -> None:
        # caller must hold _send_lock
        item.future._resolve(exc)
        self._completed += 1
        self._send_done.notify_all()

    def _sender_loop(self) -> None:
        while True:
            item = self._sendq.get()
            if item is None:
                return
            to = item.msg.recipient
            # fail fast (and skip encode) once the wire to this peer
            # errored: after a partial write that stream may be
            # mid-frame, so the engine never writes to it again
            with self._send_lock:
                err = self._send_errs.get(to)
                if err is not None:
                    self._finish_item(item, err)
                    continue
            try:
                deferred = item.raw is None
                raw = item.encode()
            except BaseException as e:          # noqa: BLE001
                # encode never touched the wire: the error is NOT
                # sticky — only this send fails
                with self._send_lock:
                    self._finish_item(item, e)
                continue
            link = self._link_for(to)
            if link is not None:
                if link.loss and self._link_rng.random() < link.loss:
                    # blackholed: the sender side believes the write
                    # succeeded (futures resolve), nothing hits the wire
                    with self._send_lock:
                        self.stats.link_dropped += 1
                        self._finish_item(item, None)
                    continue
                ckey = to if to in self._peer_links else "*"
                self._shape_delay(item.t_enq, len(raw), link, ckey)
            with self._send_lock:
                err = self._send_errs.get(to)
                if err is not None:
                    self._finish_item(item, err)
                    continue
                if deferred:       # caller didn't know the byte count
                    self.stats.record_send(item.msg.tag, len(raw), 0.0,
                                           phase=item.phase)
                t0 = time.perf_counter()
                try:
                    self._send(item.msg, raw)
                except BaseException as e:          # noqa: BLE001
                    self._send_errs[to] = e
                    self._suspect = to
                    item.future._resolve(e)
                else:
                    t1 = time.perf_counter()
                    self.stats.record_wire(t0 - item.t_enq, t1 - t0,
                                           was_async=True)
                    item.future._resolve()
                finally:
                    self._completed += 1
                    self._send_done.notify_all()

    def _ensure_sender(self) -> None:
        if self._sender is None:
            self._sender = threading.Thread(target=self._sender_loop,
                                            daemon=True,
                                            name=f"sender-{self.me}")
            self._sender.start()

    def _raise_pending_send_error(self, to: str) -> None:
        # sticky by design: after a wire error the stream to that peer
        # may be mid-frame, so the engine never writes to it again —
        # every further send to the same peer fails with the original
        # error (other peers' streams are unaffected)
        with self._send_lock:
            err = self._send_errs.get(to)
            if err is not None:
                raise err

    # -- public API ----------------------------------------------------------
    def _make(self, to: str, tag: str, payload: Payload,
              meta: Optional[Dict[str, str]],
              encode: bool = True) -> "Tuple[Message, Optional[bytes]]":
        """Build the Message (+ encoded blob unless deferred). With
        ``encode=False`` the payload is *snapshotted* instead: arrays
        whose buffers are writeable are copied (the caller may mutate
        them the moment isend returns — the snapshot contract),
        read-only arrays ride as-is (jax exports, received tensors)."""
        if encode:
            payload = {k: np.asarray(v) for k, v in payload.items()}
        else:
            snap = {}
            for k, v in payload.items():
                a = np.asarray(v)
                if _buffer_mutable(a):
                    a = a.copy()
                snap[k] = a
            payload = snap
        msg = Message(self.me, to, tag, payload, dict(meta or {}))
        if not encode:
            return msg, None
        raw = codec.encode(payload, {"sender": self.me, "tag": tag,
                                     **msg.meta})
        return msg, raw

    def _enqueue(self, msg: Message, raw: Optional[bytes],
                 t0: float) -> SendFuture:
        fut = SendFuture(msg)
        self._ensure_sender()
        with self._send_lock:
            self._submitted += 1
            if raw is not None:
                self.stats.record_send(msg.tag, len(raw),
                                       time.perf_counter() - t0)
        self._sendq.put(_SendItem(msg, raw, fut, self.stats.phase))
        return fut

    def isend(self, to: str, tag: str, payload: Payload,
              meta: Optional[Dict[str, str]] = None) -> SendFuture:
        """Non-blocking send: snapshot the payload now, encode + write
        on the background sender thread (or encode inline when
        ``CommCfg.encode_offload`` is off), FIFO with every other send.

        Example::

            fut = comm.isend("master", "splitnn/u", {"u": acts})
            ...                      # overlap compute with the write
            fut.result(timeout=30)   # re-raises transport errors
        """
        self._raise_pending_send_error(to)
        t0 = time.perf_counter()
        msg, raw = self._make(to, tag, payload, meta,
                              encode=not self.cfg.encode_offload)
        return self._enqueue(msg, raw, t0)

    def send(self, to: str, tag: str, payload: Payload,
             meta: Optional[Dict[str, str]] = None) -> None:
        """Blocking send. Fast path: when no async send is queued or in
        flight (and no link shaping is active), encode and write inline
        on the caller thread — no thread handoff."""
        self._raise_pending_send_error(to)
        t0 = time.perf_counter()
        if self._link_for(to) is None:
            msg, raw = self._make(to, tag, payload, meta)
            with self._send_lock:
                if self._submitted == self._completed:
                    t1 = time.perf_counter()
                    try:
                        self._send(msg, raw)
                    except BaseException:
                        self._suspect = to
                        raise
                    self.stats.record_wire(0.0, time.perf_counter() - t1,
                                           was_async=False)
                    self.stats.record_send(tag, len(raw),
                                           time.perf_counter() - t0)
                    return
        else:
            # shaped links route every send through the sender thread:
            # the link clock lives there, and the delivery sleep must
            # not run under the send lock
            msg, raw = self._make(to, tag, payload, meta)
        # async sends outstanding (or link shaping): join the FIFO
        fut = self._enqueue(msg, raw, t0)
        fut.result(self._timeout_for(to))

    def flush_sends(self, timeout: Optional[float] = None) -> None:
        """Block until every queued send hit the wire."""
        with self._send_done:
            ok = self._send_done.wait_for(
                lambda: self._submitted == self._completed, timeout)
            if not ok:
                raise TimeoutError("unflushed sends remain")
            if self._send_errs:
                raise next(iter(self._send_errs.values()))

    def set_link(self, link: Optional[LinkSpec]) -> None:
        """Swap WAN emulation at runtime — the chaos scenarios'
        mid-run toggle (``partition`` = ``LinkSpec(loss=1.0)``,
        ``slow`` = inflated latency). Subsequent sends route through
        the sender thread and see the new link; a message racing the
        swap may be shaped under either spec (benign). Swaps the
        *default* link only: edges whose ``CommCfg.peer_overrides``
        entry sets a link keep their pinned spec (timeout-only
        overrides ride the default link and follow the swap)."""
        if link is not None and link == LinkSpec():
            link = None                  # all-zero spec: no shaping
        self._link = link

    def suspects(self) -> set:
        """Peers this communicator has evidence are down: failed
        outbound writes here, plus transport-detected drops (TCP
        framings override to add their ``_down`` set)."""
        return {self._suspect} if self._suspect is not None else set()

    def reset_peer(self, peer: str,
                   keep_tags: Sequence[str] = ()) -> None:
        """Forget all state for one peer so a restarted process can
        re-handshake: clears its sticky send error and suspect mark,
        and drops its undelivered inbound messages except tags with a
        prefix in ``keep_tags`` (the control-plane tags a rejoiner's
        hello may already ride on). Transports extend this to also
        close cached connections and clear down-marks."""
        with self._send_lock:
            self._send_errs.pop(peer, None)
            if self._suspect == peer:
                self._suspect = None
        pending = getattr(self, "_pending", None)
        if pending is not None:
            for key in list(pending):
                if key[0] == peer and not any(
                        key[1].startswith(k) for k in keep_tags):
                    del pending[key]

    def recv(self, frm: str, tag: str,
             timeout: Optional[float] = None) -> Message:
        if timeout is None and frm in self._peer_timeouts:
            timeout = self._peer_timeouts[frm]
        t0 = time.perf_counter()
        msg = self._recv(frm, tag, timeout)
        self.stats.record_recv(time.perf_counter() - t0)
        return msg

    def recv_any(self, frm: str, tags: Sequence[str],
                 timeout: Optional[float] = None) -> Message:
        """Blocking wait for the first message from ``frm`` carrying any
        of ``tags`` (stream-aware receives: data or a coalesced frame)."""
        if timeout is None and frm in self._peer_timeouts:
            timeout = self._peer_timeouts[frm]
        t0 = time.perf_counter()
        msg = self._recv_any(frm, tuple(tags), timeout)
        self.stats.record_recv(time.perf_counter() - t0)
        return msg

    def irecv(self, frm: str, tag: str) -> RecvFuture:
        """Non-blocking receive handle for (frm, tag). Arrival already
        progresses in the background; ``result()`` is the matching wait
        and MUST be called from the agent's own thread (transports hold
        one mailbox per agent)."""
        def _resolve(timeout: Optional[float]) -> Message:
            return self.recv(frm, tag, timeout)
        return RecvFuture(_resolve, lambda: self._peek(frm, (tag,)))

    def broadcast(self, tag: str, payload: Payload,
                  targets: Optional[Sequence[str]] = None,
                  meta: Optional[Dict[str, str]] = None,
                  wait: bool = True) -> List[SendFuture]:
        """Send to every target; with ``wait=False`` the writes stay on
        the sender thread and the returned futures track completion."""
        futs = [self.isend(t, tag, payload, meta=meta)
                for t in (targets if targets is not None else self.world)
                if t != self.me]
        if wait:
            for f in futs:
                f.result(self._timeout)
        return futs

    def gather(self, frm: Sequence[str], tag: str) -> List[Message]:
        futs = [self.irecv(f, tag) for f in frm]
        return [f.result(self._timeout) for f in futs]

    def scatter(self, tag: str, payloads: Dict[str, Payload]) -> None:
        for to, payload in payloads.items():
            self.send(to, tag, payload)

    def close(self) -> None:
        """Stop the sender thread after draining queued writes."""
        if self._sender is not None:
            self._sendq.put(None)
            self._sender.join(timeout=10)
            self._sender = None

    @property
    def members(self) -> List[str]:
        return [w for w in self.world if w.startswith("member")]
