"""Communication layer: the MPI-like ``PartyCommunicator`` interface.

The paper's central abstraction (§2): agents exchange tensors through a
send/recv interface whose *implementation* (thread queue, process pipe,
TCP socket, TPU collective) is swapped without touching protocol code.
Every send is metered (payload bytes via the safetensors codec, wall
time) — the paper's "comprehensive logging of payload, exchange time".
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.comm import codec

Payload = Dict[str, np.ndarray]


@dataclass
class Message:
    sender: str
    recipient: str
    tag: str
    payload: Payload
    meta: Dict[str, str] = field(default_factory=dict)

    def tensor(self, name: str = "x") -> np.ndarray:
        return self.payload[name]


@dataclass
class CommStats:
    sent_messages: int = 0
    sent_bytes: int = 0
    recv_messages: int = 0
    recv_wait_s: float = 0.0
    send_s: float = 0.0
    per_tag_bytes: Dict[str, int] = field(default_factory=dict)
    # lifecycle phase the agent is currently in ("match" / "fit" /
    # "predict" / ...); the driver updates it at phase transitions so
    # payload accounting splits by phase with zero protocol involvement
    phase: str = "init"
    per_phase_bytes: Dict[str, int] = field(default_factory=dict)

    def record_send(self, tag: str, nbytes: int, dt: float):
        self.sent_messages += 1
        self.sent_bytes += nbytes
        self.send_s += dt
        self.per_tag_bytes[tag] = self.per_tag_bytes.get(tag, 0) + nbytes
        self.per_phase_bytes[self.phase] = \
            self.per_phase_bytes.get(self.phase, 0) + nbytes

    def record_recv(self, wait: float):
        self.recv_messages += 1
        self.recv_wait_s += wait

    def as_dict(self) -> Dict[str, Any]:
        return {
            "sent_messages": self.sent_messages,
            "sent_bytes": self.sent_bytes,
            "recv_messages": self.recv_messages,
            "recv_wait_s": round(self.recv_wait_s, 4),
            "send_s": round(self.send_s, 4),
            "per_tag_bytes": dict(self.per_tag_bytes),
            "per_phase_bytes": dict(self.per_phase_bytes),
        }


class PartyCommunicator(abc.ABC):
    """MPI-like send/recv among named agents.

    ``world`` lists every agent id ("master", "member0", ..., "arbiter").
    """

    def __init__(self, me: str, world: Sequence[str]):
        self.me = me
        self.world = list(world)
        self.stats = CommStats()

    # -- implementation hooks ------------------------------------------------
    @abc.abstractmethod
    def _send(self, msg: Message, raw: bytes) -> None:
        ...

    @abc.abstractmethod
    def _recv(self, frm: str, tag: str) -> Message:
        ...

    # -- public API ----------------------------------------------------------
    def send(self, to: str, tag: str, payload: Payload,
             meta: Optional[Dict[str, str]] = None) -> None:
        payload = {k: np.asarray(v) for k, v in payload.items()}
        msg = Message(self.me, to, tag, payload, dict(meta or {}))
        t0 = time.perf_counter()
        raw = codec.encode(payload, {"sender": self.me, "tag": tag,
                                     **msg.meta})
        self._send(msg, raw)
        self.stats.record_send(tag, len(raw), time.perf_counter() - t0)

    def recv(self, frm: str, tag: str) -> Message:
        t0 = time.perf_counter()
        msg = self._recv(frm, tag)
        self.stats.record_recv(time.perf_counter() - t0)
        return msg

    def broadcast(self, tag: str, payload: Payload,
                  targets: Optional[Sequence[str]] = None,
                  meta: Optional[Dict[str, str]] = None) -> None:
        for t in (targets if targets is not None else self.world):
            if t != self.me:
                self.send(t, tag, payload, meta=meta)

    def gather(self, frm: Sequence[str], tag: str) -> List[Message]:
        return [self.recv(f, tag) for f in frm]

    def scatter(self, tag: str, payloads: Dict[str, Payload]) -> None:
        for to, payload in payloads.items():
            self.send(to, tag, payload)

    def close(self) -> None:      # pragma: no cover - overridden as needed
        pass

    @property
    def members(self) -> List[str]:
        return [w for w in self.world if w.startswith("member")]
