"""Multi-process communicator (the paper's third execution mode).

One ``multiprocessing.Queue`` mailbox per agent; messages are the codec
blobs (bytes pickle cheaply and keep payload accounting identical to the
other modes). Agent functions must be module-level picklables.

Shares the mailbox drain/reorder logic with the thread transport; the
async sender engine (isend futures) runs per process, so a member's
wire writes overlap its jax/HE compute with true parallelism here —
this is the mode where pipelined VFL escapes the GIL entirely.
"""
from __future__ import annotations

import multiprocessing as mp
import queue
from typing import Dict, Sequence, Tuple

from repro.comm.base import Message
from repro.comm.local import _MailboxCommunicator


class ProcessBus:
    def __init__(self, world: Sequence[str], ctx=None):
        self.world = list(world)
        ctx = ctx or mp.get_context("spawn")
        self.boxes: Dict[str, mp.Queue] = {w: ctx.Queue() for w in world}

    def communicator(self, me: str, timeout: float = 240.0,
                     comm_cfg=None) -> "ProcessCommunicator":
        return ProcessCommunicator(me, self, timeout=timeout,
                                   comm_cfg=comm_cfg)


class ProcessCommunicator(_MailboxCommunicator):
    def __init__(self, me: str, bus: ProcessBus, timeout: float = 240.0,
                 comm_cfg=None):
        super().__init__(me, bus.world, timeout=timeout,
                         comm_cfg=comm_cfg)
        self._boxes = bus.boxes
        self._pending: Dict[Tuple[str, str], list] = {}

    def _send(self, msg: Message, raw: bytes) -> None:
        self._boxes[msg.recipient].put(raw)

    def _box_get(self, timeout: float) -> bytes:
        try:
            return self._boxes[self.me].get(timeout=max(timeout, 1e-4))
        except queue.Empty:
            raise TimeoutError(f"{self.me}: mailbox empty") from None
