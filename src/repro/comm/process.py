"""Multi-process communicator (the paper's third execution mode).

One ``multiprocessing.Queue`` mailbox per agent; messages are the codec
blobs (bytes pickle cheaply and keep payload accounting identical to the
other modes). Agent functions must be module-level picklables.
"""
from __future__ import annotations

import multiprocessing as mp
from collections import defaultdict
from typing import Dict, Sequence, Tuple

from repro.comm import codec
from repro.comm.base import Message, PartyCommunicator


class ProcessBus:
    def __init__(self, world: Sequence[str], ctx=None):
        self.world = list(world)
        ctx = ctx or mp.get_context("spawn")
        self.boxes: Dict[str, mp.Queue] = {w: ctx.Queue() for w in world}

    def communicator(self, me: str) -> "ProcessCommunicator":
        return ProcessCommunicator(me, self)


class ProcessCommunicator(PartyCommunicator):
    def __init__(self, me: str, bus: ProcessBus):
        super().__init__(me, bus.world)
        self._boxes = bus.boxes
        self._pending: Dict[Tuple[str, str], list] = defaultdict(list)
        self._timeout = 240.0

    def _send(self, msg: Message, raw: bytes) -> None:
        self._boxes[msg.recipient].put(raw)

    def _recv(self, frm: str, tag: str) -> Message:
        key = (frm, tag)
        while True:
            if self._pending[key]:
                return self._pending[key].pop(0)
            raw = self._boxes[self.me].get(timeout=self._timeout)
            payload, meta = codec.decode(raw)
            sender = meta.pop("sender")
            mtag = meta.pop("tag")
            msg = Message(sender, self.me, mtag, payload, meta)
            if (sender, mtag) == key:
                return msg
            self._pending[(sender, mtag)].append(msg)
