"""RWKV-6 WKV Pallas TPU kernel: linear attention with data-dependent
per-channel decay and a (head_dim x head_dim) matrix state.

TPU adaptation: the reference CUDA kernel (one thread per channel,
state in registers, warp-level reuse) becomes a VMEM-resident state
matrix updated by VPU-wide rank-1 outer products. Grid:
(batch, heads, s/chunk) with the chunk dimension sequential; the state
S (dh x dh) persists in VMEM scratch across chunks. head_dim=64 keeps
S at 16 KiB fp32 — far under VMEM budget even with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sfin_ref, s_ref, *,
            chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                    # (dh,)

    def step(t, s):
        rt = r_ref[0, 0, t].astype(jnp.float32)         # (dh,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                  # (dh, dh) rank-1
        y_ref[0, 0, t] = rt @ (s + u[:, None] * kv)     # (dh,)
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, step, s_ref[...])
    s_ref[...] = s

    @pl.when(ic == n_chunks - 1)
    def _finish():
        sfin_ref[0, 0] = s


def rwkv6_wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, *, chunk: int = 64,
              interpret: bool = False):
    """r/k/v/w: (b, h, s, dh); u: (h, dh); w is the per-step decay in (0,1).

    Returns (y (b, h, s, dh) fp32, s_final (b, h, dh, dh) fp32).
    """
    b, h, s, dh = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk
    grid = (b, h, n_chunks)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, dh),
                            lambda ib, ih, ic: (ib, ih, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, dh), lambda ib, ih, ic: (ih, 0))],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, dh, dh), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
