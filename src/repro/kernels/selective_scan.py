"""Selective-scan (Mamba S6) Pallas TPU kernel.

TPU adaptation: the CUDA implementation parallelizes over threads within
an SM and keeps per-thread state in registers; here the recurrence state
h (block_d x n) lives in VMEM scratch and persists across the sequential
seq-chunk grid dimension, while (batch, d_inner blocks) are parallel grid
dimensions. Within a chunk the kernel steps time sequentially with a
``fori_loop`` — each step is a (block_d, n) VPU-vectorized update, so the
MXU-unfriendly recurrence stays wide on the VPU.

Grid: (b, d_inner/block_d, s/chunk), last dim sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, b_ref, c_ref, u_ref, a_ref, y_ref, hfin_ref, h_ref, *,
            chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                  # (bd, n)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)         # (bd,)
        u_t = u_ref[0, t].astype(jnp.float32)           # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)           # (n,)
        c_t = c_ref[0, t].astype(jnp.float32)           # (n,)
        decay = jnp.exp(dt_t[:, None] * a)              # (bd, n)
        h = decay * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h * c_t[None, :]).sum(axis=-1)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == n_chunks - 1)
    def _finish():
        hfin_ref[0] = h


def selective_scan(dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
                   u: jax.Array, a: jax.Array, *,
                   block_d: int = 256, chunk: int = 64,
                   interpret: bool = False):
    """dt/u: (b, s, di); bmat/cmat: (b, s, n); a: (di, n).

    Returns (y (b, s, di) fp32, h_final (b, di, n) fp32).
    """
    b, s, di = dt.shape
    n = a.shape[-1]
    block_d = min(block_d, di)
    chunk = min(chunk, s)
    assert di % block_d == 0 and s % chunk == 0
    n_chunks = s // chunk
    grid = (b, di // block_d, n_chunks)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, id_, ic: (ib, ic, id_)),   # dt
            pl.BlockSpec((1, chunk, n),
                         lambda ib, id_, ic: (ib, ic, 0)),     # B
            pl.BlockSpec((1, chunk, n),
                         lambda ib, id_, ic: (ib, ic, 0)),     # C
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, id_, ic: (ib, ic, id_)),   # u
            pl.BlockSpec((block_d, n),
                         lambda ib, id_, ic: (id_, 0)),        # A
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, id_, ic: (ib, ic, id_)),   # y
            pl.BlockSpec((1, block_d, n),
                         lambda ib, id_, ic: (ib, id_, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, bmat, cmat, u, a)
