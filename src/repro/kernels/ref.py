"""Pure-jnp oracles for every Pallas kernel.

These are intentionally straightforward (quadratic attention, sequential
scans) — they define the semantics the kernels must reproduce; tests
sweep shapes/dtypes and assert allclose kernel-vs-ref.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (b, h, sq, dh); k/v: (b, kvh, sk, dh). GQA by head grouping."""
    b, h, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, dh).astype(jnp.float32)
    scale = dh ** -0.5 if scale is None else scale
    s = jnp.einsum("bngqd,bnkd->bngqk", qg * scale, k.astype(jnp.float32))
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qi >= ki
    if window:
        mask &= qi - ki < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bnkd->bngqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, dh).astype(q.dtype)


def selective_scan_ref(dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
                       u: jax.Array, a: jax.Array,
                       h0: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Sequential S6 scan. dt/u: (b, s, di); bmat/cmat: (b, s, n); a: (di, n).

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * u_t * b_t;  y_t = h_t . c_t
    Returns (y (b, s, di) fp32, h_final (b, di, n) fp32).
    """
    b, s, di = dt.shape
    n = a.shape[-1]
    dt = dt.astype(jnp.float32)
    u = u.astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        dt_t, b_t, c_t, u_t = inp
        decay = jnp.exp(dt_t[..., None] * a)            # (b, di, n)
        h = decay * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (dt.swapaxes(0, 1), bmat.swapaxes(0, 1), cmat.swapaxes(0, 1),
          u.swapaxes(0, 1))
    h_t, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_t


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """RWKV-6 WKV. r/k/v/w: (b, h, s, dh); u: (h, dh); decay w in (0,1).

    y_t[i] = sum_j r_t[j] * (S[j,i] + u[j] k_t[j] v_t[i])
    S      = diag(w_t) S + k_t v_t^T
    Returns (y (b, h, s, dh) fp32, s_final (b, h, dh, dh) fp32).
    """
    b, h, s, dh = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    r, k, v = (x.astype(jnp.float32) for x in (r, k, v))
    w = w.astype(jnp.float32)
    u = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                              # (b, h, dh)
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhj,bhji->bhi", rt, S + u[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(x.swapaxes(0, 2).swapaxes(1, 2) for x in (r, k, v, w))
    # -> (s, b, h, dh)
    s_t, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 2, 0, 3), s_t


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped matmul: x (e, c, d) @ w (e, d, f) -> (e, c, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def quantize_int8_ref(x):
    """Per-row symmetric int8 quantization oracle. x: (rows, d)."""
    x32 = x.astype(jnp.float32)
    absmax = jnp.maximum(jnp.abs(x32).max(axis=1), 1e-12)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x32 / scale[:, None]), -127, 127)
    return q.astype(jnp.int8), scale
