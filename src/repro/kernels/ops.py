"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs in Python via the interpreter); on a real TPU the same calls
compile to Mosaic. ``interpret`` defaults to True iff no TPU is present,
so the same code path works in both environments.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rwkv6_wkv as _wkv
from repro.kernels import selective_scan as _ssm


@functools.cache
def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q: (b, h, s, dh); k/v: (b, kvh, s, dh)."""
    interpret = default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk",
                                             "interpret"))
def selective_scan(dt, bmat, cmat, u, a, *, block_d: int = 256,
                   chunk: int = 64, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _ssm.selective_scan(dt, bmat, cmat, u, a, block_d=block_d,
                               chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, *, chunk: int = 64,
              interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _wkv.rwkv6_wkv(r, k, v, w, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "block_d", "interpret"))
def moe_gmm(x, w, *, block_c: int = 128, block_f: int = 128,
            block_d: int = 256, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _gmm.moe_gmm(x, w, block_c=block_c, block_f=block_f,
                        block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def quantize_int8(x, *, block_r: int = 256, interpret: Optional[bool] = None):
    interpret = default_interpret() if interpret is None else interpret
    return _q.quantize_int8(x, block_r=block_r, interpret=interpret)
