"""Flash attention Pallas TPU kernel: causal / sliding-window, GQA.

TPU adaptation (DESIGN.md): the CUDA flash-attention algorithm is
re-blocked for VMEM/MXU — q/k/v tiles live in VMEM via BlockSpec, the
score tile (block_q x block_k) hits the MXU, and the online-softmax
running state (m, l, acc) sits in VMEM scratch that persists across the
sequential k-block grid dimension. Grid: (batch, heads, q_blocks,
k_blocks) with the last dimension "arbitrary" (sequential).

Layout: q (b, h, s, dh); k/v (b, kvh, s, dh) — GQA is expressed in the
k/v index_map (kv head = q head // group), so no KV replication is ever
materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, dh)
    s = q @ k.T                                       # (bq, bk) on the MXU

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_cur
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, dh)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v

    @pl.when(ik == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (b, h, sq, dh); k/v: (b, kvh, sk, dh) -> (b, h, sq, dh)."""
    b, h, sq, dh = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_q, n_k = sq // block_q, sk // block_k
    scale = dh ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
