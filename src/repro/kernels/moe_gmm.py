"""Grouped expert matmul (MoE) Pallas TPU kernel.

Computes out[e] = x[e] @ w[e] for every expert's capacity buffer — the
compute hot-spot of capacity-based MoE dispatch. TPU adaptation: each
(capacity-block x f-block) output tile accumulates over d-blocks on the
MXU with an fp32 VMEM scratch accumulator; the expert dimension is an
outer parallel grid axis, so expert-parallel sharding composes by simply
sharding the grid.

Grid: (e, c/block_c, f/block_f, d/block_d), reduction dim last
(sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(idd == n_d - 1)
    def _finish():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, *, block_c: int = 128,
            block_f: int = 128, block_d: int = 256,
            interpret: bool = False) -> jax.Array:
    """x: (e, c, d); w: (e, d, f) -> (e, c, f)."""
    e, c, d = x.shape
    f = w.shape[-1]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    assert c % block_c == 0 and f % block_f == 0 and d % block_d == 0
    grid = (e, c // block_c, f // block_f, d // block_d)

    kernel = functools.partial(_kernel, n_d=d // block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda ie, ic, if_, id_: (ie, ic, id_)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda ie, ic, if_, id_: (ie, id_, if_)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda ie, ic, if_, id_: (ie, ic, if_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
