"""Fused symmetric int8 quantization Pallas TPU kernel.

The device-side half of the compressed VFL exchange: before a member's
embeddings cross the pod boundary, each (rows-block x d) tile is absmax-
reduced and cast to int8 in ONE pass through VMEM — the un-fused jnp
version reads the tensor twice (absmax, then scale+round) from HBM.

Grid: (rows / block_r,). Per-row scales (row = token) are emitted
alongside the int8 payload.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                    # (block_r, d)
    absmax = jnp.maximum(jnp.abs(x).max(axis=1), 1e-12)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def quantize_int8(x: jax.Array, *, block_r: int = 256,
                  interpret: bool = False):
    """x: (rows, d) -> (q int8 (rows, d), scale f32 (rows,))."""
    rows, d = x.shape
    block_r = min(block_r, rows)
    assert rows % block_r == 0
    grid = (rows // block_r,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_r, d), lambda i: (i, 0)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
