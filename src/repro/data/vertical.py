"""Vertical feature partitioning: split one dataset into per-party
silos with misaligned ID spaces — the input expected by the VFL
protocols (matching is then part of the protocol, not the pipeline).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.protocols.base import MasterData, MemberData


def vertical_partition(ids: Sequence[str], x: np.ndarray, y: np.ndarray,
                       widths: Sequence[int], *, overlap: float = 1.0,
                       seed: int = 0, shuffle_members: bool = True
                       ) -> Tuple[MasterData, List[MemberData]]:
    """Split features (n, d) into [master | member0 | member1 | ...].

    ``widths``: feature count per member (master keeps the remainder).
    ``overlap``: fraction of master rows present in each member silo.
    """
    rng = np.random.default_rng(seed)
    n, d = x.shape
    assert sum(widths) < d, "master must keep at least one feature"
    cuts = np.cumsum([d - sum(widths)] + list(widths))
    master = MasterData(list(ids), y, x[:, :cuts[0]])
    members = []
    for j, w in enumerate(widths):
        xs = x[:, cuts[j]:cuts[j + 1]]
        m = int(overlap * n)
        keep = rng.permutation(n)[:m]
        if not shuffle_members:
            keep = np.sort(keep)
        members.append(MemberData([ids[i] for i in keep], xs[keep]))
    return master, members
