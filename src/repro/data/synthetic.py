"""Synthetic data generators.

- ``SyntheticRecsys``: an SBOL-like implicit-feedback dataset (users x
  19 banking products + dense user features) with a latent-factor ground
  truth, plus a MegaMarket-like second silo sharing a user subset — the
  paper's demo workload with the published Table-1 statistics, generated
  because the real datasets are not redistributable.
- ``make_lm_batches``: deterministic token streams for LM smoke tests
  and the trainer example (a Zipfian unigram stream with a repeated-
  n-gram structure so models can actually reduce loss).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.configs.vfl_recsys import VFLRecsysConfig


@dataclass
class SyntheticRecsys:
    ids: List[str]
    features: np.ndarray          # (n_users, n_features) master silo
    labels: np.ndarray            # (n_users, n_items) implicit feedback
    member_features: List[np.ndarray]
    member_ids: List[List[str]]


def make_recsys_silos(cfg: VFLRecsysConfig, seed: int = 0,
                      latent: int = 8) -> SyntheticRecsys:
    rng = np.random.default_rng(seed)
    n, items = cfg.n_users, cfg.n_items
    zu = rng.normal(size=(n, latent))                 # user latents
    zi = rng.normal(size=(items, latent))             # item latents
    logits = zu @ zi.T + rng.normal(scale=0.5, size=(n, items))
    # calibrate threshold to the published interaction density
    density = cfg.n_interactions / (n * items)
    thresh = np.quantile(logits, 1 - density)
    labels = (logits > thresh).astype(np.float32)

    def silo(width: int, k: int) -> np.ndarray:
        w = rng.normal(size=(latent, width))
        raw = zu @ w + rng.normal(scale=1.0, size=(n, width))
        # standardize: silo features are unit-variance (keeps VFL GD
        # stable at textbook learning rates on 1k+-dim silos)
        return ((raw - raw.mean(0)) / (raw.std(0) + 1e-6)).astype(np.float32)

    features = silo(cfg.n_other_features, 0)
    ids = [f"user{i:07d}" for i in range(n)]

    member_features, member_ids = [], []
    for j, width in enumerate(cfg.member_features):
        m = int(cfg.id_overlap * n)
        keep = np.sort(rng.permutation(n)[:m])
        extra = rng.permutation(n)[: n - m]           # non-overlapping noise
        feats = silo(width, j + 1)[keep]
        member_features.append(feats)
        member_ids.append([ids[i] for i in keep])
    return SyntheticRecsys(ids, features, labels, member_features,
                           member_ids)


def make_lm_batches(vocab: int, batch: int, seq: int, steps: int,
                    seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Zipfian stream with injected bigram structure (learnable)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1 / ranks) / (1 / ranks).sum()
    follow = rng.integers(0, vocab, size=vocab)       # deterministic bigrams
    for _ in range(steps):
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        # half the positions follow the deterministic bigram table
        mask = rng.random((batch, seq)) < 0.5
        nxt = follow[toks[:, :-1]]
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
