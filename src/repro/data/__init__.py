from repro.data.synthetic import (  # noqa: F401
    SyntheticRecsys, make_recsys_silos, make_lm_batches,
)
from repro.data.vertical import vertical_partition  # noqa: F401
