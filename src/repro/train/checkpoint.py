"""Checkpointing: param/optimizer pytrees -> .npz + JSON tree manifest.

Pure-python (no orbax offline): leaves are saved flat with path-derived
keys; restore rebuilds the exact tree. Sharded arrays are gathered
implicitly by np.asarray (process-local; fine for CPU and single-host).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(path: str, step: int, params, opt_state=None, extra=None) -> None:
    p = pathlib.Path(path)
    p.mkdir(parents=True, exist_ok=True)
    blobs: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "extra": extra or {}}
    for prefix, tree in (("params", params), ("opt", opt_state)):
        if tree is None:
            continue
        flat = _flatten(tree)
        manifest[prefix] = jax.tree.map(lambda _: 0, tree)  # structure only
        for k, v in flat.items():
            arr = np.asarray(v)
            if arr.dtype == jax.numpy.bfloat16:
                blobs[f"{prefix}/{k}|bf16"] = arr.astype(np.float32)
            else:
                blobs[f"{prefix}/{k}"] = arr
    np.savez(p / f"step_{step:08d}.npz", **blobs)
    (p / "manifest.json").write_text(json.dumps(
        {"step": step, "extra": extra or {}}))


def latest_step(path: str) -> int:
    p = pathlib.Path(path)
    ckpts = sorted(p.glob("step_*.npz"))
    if not ckpts:
        return -1
    return int(ckpts[-1].stem.split("_")[1])


def restore(path: str, step: int, params_like, opt_like=None
            ) -> Tuple[Any, Any]:
    """Restore into the structure of ``params_like`` / ``opt_like``."""
    p = pathlib.Path(path)
    data = np.load(p / f"step_{step:08d}.npz")
    loaded = {}
    for k in data.files:
        if k.endswith("|bf16"):
            loaded[k[:-5]] = jax.numpy.asarray(data[k], jax.numpy.bfloat16)
        else:
            loaded[k] = data[k]

    def rebuild(prefix, like):
        if like is None:
            return None
        flat = _flatten(like)
        out = {k: loaded[f"{prefix}/{k}"] for k in flat}
        leaves = [out[k] for k in flat]
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)

    return rebuild("params", params_like), rebuild("opt", opt_like)
