"""Metric sinks — the offline stand-in for the paper's MLflow/Prometheus
stack: same counters (ML metrics, payload bytes, exchange times), CSV +
JSONL backends, pluggable interface.
"""
from __future__ import annotations

import csv
import json
import pathlib
import time
from typing import Any, Dict, List, Optional


class MetricsLogger:
    def __init__(self, out_dir: Optional[str] = None, run: str = "run"):
        self.records: List[Dict[str, Any]] = []
        self.out_dir = pathlib.Path(out_dir) if out_dir else None
        self.run = run
        self._t0 = time.perf_counter()
        if self.out_dir:
            self.out_dir.mkdir(parents=True, exist_ok=True)
            self._jsonl = open(self.out_dir / f"{run}.jsonl", "w")
        else:
            self._jsonl = None

    def log(self, step: int, **metrics):
        rec = {"step": step, "t": round(time.perf_counter() - self._t0, 4),
               **{k: (float(v) if hasattr(v, "__float__") else v)
                  for k, v in metrics.items()}}
        self.records.append(rec)
        if self._jsonl:
            self._jsonl.write(json.dumps(rec) + "\n")
            self._jsonl.flush()

    def close(self):
        if self._jsonl:
            self._jsonl.close()
        if self.out_dir and self.records:
            keys = sorted({k for r in self.records for k in r})
            with open(self.out_dir / f"{self.run}.csv", "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=keys)
                w.writeheader()
                w.writerows(self.records)

    def last(self) -> Dict[str, Any]:
        return self.records[-1] if self.records else {}
