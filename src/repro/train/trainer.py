"""Training loop: any registered architecture, any mesh (or none),
checkpointing + metrics. Used by examples/quickstart.py and the
end-to-end driver (examples/train_lm.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as ST
from repro.models import params as PRM, transformer as T
from repro.sharding.rules import MeshRules
from repro.train import checkpoint as CKPT
from repro.train import optimizer as O
from repro.train.metrics import MetricsLogger


@dataclass
class TrainJob:
    cfg: ModelConfig
    lr: float = 3e-4
    steps: int = 100
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    metrics_dir: Optional[str] = None
    rules: Optional[MeshRules] = None
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    accum_steps: int = 1


def train(job: TrainJob, batches: Iterator[Dict[str, np.ndarray]]
          ) -> Dict[str, Any]:
    cfg = job.cfg
    spec = T.model_spec(cfg)
    params = PRM.init_tree(spec, jax.random.key(job.seed), job.param_dtype)
    opt = O.make_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    sched = O.warmup_cosine(job.lr, warmup=max(1, job.steps // 10),
                            total=job.steps)

    raw_step = ST.make_train_step(cfg, opt, lr=job.lr, rules=job.rules,
                                  compute_dtype=job.compute_dtype,
                                  accum_steps=job.accum_steps)
    step_fn = jax.jit(raw_step, donate_argnums=(0, 1))

    logger = MetricsLogger(job.metrics_dir, run=f"train_{cfg.arch_id}")
    t0 = time.perf_counter()
    last_metrics: Dict[str, Any] = {}
    for i, batch in enumerate(batches):
        if i >= job.steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        if i % job.log_every == 0 or i == job.steps - 1:
            last_metrics = {k: float(v) for k, v in metrics.items()}
            logger.log(i, **last_metrics,
                       tokens_per_s=(np.prod(jb["tokens"].shape)
                                     * (i + 1)) / (time.perf_counter() - t0))
        if job.ckpt_every and job.ckpt_dir and i and i % job.ckpt_every == 0:
            CKPT.save(job.ckpt_dir, i, params, opt_state)
    if job.ckpt_dir:
        CKPT.save(job.ckpt_dir, job.steps, params, opt_state)
    logger.close()
    return {"params": params, "metrics": last_metrics,
            "history": logger.records}
