"""Recommendation metrics for the demo workload (paper §4 evaluates a
recommender): AUC, precision@k, NDCG@k over multi-label implicit
feedback, plus LM perplexity for the training driver."""
from __future__ import annotations

from typing import Dict

import numpy as np


def auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Micro-averaged ROC-AUC via the rank statistic."""
    s = scores.ravel()
    y = labels.ravel().astype(bool)
    n_pos = int(y.sum())
    n_neg = y.size - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    order = np.argsort(s, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, s.size + 1)
    return float((ranks[y].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def precision_at_k(scores: np.ndarray, labels: np.ndarray,
                   k: int = 5) -> float:
    """Mean per-user precision@k. scores/labels: (users, items)."""
    k = min(k, scores.shape[1])
    top = np.argsort(-scores, axis=1)[:, :k]
    hits = np.take_along_axis(labels, top, axis=1)
    return float(hits.mean())


def ndcg_at_k(scores: np.ndarray, labels: np.ndarray, k: int = 5) -> float:
    k = min(k, scores.shape[1])
    top = np.argsort(-scores, axis=1)[:, :k]
    gains = np.take_along_axis(labels, top, axis=1)
    discounts = 1.0 / np.log2(np.arange(2, k + 2))
    dcg = (gains * discounts).mean(axis=1) if k else 0.0
    ideal = np.sort(labels, axis=1)[:, ::-1][:, :k]
    idcg = (ideal * discounts).mean(axis=1)
    mask = idcg > 0
    if not mask.any():
        return 0.0
    return float((dcg[mask] / idcg[mask]).mean())


def recsys_report(scores: np.ndarray, labels: np.ndarray,
                  k: int = 5) -> Dict[str, float]:
    return {
        "auc": auc(scores, labels),
        f"precision@{k}": precision_at_k(scores, labels, k),
        f"ndcg@{k}": ndcg_at_k(scores, labels, k),
    }


def perplexity(mean_nll: float) -> float:
    return float(np.exp(min(mean_nll, 30.0)))
