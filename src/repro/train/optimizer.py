"""Optimizers over param pytrees, axes-aware for sharded dry-runs.

Each optimizer provides ``init(params) -> state`` and
``update(grads, state, params, lr) -> (new_params, new_state)``; plus
``state_axes(axes_tree) -> axes for state`` so the dry-run can resolve
NamedShardings for optimizer slots (Adafactor's factored slots drop a
dim, so their axes are derived from the param axes).

AdamW for <=20B archs; Adafactor (factored second moment, no first
moment) for jamba-398B / internvl-76B where Adam slots would not fit
16 GB/chip (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _tmap(fn, *trees):
    return jax.tree.map(fn, *trees)


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array],
                     Tuple[PyTree, PyTree]]
    state_axes: Callable[[PyTree], PyTree]


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mom": _tmap(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        mom = _tmap(lambda m, g: momentum * m + g.astype(m.dtype),
                    state["mom"], grads)
        def upd(p, m):
            step = m + weight_decay * p.astype(m.dtype)
            return (p.astype(jnp.float32) - lr * step.astype(jnp.float32)
                    ).astype(p.dtype)
        return _tmap(upd, params, mom), {"mom": mom}

    def state_axes(axes):
        return {"mom": axes}

    return Optimizer("sgdm", init, update, state_axes)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state["v"], grads)

        def upd(p, m_, v_):
            mh = m_ / (1 - b1 ** c)
            vh = v_ / (1 - b2 ** c)
            step = mh / (jnp.sqrt(vh) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = _tmap(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": count}

    def state_axes(axes):
        return {"m": axes, "v": axes, "count": ()}

    return Optimizer("adamw", init, update, state_axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, beta1=0)
# ---------------------------------------------------------------------------


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def slot(p):
            if _factored(p):
                return {
                    "v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": _tmap(slot, params,
                               ), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd(p, g, slot):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                v_row = beta2 * slot["v_row"] + (1 - beta2) * g2.mean(-1)
                v_col = beta2 * slot["v_col"] + (1 - beta2) * g2.mean(-2)
                row_mean = v_row.mean(-1, keepdims=True)
                r = (v_row / jnp.maximum(row_mean, eps))[..., None]
                u = g * jax.lax.rsqrt(jnp.maximum(r, eps)) \
                    * jax.lax.rsqrt(jnp.maximum(v_col, eps))[..., None, :]
                new_slot = {"v_row": v_row, "v_col": v_col}
            else:
                v = beta2 * slot["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_slot = {"v": v}
            norm = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, norm / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_slot

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        sflat = treedef.flatten_up_to(state["slots"])
        out = [upd(p, g, s) for p, g, s in zip(flat, gflat, sflat)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_slots = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_params, {"slots": new_slots, "count": count}

    def state_axes(axes):
        def slot_axes(ax):
            if len(ax) >= 2:
                return {"v_row": ax[:-1], "v_col": ax[:-2] + ax[-1:]}
            return {"v": ax}
        return {"slots": jax.tree.map(
            slot_axes, axes,
            is_leaf=lambda x: isinstance(x, tuple)), "count": ()}

    return Optimizer("adafactor", init, update, state_axes)


def make_optimizer(name: str) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name]()


def warmup_cosine(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
