"""Persistent federated inference on top of :class:`VFLJob`.

The training driver's predict phase (PR 2) answers one caller at a
time: every query pays a ``ctrl/phase`` handshake and the caller owns
the master until its scores return. Serving millions of recsys users
needs the opposite shape — the federation stays parked in a long-lived
predict session (``serve_open``), concurrent queries are admitted into
a bounded queue, coalesced into one ``predict/rows`` round across the
members, and de-multiplexed back to their callers:

    callers ──submit──> admission queue ──coalesce──> one federated
    round (``serve_query``; duplicate rows cross the wire once) ──demux
    ──> per-caller scores

Three knobs shape the latency/throughput trade (docs/serving.md):

* ``max_batch`` — row budget per federated round; whole requests are
  packed until the budget is hit.
* ``max_wait_ms`` — how long the batcher holds an under-full round open
  for more arrivals. 0 favors latency, a few ms favors QPS.
* ``admission_limit`` — queued-row bound; beyond it ``submit`` fails
  fast with :class:`AdmissionError` instead of building an unbounded
  backlog (tail latency stays bounded under overload).

Every request carries a trace (admission -> coalesce -> exchange ->
dequeue timestamps) aggregated by :class:`ServeStats`, the serving
sibling of ``CommStats``. A thin length-prefixed-safetensors TCP
frontend (:class:`ServeFrontend` / :class:`ServeClient`) exposes the
engine on a port so ``repro.launch.cluster`` can deploy it from a
``[serve]`` spec section.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.comm import codec

__all__ = ["ServeCfg", "ServeStats", "AdmissionError", "FederatedServer",
           "ServeFrontend", "ServeClient"]


class AdmissionError(RuntimeError):
    """Raised by ``submit``/``query`` when the admission queue is full
    (queued rows would exceed ``ServeCfg.admission_limit``). Callers
    should back off and retry; the server sheds load instead of letting
    the backlog grow without bound."""


@dataclass
class ServeCfg:
    """Knobs for :class:`FederatedServer` (mirrored by the cluster
    spec's ``[serve]`` section)."""

    max_batch: int = 64           # row budget per federated round
    max_wait_ms: float = 2.0      # batcher hold time for an under-full round
    admission_limit: int = 4096   # queued-row bound before shedding
    cache_rows: int = 0           # member embed-cache capacity (0 = off)
    host: str = "127.0.0.1"       # TCP frontend bind address
    port: int = 0                 # frontend port (0 = engine only, no TCP)


@dataclass
class _Pending:
    """One admitted request travelling through the batcher."""

    rows: np.ndarray
    done: threading.Event = field(default_factory=threading.Event)
    scores: Optional[np.ndarray] = None
    err: Optional[BaseException] = None
    # trace stamps (time.perf_counter): admitted, picked into a round,
    # round sent to the federation, scores handed back
    t_admit: float = 0.0
    t_coalesce: float = 0.0
    t_exchange: float = 0.0
    t_done: float = 0.0

    def trace(self) -> Dict[str, float]:
        return {"queue_s": self.t_coalesce - self.t_admit,
                "exchange_s": self.t_done - self.t_exchange,
                "total_s": self.t_done - self.t_admit}


class ServeStats:
    """CommStats-style counters for the serving path. Latencies keep a
    bounded reservoir (most recent ``window`` requests) so percentile
    math stays O(window) regardless of uptime."""

    def __init__(self, window: int = 4096):
        self.requests = 0
        self.rejected = 0
        self.batches = 0
        self.rows_in = 0            # rows admitted
        self.rows_wire = 0          # rows actually sent (post-dedupe)
        self.queue_s = 0.0          # summed admission -> coalesce wait
        self.exchange_s = 0.0       # summed round exchange time
        self._lat = deque(maxlen=window)
        self._lock = threading.Lock()

    def record(self, p: "_Pending") -> None:
        with self._lock:
            self.requests += 1
            self.rows_in += len(p.rows)
            t = p.trace()
            self.queue_s += t["queue_s"]
            self.exchange_s += t["exchange_s"]
            self._lat.append(t["total_s"])

    def record_batch(self, n_rows_wire: int) -> None:
        with self._lock:
            self.batches += 1
            self.rows_wire += n_rows_wire

    def record_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def latency_s(self, q: float) -> float:
        """Latency quantile (0..1) over the recent-request window."""
        with self._lock:
            lat = sorted(self._lat)
        if not lat:
            return 0.0
        return lat[min(len(lat) - 1, int(q * len(lat)))]

    def as_dict(self) -> Dict[str, Any]:
        with self._lock:
            avg_batch = self.rows_wire / max(self.batches, 1)
            d = {"requests": self.requests, "rejected": self.rejected,
                 "batches": self.batches, "rows_in": self.rows_in,
                 "rows_wire": self.rows_wire,
                 "avg_batch_rows": round(avg_batch, 2),
                 "queue_s": round(self.queue_s, 4),
                 "exchange_s": round(self.exchange_s, 4)}
        d["p50_ms"] = round(self.latency_s(0.50) * 1e3, 3)
        d["p99_ms"] = round(self.latency_s(0.99) * 1e3, 3)
        return d


class FederatedServer:
    """Admission + dynamic batching around an open serve session.

    ``engine`` is anything with the ``serve_open`` / ``serve_query`` /
    ``serve_close`` trio — a :class:`repro.core.party.VFLJob` (agents
    in-process or spawned) or a bare ``PartyMaster`` whose peers run
    elsewhere. The server owns the session: :meth:`start` opens it,
    :meth:`stop` drains the queue and closes it.

    Thread-safe: any number of caller threads may :meth:`query`
    concurrently; one batcher thread serializes the federated rounds
    (the VFL round itself is single-flight — members answer EVAL rounds
    in announcement order)."""

    def __init__(self, engine: Any, cfg: Optional[ServeCfg] = None):
        self.engine = engine
        self.cfg = cfg or ServeCfg()
        self.stats = ServeStats()
        self._cv = threading.Condition()
        self._queue: "deque[_Pending]" = deque()
        self._queued_rows = 0
        self._stopping = False
        self._failed: Optional[BaseException] = None
        self._batcher: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FederatedServer":
        """Open the serve session and start the batcher thread."""
        self.engine.serve_open()
        self._batcher = threading.Thread(target=self._batch_loop,
                                         name="serve-batcher",
                                         daemon=True)
        self._batcher.start()
        return self

    def stop(self, timeout: float = 30.0) -> Dict[str, Any]:
        """Drain queued requests, close the serve session, and return
        the final :class:`ServeStats` snapshot."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._batcher is not None:
            self._batcher.join(timeout)
        if self._failed is None:
            self.engine.serve_close()
        return self.stats.as_dict()

    def __enter__(self) -> "FederatedServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- caller side ---------------------------------------------------------
    def submit(self, rows: Sequence[int]) -> _Pending:
        """Admit one query (non-blocking). Returns the pending handle;
        wait on ``handle.done`` and read ``handle.scores``. Raises
        :class:`AdmissionError` when the queue is over budget."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        p = _Pending(rows=rows)
        with self._cv:
            if self._failed is not None:
                raise RuntimeError("serving session failed"
                                   ) from self._failed
            if self._stopping:
                raise RuntimeError("server is stopping")
            if self._queued_rows + len(rows) > self.cfg.admission_limit:
                self.stats.record_reject()
                raise AdmissionError(
                    f"admission queue full ({self._queued_rows} rows "
                    f"queued, limit {self.cfg.admission_limit})")
            p.t_admit = time.perf_counter()
            self._queue.append(p)
            self._queued_rows += len(rows)
            self._cv.notify_all()
        return p

    def query(self, rows: Sequence[int],
              timeout: float = 60.0) -> np.ndarray:
        """Blocking federated inference for ``rows``: admit, ride a
        coalesced round, return this caller's score slice."""
        p = self.submit(rows)
        if not p.done.wait(timeout):
            raise TimeoutError(f"serve query not answered in {timeout}s")
        if p.err is not None:
            raise RuntimeError("federated round failed") from p.err
        return p.scores

    # -- batcher -------------------------------------------------------------
    def _take_batch(self) -> List[_Pending]:
        """Block for the first request, then hold the round open up to
        ``max_wait_ms`` packing whole requests until ``max_batch`` rows.
        Returns [] only when stopping with an empty queue."""
        cfg = self.cfg
        with self._cv:
            while not self._queue and not self._stopping:
                self._cv.wait(0.05)
            if not self._queue:
                return []
            batch = [self._queue.popleft()]
            nrows = len(batch[0].rows)
            deadline = time.perf_counter() + cfg.max_wait_ms * 1e-3
            while nrows < cfg.max_batch:
                if self._queue:
                    nxt = self._queue[0]
                    if nrows + len(nxt.rows) > cfg.max_batch:
                        break
                    batch.append(self._queue.popleft())
                    nrows += len(nxt.rows)
                    continue
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or self._stopping:
                    break
                self._cv.wait(remaining)
            self._queued_rows -= nrows
        now = time.perf_counter()
        for p in batch:
            p.t_coalesce = now
        return batch

    def _batch_loop(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                return
            rows = np.concatenate([p.rows for p in batch])
            # duplicates across coalesced callers cross the wire once
            # (Driver.predict_now dedupes); count the post-dedupe rows
            # the members actually see
            self.stats.record_batch(len(np.unique(rows)))
            t_ex = time.perf_counter()
            for p in batch:
                p.t_exchange = t_ex
            try:
                scores = np.asarray(self.engine.serve_query(rows=rows))
            except BaseException as e:
                with self._cv:
                    self._failed = e
                    self._stopping = True
                for p in batch + list(self._queue):
                    p.err = e
                    p.done.set()
                self._queue.clear()
                return
            t_done = time.perf_counter()
            lo = 0
            for p in batch:
                p.scores = scores[lo:lo + len(p.rows)]
                lo += len(p.rows)
                p.t_done = t_done
                self.stats.record(p)
                p.done.set()


# ---------------------------------------------------------------------------
# TCP frontend: length-prefixed safetensors request/reply
# ---------------------------------------------------------------------------
# Frame = 8-byte LE length + codec.encode payload. Request metadata op:
#   "query" {"rows": int64[n]} -> {"scores": float[n, items]}
#   "stats" {}                 -> metadata {"stats": json}
# Errors return metadata {"error": str}. One in-flight request per
# connection; concurrent callers open concurrent connections (the
# engine coalesces them into shared rounds).

_MAX_REQ = 64 << 20


def _read_frame(conn: socket.socket) -> Optional[bytes]:
    hdr = b""
    while len(hdr) < 8:
        chunk = conn.recv(8 - len(hdr))
        if not chunk:
            return None
        hdr += chunk
    (n,) = struct.unpack("<Q", hdr)
    if n > _MAX_REQ:
        raise ValueError(f"frame of {n} bytes exceeds {_MAX_REQ}")
    buf = bytearray()
    while len(buf) < n:
        chunk = conn.recv(min(1 << 16, n - len(buf)))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return bytes(buf)


def _write_frame(conn: socket.socket, payload: bytes) -> None:
    conn.sendall(struct.pack("<Q", len(payload)) + payload)


class ServeFrontend:
    """TCP face of a :class:`FederatedServer` — what the cluster
    launcher's ``serve`` phase binds from the ``[serve]`` spec section.
    Thread-per-connection; each query blocks its connection while the
    engine coalesces it with concurrent callers' rows."""

    def __init__(self, server: FederatedServer,
                 host: Optional[str] = None, port: Optional[int] = None):
        cfg = server.cfg
        self.server = server
        self._sock = socket.create_server(
            (host or cfg.host, cfg.port if port is None else port))
        self._sock.listen(128)
        self.address = self._sock.getsockname()[:2]
        self._closing = False
        self._threads: List[threading.Thread] = []
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          name="serve-accept",
                                          daemon=True)
        self._acceptor.start()

    @property
    def port(self) -> int:
        return self.address[1]

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            with conn:
                while True:
                    blob = _read_frame(conn)
                    if blob is None:
                        return
                    tensors, meta = codec.decode(blob)
                    _write_frame(conn, self._answer(tensors, meta))
        except (ConnectionError, OSError, ValueError):
            return

    def _answer(self, tensors: Dict[str, np.ndarray],
                meta: Dict[str, str]) -> bytes:
        op = meta.get("op", "query")
        try:
            if op == "query":
                scores = self.server.query(
                    tensors["rows"],
                    timeout=float(meta.get("timeout", 60.0)))
                return codec.encode(
                    {"scores": np.ascontiguousarray(scores)})
            if op == "stats":
                return codec.encode(
                    {}, {"stats": json.dumps(self.server.stats.as_dict())})
            return codec.encode({}, {"error": f"unknown op {op!r}"})
        except AdmissionError as e:
            return codec.encode({}, {"error": str(e),
                                     "rejected": "1"})
        except BaseException as e:
            return codec.encode({}, {"error": f"{type(e).__name__}: {e}"})

    def close(self) -> None:
        self._closing = True
        try:
            self._sock.close()
        except OSError:
            pass
        self._acceptor.join(5)


class ServeClient:
    """Minimal blocking client for :class:`ServeFrontend`. One
    connection, one in-flight request; load generators open one client
    per worker."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self._addr = (host, int(port))
        self._timeout = timeout
        self._conn: Optional[socket.socket] = None

    def _connect(self) -> socket.socket:
        if self._conn is None:
            c = socket.create_connection(self._addr,
                                         timeout=self._timeout)
            c.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conn = c
        return self._conn

    def _roundtrip(self, payload: bytes):
        conn = self._connect()
        try:
            _write_frame(conn, payload)
            blob = _read_frame(conn)
        except (ConnectionError, OSError):
            self.close()
            raise
        if blob is None:
            self.close()
            raise ConnectionError("serve frontend closed the connection")
        return codec.decode(blob)

    def query(self, rows: Sequence[int]) -> np.ndarray:
        """Score ``rows`` over the wire; blocks for the coalesced
        round. Raises :class:`AdmissionError` on shed load."""
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        tensors, meta = self._roundtrip(
            codec.encode({"rows": rows}, {"op": "query"}))
        if "error" in meta:
            if meta.get("rejected"):
                raise AdmissionError(meta["error"])
            raise RuntimeError(meta["error"])
        return tensors["scores"]

    def stats(self) -> Dict[str, Any]:
        """Fetch the server's live :class:`ServeStats` snapshot."""
        _, meta = self._roundtrip(codec.encode({}, {"op": "stats"}))
        if "error" in meta:
            raise RuntimeError(meta["error"])
        return json.loads(meta["stats"])

    def close(self) -> None:
        """Close the connection (idempotent)."""
        if self._conn is not None:
            try:
                self._conn.close()
            finally:
                self._conn = None

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
