from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.federated import (AdmissionError,  # noqa: F401
                                   FederatedServer, ServeCfg, ServeClient,
                                   ServeFrontend, ServeStats)
