"""Batched serving engine: prefill + decode with per-family caches.

Serves any registered architecture. ``generate`` prefappends the prompt
through the training forward pass (teacher-forced fill of the cache via
repeated decode steps for simplicity and correctness across all cache
families — SWA ring, MLA latent, SSM state), then samples new tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import params as PRM, transformer as T


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any
    max_seq: int = 512
    dtype: Any = jnp.float32

    def __post_init__(self):
        cfg = self.cfg
        self._decode = jax.jit(
            lambda p, tok, cache, idx, memory: T.decode_step(
                cfg, p, tok, cache, idx, memory, self.dtype))

    def init_cache(self, batch: int):
        return T.init_cache(self.cfg, batch, self.max_seq, self.dtype)

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 temperature: float = 0.0, seed: int = 0,
                 memory: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (b, s0) int32 -> (b, s0 + n_new)."""
        b, s0 = prompts.shape
        cache = self.init_cache(b)
        toks = jnp.asarray(prompts, jnp.int32)
        logits = None
        for i in range(s0):
            logits, cache = self._decode(self.params, toks[:, i:i + 1],
                                         cache, i, memory)
        out = [toks]
        key = jax.random.key(seed)
        for j in range(n_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, -1] / temperature, axis=-1)[:, None]
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(nxt.astype(jnp.int32))
            logits, cache = self._decode(self.params, out[-1], cache,
                                         s0 + j, memory)
        return np.asarray(jnp.concatenate(out, axis=1))

    def score(self, tokens: np.ndarray) -> float:
        """Mean NLL of a token batch under the model (prefill path)."""
        batch = {"tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
                 "labels": jnp.asarray(tokens[:, 1:], jnp.int32)}
        if self.cfg.encoder is not None:
            raise NotImplementedError("use generate() for enc-dec")
        loss, _ = T.loss_fn(self.cfg, self.params, batch, self.dtype)
        return float(loss)
