"""Model assembly: block -> stack (scan over repeats) -> LM / enc-dec.

One code path serves all 10 assigned architectures:

- dense / moe / ssm / hybrid decoder-only LMs (glm4, qwen3, granite,
  deepseek, minicpm3, h2o-danube, rwkv6, jamba),
- encoder-decoder with cross-attention + audio frontend stub (whisper),
- VLM with vision-patch prefix + text tokens (internvl2).

Layer stacks are stored *stacked* (leading scan dim) and iterated with
``jax.lax.scan`` so HLO size is independent of depth; each block is
optionally wrapped in ``jax.checkpoint`` per ``cfg.remat_policy``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mamba, mla, moe, params as P
from repro.models import rwkv
from repro.sharding import constrain

Params = Any

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def _is_ln(cfg: ModelConfig) -> bool:
    """Whisper-family uses layernorm + biased (non-gated) MLP."""
    return cfg.encoder is not None


def _norm_spec(cfg):
    return layers.layernorm_spec(cfg.d_model) if _is_ln(cfg) \
        else layers.rmsnorm_spec(cfg.d_model)


def _norm(cfg, p, x):
    fn = layers.layernorm if _is_ln(cfg) else layers.rmsnorm
    return fn(p, x, cfg.norm_eps)


def block_spec(cfg: ModelConfig, mixer: str, ffn: str, cross: bool = False):
    spec: Dict[str, Any] = {"norm1": _norm_spec(cfg)}
    if mixer == "attn":
        if cfg.attention == "mla":
            spec["mixer"] = mla.mla_spec(cfg)
        else:
            spec["mixer"] = attention.attention_spec(cfg)
    elif mixer == "mamba":
        spec["mixer"] = mamba.mamba_spec(cfg)
    elif mixer == "rwkv":
        spec["mixer"] = rwkv.rwkv_spec(cfg)
    if cross:
        spec["norm_x"] = _norm_spec(cfg)
        spec["cross"] = attention.attention_spec(cfg, cross=True)
    spec["norm2"] = _norm_spec(cfg)
    if ffn == "moe":
        spec["ffn"] = moe.moe_spec(cfg)
    elif _is_ln(cfg):
        spec["ffn"] = layers.mlp_spec(cfg.d_model, cfg.d_ff)
    else:
        spec["ffn"] = layers.gated_mlp_spec(cfg.d_model, cfg.d_ff)
    return spec


def model_spec(cfg: ModelConfig):
    cross = cfg.encoder is not None
    spec: Dict[str, Any] = {
        "embed": layers.embedding_spec(cfg.vocab, cfg.d_model),
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = layers.unembed_spec(cfg.vocab, cfg.d_model)
    for i, (mixer, ffn) in enumerate(cfg.prefix_pattern):
        spec[f"prefix{i}"] = block_spec(cfg, mixer, ffn, cross)
    stacked = {}
    for i, (mixer, ffn) in enumerate(cfg.block_pattern):
        stacked[f"pos{i}"] = P.stack(block_spec(cfg, mixer, ffn, cross),
                                     cfg.n_repeats)
    spec["blocks"] = stacked
    if cfg.encoder is not None:
        enc_block = {
            "norm1": _norm_spec(cfg),
            "mixer": attention.attention_spec(cfg),
            "norm2": _norm_spec(cfg),
            "ffn": layers.mlp_spec(cfg.d_model, cfg.d_ff),
        }
        spec["encoder"] = {
            "blocks": P.stack(enc_block, cfg.encoder.n_layers),
            "final_norm": _norm_spec(cfg),
        }
    return spec


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, mixer: str, ffn: str, p, x,
                 memory=None, positions=None) -> Tuple[jax.Array, Dict]:
    aux = {}
    h = _norm(cfg, p["norm1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            h = mla.mla_self_attention(cfg, p["mixer"], h,
                                       positions=positions)
        else:
            h = attention.self_attention(cfg, p["mixer"], h,
                                         positions=positions)
    elif mixer == "mamba":
        h = mamba.mamba_mixer(cfg, p["mixer"], h)
    elif mixer == "rwkv":
        h = rwkv.rwkv_mixer(cfg, p["mixer"], h)
    x = x + h
    if memory is not None and "cross" in p:
        x = x + attention.cross_attention(cfg, p["cross"],
                                          _norm(cfg, p["norm_x"], x), memory)
    h = _norm(cfg, p["norm2"], x)
    if ffn == "moe":
        h, aux = moe.moe_ffn(cfg, p["ffn"], h, cfg.act)
    elif _is_ln(cfg):
        h = layers.mlp(p["ffn"], h, cfg.act)
    else:
        h = layers.gated_mlp(p["ffn"], h, cfg.act)
    x = x + h
    x = constrain(x, ("batch", "seq", "embed"))
    return x, aux


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _stack_forward(cfg: ModelConfig, params, x, memory=None, positions=None
                   ) -> Tuple[jax.Array, Dict]:
    """Prefix blocks (unrolled) + pattern blocks (lax.scan over repeats)."""
    aux_losses = {"load_balance": jnp.zeros((), jnp.float32),
                  "router_z": jnp.zeros((), jnp.float32)}

    def add_aux(aux):
        for k in aux_losses:
            if k in aux:
                aux_losses[k] = aux_losses[k] + aux[k]

    for i, (mixer, ffn) in enumerate(cfg.prefix_pattern):
        x, aux = _apply_block(cfg, mixer, ffn, params[f"prefix{i}"], x,
                              memory, positions)
        add_aux(aux)

    def unit(x, unit_params):
        aux_acc = {"load_balance": jnp.zeros((), jnp.float32),
                   "router_z": jnp.zeros((), jnp.float32)}
        for i, (mixer, ffn) in enumerate(cfg.block_pattern):
            x, aux = _apply_block(cfg, mixer, ffn, unit_params[f"pos{i}"], x,
                                  memory, positions)
            for k in aux_acc:
                if k in aux:
                    aux_acc[k] = aux_acc[k] + aux[k]
        return x, aux_acc

    unit = _maybe_remat(cfg, unit)

    def body(x, unit_params):
        return unit(x, unit_params)

    x, aux_stacked = jax.lax.scan(body, x, params["blocks"])
    for k in aux_losses:
        aux_losses[k] = aux_losses[k] + aux_stacked[k].sum()
    n_moe = sum(f == "moe" for _, f in
                cfg.prefix_pattern + cfg.block_pattern * cfg.n_repeats)
    if n_moe:
        for k in aux_losses:
            aux_losses[k] = aux_losses[k] / n_moe
    return x, aux_losses


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def _sinusoidal(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], -1)[:, :d]


def encode(cfg: ModelConfig, params, frames: jax.Array) -> jax.Array:
    """frames: (b, n_frames, d) precomputed embeddings (frontend stub)."""
    x = frames + _sinusoidal(frames.shape[1],
                             cfg.d_model).astype(frames.dtype)[None]

    def body(x, p):
        h = _norm(cfg, p["norm1"], x)
        h = attention.self_attention(cfg, p["mixer"], h, causal=False)
        x = x + h
        h = layers.mlp(p["ffn"], _norm(cfg, p["norm2"], x), cfg.act)
        return x + h, ()

    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return _norm(cfg, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    """Training / prefill forward. batch keys:

    - "tokens": (b, s_text) int32 — always present
    - "frames": (b, n_frames, d) — audio stub (whisper)
    - "patches": (b, n_patch, d) — vision stub (internvl); the full
      sequence is [patches ; embed(tokens)] with total length s.
    Returns (logits (b, s, vocab), aux).
    """
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens, dtype)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
    x = constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    if _is_ln(cfg):   # whisper decoder: learned-free sinusoidal positions
        x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(dtype)[None]

    memory = None
    if cfg.encoder is not None:
        memory = encode(cfg, params, batch["frames"].astype(dtype))

    x, aux = _stack_forward(cfg, params, x, memory, positions)
    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    else:
        logits = layers.unembed(params["lm_head"], x)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(cfg, params, batch, dtype)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        # vision prefix carries no next-token loss
        npatch = cfg.frontend.num_tokens
        pad = jnp.zeros(labels.shape[:1] + (npatch,), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        pm = jnp.concatenate(
            [jnp.zeros_like(pad, jnp.float32),
             jnp.ones(batch["labels"].shape, jnp.float32)], axis=1)
        mask = pm if mask is None else mask * pm
    loss, metrics = layers.softmax_xent(logits, labels, mask)
    total = loss
    if cfg.moe is not None:
        total = (total
                 + cfg.moe.router_aux_weight * aux["load_balance"]
                 + cfg.moe.router_z_weight * aux["router_z"])
        metrics["load_balance"] = aux["load_balance"]
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# decode: cache init + single-token step
# ---------------------------------------------------------------------------


def _block_cache(cfg: ModelConfig, mixer: str, batch: int, max_seq: int,
                 dtype=jnp.bfloat16):
    if mixer == "attn":
        if cfg.attention == "mla":
            return mla.init_mla_cache(cfg, batch, max_seq, dtype)
        return attention.init_kv_cache(cfg, batch, max_seq, dtype)
    if mixer == "mamba":
        return mamba.init_mamba_cache(cfg, batch, dtype)
    if mixer == "rwkv":
        return rwkv.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    cache: Dict[str, Any] = {}
    for i, (mixer, _) in enumerate(cfg.prefix_pattern):
        cache[f"prefix{i}"] = _block_cache(cfg, mixer, batch, max_seq, dtype)
    stacked = {}
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        one = _block_cache(cfg, mixer, batch, max_seq, dtype)
        stacked[f"pos{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_repeats,) + x.shape).copy(), one)
    cache["blocks"] = stacked
    return cache


def _decode_block(cfg, mixer, ffn, p, x, cache, index, memory):
    from repro.sharding import current_rules
    aux: Dict = {}
    h = _norm(cfg, p["norm1"], x)
    if mixer == "attn":
        if cfg.attention == "mla":
            h, cache = mla.mla_decode_attention(cfg, p["mixer"], h, cache,
                                                index)
        elif (cfg.decode_partial_softmax and cfg.attention == "full"
              and current_rules() is not None):
            from repro.models.decode_sharded import sharded_decode_attention
            h, cache = sharded_decode_attention(cfg, p["mixer"], h, cache,
                                                index, current_rules())
        else:
            h, cache = attention.decode_attention(cfg, p["mixer"], h, cache,
                                                  index)
    elif mixer == "mamba":
        h, cache = mamba.mamba_decode(cfg, p["mixer"], h, cache)
    elif mixer == "rwkv":
        h, cache = rwkv.rwkv_decode(cfg, p["mixer"], h, cache)
    x = x + h
    if memory is not None and "cross" in p:
        x = x + attention.cross_attention(cfg, p["cross"],
                                          _norm(cfg, p["norm_x"], x), memory)
    h = _norm(cfg, p["norm2"], x)
    if ffn == "moe":
        h, aux = moe.moe_ffn(cfg, p["ffn"], h, cfg.act)
    elif _is_ln(cfg):
        h = layers.mlp(p["ffn"], h, cfg.act)
    else:
        h = layers.gated_mlp(p["ffn"], h, cfg.act)
    return x + h, cache


def decode_step(cfg: ModelConfig, params, token: jax.Array, cache,
                index, memory: Optional[jax.Array] = None,
                dtype=jnp.bfloat16) -> Tuple[jax.Array, Any]:
    """token: (b, 1) int32; index: scalar int32 tokens-so-far.

    Returns (logits (b, 1, vocab), new_cache).
    """
    index = jnp.asarray(index, jnp.int32)
    x = layers.embed(params["embed"], token, dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    if _is_ln(cfg):
        # scalar sinusoidal position for the traced index
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)
        angle = index.astype(jnp.float32) / jnp.power(10_000.0, dim / d)
        pos_emb = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])[:d]
        x = x + pos_emb.astype(dtype)[None, None]

    new_cache: Dict[str, Any] = {}
    for i, (mixer, ffn) in enumerate(cfg.prefix_pattern):
        x, c = _decode_block(cfg, mixer, ffn, params[f"prefix{i}"], x,
                             cache[f"prefix{i}"], index, memory)
        new_cache[f"prefix{i}"] = c

    def body(x, scan_in):
        unit_params, unit_cache = scan_in
        out_cache = {}
        for i, (mixer, ffn) in enumerate(cfg.block_pattern):
            x, c = _decode_block(cfg, mixer, ffn, unit_params[f"pos{i}"], x,
                                 unit_cache[f"pos{i}"], index, memory)
            out_cache[f"pos{i}"] = c
        return x, out_cache

    x, blocks_cache = jax.lax.scan(body, x, (params["blocks"],
                                             cache["blocks"]))
    new_cache["blocks"] = blocks_cache
    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    else:
        logits = layers.unembed(params["lm_head"], x)
    return logits, new_cache
