"""RWKV-6 "Finch" time-mix: linear attention with data-dependent decay.

Faithful points: per-channel decay produced by a LoRA on the token-shifted
input (the headline RWKV-6 feature), bonus ``u`` on the current token,
per-head matrix state S of shape (head_dim, head_dim), group-norm on the
read-out, silu output gate. Token-shift uses learned static mix
coefficients (the double-dynamic-mix of the full model is simplified; see
DESIGN.md).

The sequential recurrence here is the oracle; the Pallas kernel
(``repro/kernels/rwkv6_wkv.py``) computes the same function chunked.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec
from repro.sharding.rules import reduce_dtype


def rwkv_spec(cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.rwkv
    h = d // r.head_dim
    dh = r.head_dim
    spec = {
        "w_r": Spec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_k": Spec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_v": Spec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_g": Spec((d, h, dh), ("embed", "heads", "head_dim")),
        "w_o": Spec((h, dh, d), ("heads", "head_dim", "embed")),
        "decay_base": Spec((h, dh), ("heads", "head_dim"), init="ones",
                           scale=1.0, dtype=jnp.float32),
        "decay_a": Spec((d, r.decay_lora), ("embed", None)),
        "decay_b": Spec((r.decay_lora, h, dh), (None, "heads", "head_dim")),
        "bonus": Spec((h, dh), ("heads", "head_dim"), init="ones",
                      scale=0.5, dtype=jnp.float32),
        "gn_scale": Spec((h, dh), ("heads", "head_dim"), init="ones",
                         dtype=jnp.float32),
        "gn_bias": Spec((h, dh), ("heads", "head_dim"), init="zeros",
                        dtype=jnp.float32),
    }
    for name in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        spec[name] = Spec((d,), ("embed",), init="ones", scale=0.5,
                          dtype=jnp.float32)
    return spec


def wkv_scan(r, k, v, w, u, s0) -> Tuple[jax.Array, jax.Array]:
    """The RWKV-6 recurrence (oracle for the Pallas kernel).

    r,k,v,w: (b, s, h, dh) fp32 (w = per-step decay in (0,1));
    u: (h, dh); s0: (b, h, dh, dh) with S[j, i] indexed [key_dim, val_dim].
    Returns (y (b,s,h,dh), s_final).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                          # (b,h,dh)
        kv = kt[..., :, None] * vt[..., None, :]      # (b,h,dh,dh)
        y = jnp.einsum("bhj,bhji->bhi", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = jax.tree.map(lambda x: x.swapaxes(0, 1), (r, k, v, w))
    s_t, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s_t


def _project(x, wmat):
    return jnp.einsum("bsd,dhk->bshk", x, wmat)


def _mix(x, x_prev, mu):
    return x + mu.astype(x.dtype) * (x_prev - x)


def _decay(cfg, params, mix_w):
    lora = jnp.einsum("bsr,rhk->bshk",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr", mix_w,
                                          params["decay_a"])),
                      params["decay_b"]).astype(jnp.float32)
    return jnp.exp(-jnp.exp(params["decay_base"] + lora))


def _groupnorm(params, y, eps=1e-5):
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    return (y - mean) * jax.lax.rsqrt(var + eps) * params["gn_scale"] \
        + params["gn_bias"]


def rwkv_mixer(cfg: ModelConfig, params, x) -> jax.Array:
    """Training / prefill. x: (b, s, d)."""
    b, s, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r = _project(_mix(x, x_prev, params["mu_r"]), params["w_r"])
    k = _project(_mix(x, x_prev, params["mu_k"]), params["w_k"])
    v = _project(_mix(x, x_prev, params["mu_v"]), params["w_v"])
    g = jax.nn.silu(_project(_mix(x, x_prev, params["mu_g"]), params["w_g"]))
    w = _decay(cfg, params, _mix(x, x_prev, params["mu_w"]))

    h = r.shape[2]
    s0 = jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
    y, _ = wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), w, params["bonus"], s0)
    y = _groupnorm(params, y).astype(x.dtype) * g
    return jnp.einsum("bshk,hkd->bsd", y, params["w_o"],
                      preferred_element_type=reduce_dtype(y.dtype))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_rwkv_cache(cfg: ModelConfig, batch: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h = d // cfg.rwkv.head_dim
    return {
        "x_prev": jnp.zeros((batch, d), dtype),
        "s": jnp.zeros((batch, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                       jnp.float32),
    }


def rwkv_decode(cfg: ModelConfig, params, x, cache
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (b, 1, d). O(1) state update."""
    x_prev = cache["x_prev"].astype(x.dtype)[:, None, :]
    r = _project(_mix(x, x_prev, params["mu_r"]), params["w_r"])
    k = _project(_mix(x, x_prev, params["mu_k"]), params["w_k"])
    v = _project(_mix(x, x_prev, params["mu_v"]), params["w_v"])
    g = jax.nn.silu(_project(_mix(x, x_prev, params["mu_g"]), params["w_g"]))
    w = _decay(cfg, params, _mix(x, x_prev, params["mu_w"]))

    rt = r[:, 0].astype(jnp.float32)
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    wt = w[:, 0]
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhj,bhji->bhi", rt,
                   cache["s"] + params["bonus"][..., :, None] * kv)
    s = wt[..., :, None] * cache["s"] + kv
    y = _groupnorm(params, y)[:, None].astype(x.dtype) * g
    out = jnp.einsum("bshk,hkd->bsd", y, params["w_o"])
    return out, {"x_prev": x[:, 0].astype(cache["x_prev"].dtype), "s": s}
