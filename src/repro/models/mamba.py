"""Selective SSM (S6 / Mamba-1) mixer, used by Jamba's mamba layers.

Training/prefill uses a *chunked* associative scan: the sequence is cut
into chunks of 128; the (b, chunk, d_inner, d_state) decay/drive tensors
are materialized only per-chunk inside the scan body, the diagonal linear
recurrence ``h_t = a_t * h_{t-1} + bx_t`` is solved with
``lax.associative_scan``, outputs are contracted with C inside the body,
and only the chunk-final state is carried. Live memory is
O(b * chunk * d_inner * d_state), never O(seq * ...).

Decode is the O(1) recurrent step on a cached state + conv tail.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import Spec
from repro.sharding import constrain
from repro.sharding.rules import reduce_dtype

CHUNK = 128


def mamba_spec(cfg: ModelConfig):
    mb = cfg.mamba
    d = cfg.d_model
    di = mb.d_inner(d)
    return {
        "w_in": Spec((d, 2 * di), ("embed", "d_inner")),
        "conv_w": Spec((mb.d_conv, di), ("conv", "d_inner"), scale=0.5),
        "conv_b": Spec((di,), ("d_inner",), init="zeros"),
        "w_x": Spec((di, mb.dt_rank + 2 * mb.d_state), ("d_inner", None)),
        "w_dt": Spec((mb.dt_rank, di), ("dt_rank", "d_inner")),
        "b_dt": Spec((di,), ("d_inner",), init="ones", scale=-4.6,
                     dtype=jnp.float32),   # softplus(-4.6) ~ 0.01
        "a_log": Spec((di, mb.d_state), ("d_inner", "state"), init="ones",
                      scale=0.0, dtype=jnp.float32),
        "d_skip": Spec((di,), ("d_inner",), init="ones", dtype=jnp.float32),
        "w_out": Spec((di, d), ("d_inner", "embed")),
    }


def _conv1d(x, w, b):
    """Causal depthwise conv. x: (b, s, di); w: (k, di)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b.astype(x.dtype)


def _dt_b_c(cfg, params, u):
    """u: (b, s, di) post-conv. Returns dt (b,s,di) fp32, B/C (b,s,N) fp32."""
    mb = cfg.mamba
    proj = jnp.einsum("bsd,dr->bsr", u, params["w_x"])
    dt_r, bmat, cmat = jnp.split(
        proj, [mb.dt_rank, mb.dt_rank + mb.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_r, params["w_dt"]).astype(jnp.float32)
        + params["b_dt"])
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def ssm_scan(dt, bmat, cmat, u, a_mat, h0) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan (also the oracle for the Pallas kernel).

    dt: (b,s,di) fp32; bmat/cmat: (b,s,N); u: (b,s,di); a_mat: (di,N) (<0);
    h0: (b,di,N). Returns (y (b,s,di) fp32, h_final).
    """
    b, s, di = dt.shape
    n = a_mat.shape[-1]
    chunk = min(CHUNK, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    def split(x, ax):
        out = x.reshape((b, n_chunks, chunk) + x.shape[2:]).swapaxes(0, 1)
        # keep d_inner model-sharded through the reshape/transpose —
        # without this XLA loses the sharding and replicates (§Perf)
        return constrain(out, (None, "batch", None) + ax)

    dt_c = split(dt, ("d_inner",))
    b_c = split(bmat, ("state",))
    c_c = split(cmat, ("state",))
    u_c = split(u.astype(jnp.float32), ("d_inner",))

    def body(h, inp):
        dtc, bc, cc, uc = inp                       # (b, chunk, ...)
        a = jnp.exp(dtc[..., None] * a_mat)         # (b,chunk,di,N)
        bx = (dtc * uc)[..., None] * bc[:, :, None, :]

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        aa, bb = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h_all = bb + aa * h[:, None]                # absorb carry
        y = jnp.einsum("bsdn,bsn->bsd", h_all, cc)
        y = constrain(y, ("batch", None, "d_inner"))
        return constrain(h_all[:, -1], ("batch", "d_inner", "state")), y

    h_t, y_c = jax.lax.scan(body, h0, (dt_c, b_c, c_c, u_c))
    y = y_c.swapaxes(0, 1).reshape(b, s, di)
    return y, h_t


def mamba_mixer(cfg: ModelConfig, params, x) -> jax.Array:
    """Training / prefill. x: (b, s, d) -> (b, s, d)."""
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xz = constrain(xz, ("batch", "seq", "d_inner"))
    u, z = jnp.split(xz, 2, axis=-1)                   # (b,s,di) each
    u = jax.nn.silu(_conv1d(u, params["conv_w"], params["conv_b"]))
    u = constrain(u, ("batch", "seq", "d_inner"))
    dt, bmat, cmat = _dt_b_c(cfg, params, u)
    dt = constrain(dt, ("batch", "seq", "d_inner"))
    a_mat = -jnp.exp(params["a_log"])
    h0 = jnp.zeros((x.shape[0], a_mat.shape[0], a_mat.shape[1]), jnp.float32)
    y, _ = ssm_scan(dt, bmat, cmat, u, a_mat, h0)
    y = y + params["d_skip"] * u.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, params["w_out"],
                      preferred_element_type=reduce_dtype(y.dtype))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    mb = cfg.mamba
    di = mb.d_inner(cfg.d_model)
    return {
        "h": jnp.zeros((batch, di, mb.d_state), jnp.float32),
        "conv": jnp.zeros((batch, mb.d_conv - 1, di), dtype),
    }


def mamba_decode(cfg: ModelConfig, params, x, cache
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (b, 1, d). O(1) state update."""
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    u, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
    u = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, params["conv_w"])
        + params["conv_b"])[:, None, :]
    dt, bmat, cmat = _dt_b_c(cfg, params, u)
    a_mat = -jnp.exp(params["a_log"])
    a = jnp.exp(dt[:, 0, :, None] * a_mat)             # (b,di,N)
    bx = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
        * bmat[:, 0, None, :]
    h = a * cache["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
    y = y + params["d_skip"] * u.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["w_out"])
    return out, {"h": h, "conv": window[:, 1:].astype(cache["conv"].dtype)}
