"""Mixture-of-experts FFN with top-k routing and capacity-based dispatch.

Dispatch is scatter-based (memory-safe): tokens are placed into a
per-expert capacity buffer with ``.at[].add`` using positions from a
token-priority cumsum — no (tokens, experts, capacity) one-hot tensor is
ever materialized. Experts are sharded over the ``model`` axis (expert
parallelism); XLA lowers the buffer exchange to an all-to-all-like
collective. Shared experts (DeepSeek style) run densely on every token.

Aux losses: GShard load-balance loss and router z-loss, returned per call
and averaged over layers by the caller.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import Spec
from repro.sharding import constrain
from repro.sharding.rules import reduce_dtype


def moe_spec(cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    # experts_dp resolves to replication: data-parallel expert compute
    # with FSDP-sharded weights (§Perf lever for small-expert MoEs)
    e_ax = "experts" if cfg.moe_expert_parallel else "experts_dp"
    spec = {
        "router": Spec((d, m.num_experts), ("embed", "experts_dp"),
                       dtype=jnp.float32),
        "w_gate": Spec((m.num_experts, d, m.d_expert),
                       (e_ax, "embed", "expert_mlp")),
        "w_up": Spec((m.num_experts, d, m.d_expert),
                     (e_ax, "embed", "expert_mlp")),
        "w_down": Spec((m.num_experts, m.d_expert, d),
                       (e_ax, "expert_mlp", "embed")),
    }
    if m.num_shared:
        spec["shared"] = layers.gated_mlp_spec(d, m.num_shared * m.d_expert)
    return spec


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_ffn(cfg: ModelConfig, params, x, act: str = "silu"
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.moe_group_dispatch:
        return moe_ffn_grouped(cfg, params, x, act)
    return moe_ffn_global(cfg, params, x, act)


def moe_ffn_global(cfg: ModelConfig, params, x, act: str = "silu"
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Baseline: GLOBAL token-priority dispatch — the capacity cumsum runs
    over the full (sharded) token dim, so SPMD lowers it to cross-device
    prefix collectives. Kept as the §Perf baseline."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])                       # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, m.top_k)              # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # --- aux losses (GShard) ---------------------------------------------
    me = probs.mean(axis=0)                                     # (E,)
    onehot_top1 = jax.nn.one_hot(sel[:, 0], m.num_experts)
    ce = onehot_top1.mean(axis=0)
    aux = {
        "load_balance": m.num_experts * jnp.sum(me * ce),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1))),
    }

    # --- capacity dispatch -------------------------------------------------
    cap = _capacity(t, cfg)
    sel_flat = sel.reshape(-1)                                  # (t*k,) slot-major rows
    # priority: token order within each expert, over all (t*k) assignments
    onehot = jax.nn.one_hot(sel_flat, m.num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # (t*k, E)
    pos = jnp.take_along_axis(pos, sel_flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    idx_e = jnp.where(keep, sel_flat, m.num_experts)            # overflow row
    idx_c = jnp.where(keep, pos, 0)

    x_rep = jnp.repeat(xf, m.top_k, axis=0)                     # (t*k, d)
    buf = jnp.zeros((m.num_experts + 1, cap, d), x.dtype)
    buf = buf.at[idx_e, idx_c].add(x_rep)
    buf = constrain(buf[:m.num_experts], ("experts", None, "embed"))

    # --- expert computation (grouped gated MLP) ---------------------------
    a = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    out = jnp.einsum("ecf,efd->ecd", h * u, params["w_down"],
                      preferred_element_type=reduce_dtype(h.dtype))
    out = jnp.concatenate(
        [out, jnp.zeros((1, cap, d), out.dtype)], axis=0)       # overflow row

    # --- combine ------------------------------------------------------------
    gathered = out[idx_e, idx_c]                                # (t*k, d)
    w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(t, m.top_k, d).sum(axis=1)

    if m.num_shared:
        y = y + layers.gated_mlp(params["shared"], xf, act)
    return y.reshape(b, s, d), aux


MOE_DISPATCH_CHUNK = 128


def moe_ffn_grouped(cfg: ModelConfig, params, x, act: str = "silu"
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Beyond-paper §Perf lever: GROUP-LOCAL one-hot EINSUM dispatch
    (GShard grouping, chunked).

    Two fixes vs the baseline (validated in EXPERIMENTS.md §Perf):
    1. routing positions come from a cumsum *within* each 256-token
       chunk of a sequence row, so no cross-device prefix collectives;
    2. dispatch/combine are dense one-hot einsums instead of
       scatter/gather — XLA's scatter partitioner replicates the f32
       capacity buffer across the model axis and all-reduces it (7.9 GiB
       per MoE layer on granite); einsums partition cleanly.

    Capacity is enforced per chunk (out-of-capacity one_hot rows are all
    zero, which drops the token exactly like the baseline's keep-mask).
    """
    m = cfg.moe
    b, s, d = x.shape
    e_ax = "experts" if cfg.moe_expert_parallel else "experts_dp"

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, m.top_k)          # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(sel[..., 0], m.num_experts).mean(axis=(0, 1))
    aux = {
        "load_balance": m.num_experts * jnp.sum(me * ce),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1))),
    }

    chunk = min(MOE_DISPATCH_CHUNK, s)
    if s % chunk:
        chunk = s
    g = s // chunk                                          # chunks per row
    cap = _capacity(chunk, cfg)
    tk = chunk * m.top_k

    sel_c = sel.reshape(b, g, tk)
    gate_c = gate_vals.reshape(b, g, tk)
    oh_e = jax.nn.one_hot(sel_c, m.num_experts, dtype=x.dtype)
    pos = jnp.cumsum(oh_e, axis=2) - oh_e                   # chunk-local
    pos = jnp.take_along_axis(pos, sel_c[..., None], axis=3)[..., 0]
    oh_c = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
    # D[b,g,t,e,c]: dispatch one-hot; combine weights fold in the gate
    disp = jnp.einsum("bgte,bgtc->bgtec", oh_e, oh_c)
    comb = disp * gate_c[..., None, None].astype(x.dtype)

    x_rep = jnp.repeat(x.reshape(b, g, chunk, d), m.top_k, axis=2)
    buf = jnp.einsum("bgtec,bgtd->begcd", disp, x_rep)
    buf = buf.reshape(b, m.num_experts, g * cap, d)
    buf = constrain(buf, ("batch", e_ax, None, "embed"))

    # ZeRO-3 semantics: expert weights are STORED d-sharded (FSDP) but
    # COMPUTED gathered — without this constraint XLA contracts over the
    # sharded d and all-reduces the (b,e,cap,d_expert) activation
    # (16 GiB/layer on jamba) instead of gathering 0.4 GiB of weights.
    w_gate = constrain(params["w_gate"], (e_ax, None, None))
    w_up = constrain(params["w_up"], (e_ax, None, None))
    w_down = constrain(params["w_down"], (e_ax, None, None))

    a = jnp.einsum("becd,edf->becf", buf, w_gate)
    u = jnp.einsum("becd,edf->becf", buf, w_up)
    h = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    out = jnp.einsum("becf,efd->becd", h * u, w_down,
                      preferred_element_type=reduce_dtype(h.dtype))
    out = out.reshape(b, m.num_experts, g, cap, d)

    y = jnp.einsum("bgtec,begcd->bgtd", comb, out)
    y = y.reshape(b, g, chunk, m.top_k, d).sum(axis=3).reshape(b, s, d)

    if m.num_shared:
        y = y + layers.gated_mlp(params["shared"], x, act)
    return y, aux
