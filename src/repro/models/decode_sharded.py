"""Sequence-sharded decode attention with partial-softmax combine.

§Perf lever (target 4, decode shapes): the baseline einsum decode path
leaves XLA to all-gather the model-axis-sharded KV cache every step
(~1 GiB/step on glm4 decode_32k). Here each model-axis shard computes
flash-style partials (m, l, o) over its local slice of the cache and the
exact softmax is reconstructed with one tiny ``pmax``/``psum`` pair —
the collective moves O(b*h*dh) instead of O(b*S*kv*dh).

Implemented with ``jax.shard_map`` over the full mesh; only the cache
sequence dim is mapped to ``model``. Enabled via
``ModelConfig.decode_partial_softmax`` (``--opt decodeps``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.sharding.rules import MeshRules, shard_map_compat as _shard_map

NEG_INF = -1e30


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def sharded_decode_attention(cfg: ModelConfig, params, x, cache, index,
                             rules: MeshRules
                             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """GQA decode with a ('model'-sharded on seq) KV cache.

    x: (b, 1, d); cache k/v: (b, S, kv, hd) with S sharded over 'model'.
    """
    mesh = rules.mesh
    b = x.shape[0]
    hd = cfg.head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v_new = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    if cfg.qk_norm:
        from repro.models.attention import _qk_norm
        q = _qk_norm(params["q_norm"], q, cfg.norm_eps)
        k_new = _qk_norm(params["k_norm"], k_new, cfg.norm_eps)
    if cfg.rope:
        pos = jnp.full((1, 1), index, jnp.int32)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k_new = layers.apply_rope(k_new, pos, cfg.rope_theta)

    n_model = mesh.shape["model"]
    s_total = cache["k"].shape[1]
    s_local = s_total // n_model
    batch_ax = _batch_axes(mesh)
    # batch maps to (pod, data) only when divisible (long_500k: batch 1)
    bspec: Optional[Tuple[str, ...]] = None
    if batch_ax:
        size = 1
        for a in batch_ax:
            size *= mesh.shape[a]
        if b % size == 0:
            bspec = batch_ax

    def local(q, k_new, v_new, k_shard, v_shard, index):
        # runs per (data x model) shard; seq dim is the model shard
        shard = jax.lax.axis_index("model")
        offset = shard * s_local
        local_idx = jnp.clip(index - offset, 0, s_local - 1)
        in_range = (index >= offset) & (index < offset + s_local)
        k_upd = jax.lax.dynamic_update_slice_in_dim(
            k_shard, k_new.astype(k_shard.dtype), local_idx, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(
            v_shard, v_new.astype(v_shard.dtype), local_idx, axis=1)
        k_shard = jnp.where(in_range, k_upd, k_shard)
        v_shard = jnp.where(in_range, v_upd, v_shard)

        kvh = k_shard.shape[2]
        h_eff = q.shape[2]
        g = h_eff // kvh
        qg = q.reshape(q.shape[0], 1, kvh, g, hd)
        scale = hd ** -0.5
        s = jnp.einsum("bqngd,bknd->bnqgk",
                       qg.astype(jnp.float32) * scale,
                       k_shard.astype(jnp.float32))      # (b,kv,1,g,S_l)
        slots = offset + jnp.arange(s_local)
        valid = slots <= index
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)

        m_loc = s.max(axis=-1)                           # (b,kv,1,g)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_glob[..., None])
        l_loc = p.sum(axis=-1)
        o_loc = jnp.einsum("bnqgk,bknd->bqngd",
                           p.astype(v_shard.dtype), v_shard)
        l_glob = jax.lax.psum(l_loc, "model")
        o = jax.lax.psum(o_loc.astype(jnp.float32), "model")
        o = o / jnp.maximum(
            l_glob.transpose(0, 2, 1, 3), 1e-30)[..., None]
        o = o.reshape(o.shape[0], 1, h_eff, hd).astype(q.dtype)
        return o, k_shard, v_shard

    out, k, v = _shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec),
                  P(bspec, "model"), P(bspec, "model"), P()),
        out_specs=(P(bspec), P(bspec, "model"), P(bspec, "model")),
    )(q, k_new, v_new, cache["k"], cache["v"],
      jnp.asarray(index, jnp.int32))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}
