"""Multi-head latent attention (DeepSeek-V2 / MiniCPM3).

Faithful points: low-rank compressed KV latent (kv_lora_rank) with RMSNorm,
decoupled RoPE key shared across heads, optional low-rank Q. The decode
path stores ONLY the compressed latent + rope key (the MLA memory win) and
uses the absorbed-weight formulation so per-step compute is
O(S * kv_lora_rank) per head, never materializing full K/V.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.attention import NEG_INF, attend
from repro.models.params import Spec
from repro.sharding.rules import reduce_dtype


def mla_spec(cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.eff_heads
    spec = {
        "w_dkv": Spec((d, m.kv_lora_rank), ("embed", "kv_lora")),
        "kv_norm": {"scale": Spec((m.kv_lora_rank,), ("kv_lora",),
                                  init="ones", dtype=jnp.float32)},
        "w_kr": Spec((d, m.rope_head_dim), ("embed", "head_dim")),
        "w_uk": Spec((m.kv_lora_rank, h, m.nope_head_dim),
                     ("kv_lora", "heads", "head_dim")),
        "w_uv": Spec((m.kv_lora_rank, h, m.v_head_dim),
                     ("kv_lora", "heads", "head_dim")),
        "wo": Spec((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                   init="zeros" if cfg.pad_heads_to else "normal"),
    }
    if m.q_lora_rank:
        spec["w_dq"] = Spec((d, m.q_lora_rank), ("embed", "q_lora"))
        spec["q_norm"] = {"scale": Spec((m.q_lora_rank,), ("q_lora",),
                                        init="ones", dtype=jnp.float32)}
        spec["w_uq_nope"] = Spec((m.q_lora_rank, h, m.nope_head_dim),
                                 ("q_lora", "heads", "head_dim"))
        spec["w_uq_rope"] = Spec((m.q_lora_rank, h, m.rope_head_dim),
                                 ("q_lora", "heads", "head_dim"))
    else:
        spec["wq_nope"] = Spec((d, h, m.nope_head_dim),
                               ("embed", "heads", "head_dim"))
        spec["wq_rope"] = Spec((d, h, m.rope_head_dim),
                               ("embed", "heads", "head_dim"))
    return spec


def _queries(cfg, params, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = layers.rmsnorm(params["q_norm"],
                            jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                            cfg.norm_eps)
        q_nope = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq_nope"])
        q_rope = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq_rope"])
    else:
        q_nope = jnp.einsum("bsd,dhk->bshk", x, params["wq_nope"])
        q_rope = jnp.einsum("bsd,dhk->bshk", x, params["wq_rope"])
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_self_attention(cfg: ModelConfig, params, x, *, positions=None
                       ) -> jax.Array:
    """Training / prefill. x: (b, s, d)."""
    m = cfg.mla
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    ckv = layers.rmsnorm(params["kv_norm"],
                         jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                         cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["w_kr"])[:, :, None, :]
    k_rope = layers.apply_rope(k_rope, positions[None], cfg.rope_theta)
    q_nope, q_rope = _queries(cfg, params, x, positions[None])

    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope,
                                  (b, s, cfg.eff_heads, m.rope_head_dim))],
        axis=-1)
    out = attend(q, k, v, positions, positions, window=0, causal=True)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=reduce_dtype(out.dtype))


# ---------------------------------------------------------------------------
# decode with compressed latent cache (absorbed formulation)
# ---------------------------------------------------------------------------


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.rope_head_dim), dtype),
    }


def mla_decode_attention(cfg: ModelConfig, params, x, cache, index
                         ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (b, 1, d). Cache holds latents only: (b, S, kv_lora)+(b, S, rope)."""
    m = cfg.mla
    pos = jnp.full((1, 1), index, jnp.int32)
    ckv_t = layers.rmsnorm(params["kv_norm"],
                           jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                           cfg.norm_eps)
    kr_t = jnp.einsum("bsd,dk->bsk", x, params["w_kr"])[:, :, None, :]
    kr_t = layers.apply_rope(kr_t, pos, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), index, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_t.astype(cache["k_rope"].dtype), index, axis=1)

    q_nope, q_rope = _queries(cfg, params, x, pos)
    # absorb W_uk into the query: score contraction happens in latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,bSr->bhsS", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bshk,bSk->bhsS", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(ckv.shape[1]) <= index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhsS,bSr->bshr", probs.astype(ckv.dtype), ckv)
    out = jnp.einsum("bshr,rhk->bshk", out_lat, params["w_uv"])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=reduce_dtype(out.dtype))
    return y, {"ckv": ckv, "k_rope": k_rope}
