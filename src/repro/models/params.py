"""Minimal spec-based parameter system.

Each layer module defines a *spec tree*: nested dicts whose leaves are
:class:`Spec` (shape + logical axes + initializer). From one spec tree we
derive three views:

- ``init_tree``      -> concrete ``jnp.ndarray`` params (smoke tests, training)
- ``abstract_tree``  -> ``jax.ShapeDtypeStruct`` params (AOT dry-run: a 398B
                        model is never materialized)
- ``axes_tree``      -> logical-axis tuples, resolved to ``NamedSharding`` by
                        ``repro.sharding.rules``

``stack(spec, n, axis_name)`` prepends a scan dimension so layer stacks are
stored stacked and iterated with ``jax.lax.scan``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled_normal
    scale: float = 1.0            # stddev multiplier (normal) or value
    dtype: Any = None             # override param dtype (e.g. fp32 norms)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"spec rank mismatch: {self.shape} vs {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _map_specs(fn: Callable[[Tuple[str, ...], Spec], Any], tree: PyTree,
               path: Tuple[str, ...] = ()) -> PyTree:
    if is_spec(tree):
        return fn(path, tree)
    return {k: _map_specs(fn, v, path + (k,)) for k, v in tree.items()}


def _key_for(root: jax.Array, path: Tuple[str, ...]) -> jax.Array:
    # deterministic per-path key: fold in a stable hash of the path
    h = int.from_bytes(
        hashlib.sha256("/".join(path).encode()).digest()[:4], "little")
    return jax.random.fold_in(root, h)


def init_tree(spec: PyTree, key: jax.Array, param_dtype=jnp.float32) -> PyTree:
    def leaf(path, s: Spec):
        dtype = s.dtype or param_dtype
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.full(s.shape, s.scale, dtype)
        fan_in = s.shape[0] if len(s.shape) == 1 else int(
            np.prod(s.shape[:-1]))
        std = s.scale / max(1.0, fan_in) ** 0.5
        k = _key_for(key, path)
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dtype)
    return _map_specs(leaf, spec)


def abstract_tree(spec: PyTree, param_dtype=jnp.float32) -> PyTree:
    def leaf(path, s: Spec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype)
    return _map_specs(leaf, spec)


def axes_tree(spec: PyTree) -> PyTree:
    return _map_specs(lambda _, s: s.axes, spec)


def stack(spec: PyTree, n: int, axis_name: str = "layers") -> PyTree:
    """Prepend a scan dimension of size ``n`` to every leaf."""
    def leaf(_, s: Spec):
        return replace(s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes)
    return _map_specs(leaf, spec)


def param_bytes(spec: PyTree, bytes_per_el: int = 2) -> int:
    total = 0

    def leaf(_, s: Spec):
        nonlocal total
        total += int(np.prod(s.shape)) * bytes_per_el
    _map_specs(leaf, spec)
    return total


def tree_slice(tree: PyTree, i) -> PyTree:
    """Index the leading (scan) dim of every leaf — used inside lax.scan."""
    return jax.tree.map(lambda x: x[i], tree)
