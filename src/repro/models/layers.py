"""Shared primitive layers: norms, RoPE, MLPs, embeddings, losses.

All apply-functions are pure: ``apply(params, x, cfg-ish args) -> y``.
Norm params are kept in fp32 (Spec dtype override); matmuls run in the
activation dtype with fp32 accumulation where it matters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import Spec
from repro.sharding.rules import reduce_dtype

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int):
    return {"scale": Spec((dim,), ("embed",), init="ones", dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(x.dtype)


def layernorm_spec(dim: int):
    return {
        "scale": Spec((dim,), ("embed",), init="ones", dtype=jnp.float32),
        "bias": Spec((dim,), ("embed",), init="zeros", dtype=jnp.float32),
    }


def layernorm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def gated_mlp_spec(d_model: int, d_ff: int):
    return {
        "w_gate": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_up": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_down": Spec((d_ff, d_model), ("mlp", "embed")),
    }


def gated_mlp(params, x, act: str = "silu"):
    a = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = _act(act)(a) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=reduce_dtype(h.dtype))


def mlp_spec(d_model: int, d_ff: int):
    """Non-gated MLP (whisper-style)."""
    return {
        "w_up": Spec((d_model, d_ff), ("embed", "mlp")),
        "b_up": Spec((d_ff,), ("mlp",), init="zeros"),
        "w_down": Spec((d_ff, d_model), ("mlp", "embed")),
        "b_down": Spec((d_model,), ("embed",), init="zeros"),
    }


def mlp(params, x, act: str = "gelu"):
    h = _act(act)(jnp.einsum("...d,df->...f", x, params["w_up"])
                  + params["b_up"].astype(x.dtype))
    return (jnp.einsum("...f,fd->...d", h, params["w_down"],
                       preferred_element_type=reduce_dtype(h.dtype))
            + params["b_down"].astype(x.dtype))


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d_model: int):
    return {"table": Spec((vocab, d_model), ("vocab", "embed"), scale=1.0)}


def embed(params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed_spec(vocab: int, d_model: int):
    return {"w": Spec((d_model, vocab), ("embed", "vocab"))}


def unembed(params, x) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, params["w"])


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None,
                 z_weight: float = 0.0):
    """Token-level cross-entropy in fp32; returns (mean_loss, aux)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    target = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - target
    if z_weight:
        nll = nll + z_weight * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
