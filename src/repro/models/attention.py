"""GQA attention: full-causal, sliding-window, qk-norm, RoPE; training,
prefill and single-token decode paths.

The jnp implementation here is both the CPU oracle and the dry-run
lowering path (Pallas kernels are validated separately in interpret mode;
see ``repro/kernels``). For long sequences the query dimension is chunked
with ``lax.map`` so prefill_32k never materializes a full S x S score
matrix per head.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import Spec
from repro.sharding.rules import reduce_dtype

NEG_INF = -1e30


def attention_spec(cfg: ModelConfig, cross: bool = False):
    # eff_heads >= n_heads when TP head padding is on (§Perf); the extra
    # heads are zero-output-initialized so the function at init matches
    # the unpadded architecture exactly.
    d, h, kv, hd = cfg.d_model, cfg.eff_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed"),
                   init="zeros" if cfg.pad_heads_to else "normal"),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = {"scale": Spec((hd,), ("head_dim",), init="ones",
                                        dtype=jnp.float32)}
        spec["k_norm"] = {"scale": Spec((hd,), ("head_dim",), init="ones",
                                        dtype=jnp.float32)}
    return spec


def _qk_norm(scale_params, x, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale_params["scale"]).astype(x.dtype)


def _mask(q_pos, k_pos, window: int, causal: bool):
    """(q, k) boolean mask. q_pos/k_pos: int32 position vectors."""
    d = q_pos[:, None] - k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= d >= 0
    if window:
        m &= d < window
    return m


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (b,sq,kv,g,hd) k/v: (b,sk,kv,hd); grouped-query attention core."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqngd,bkn d->bnqgk".replace(" ", ""),
                        q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(mask[:, None, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqgk,bknd->bqngd", probs.astype(v.dtype), v)
    return out


def attend(q, k, v, q_pos, k_pos, *, window=0, causal=True,
           q_chunk: int = 2048) -> jax.Array:
    """Chunked-over-queries masked attention.

    q: (b, sq, h, hd); k/v: (b, sk, kv, hd). Returns (b, sq, h, hd).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    vd = v.shape[-1]            # may differ from hd (MLA)
    qg = q.reshape(b, sq, kvh, g, hd)

    if sq <= q_chunk:
        mask = _mask(q_pos, k_pos, window, causal)[None]
        return _sdpa(qg, k, v, mask).reshape(b, sq, h, vd)

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qc = qg.reshape(b, n_chunks, q_chunk, kvh, g, hd)
    pc = q_pos.reshape(n_chunks, q_chunk)

    def one(args):
        qi, pi = args
        mask = _mask(pi, k_pos, window, causal)[None]
        return _sdpa(qi, k, v, mask)

    out = jax.lax.map(one, (qc.swapaxes(0, 1), pc))      # (n, b, qc, kv, g, vd)
    return out.swapaxes(0, 1).reshape(b, sq, h, vd)


def self_attention(cfg: ModelConfig, params, x, *, positions=None,
                   causal=True) -> jax.Array:
    """Training / prefill self-attention. x: (b, s, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    if cfg.qk_norm:
        q = _qk_norm(params["q_norm"], q, cfg.norm_eps)
        k = _qk_norm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope:
        q = layers.apply_rope(q, positions[None], cfg.rope_theta)
        k = layers.apply_rope(k, positions[None], cfg.rope_theta)
    window = cfg.window if cfg.attention == "swa" else 0
    out = attend(q, k, v, positions, positions, window=window, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=reduce_dtype(out.dtype))


def cross_attention(cfg: ModelConfig, params, x, memory) -> jax.Array:
    """Decoder->encoder attention (whisper). memory: (b, frames, d)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bfd,dnk->bfnk", memory, params["wk"])
    v = jnp.einsum("bfd,dnk->bfnk", memory, params["wv"])
    sq, sk = x.shape[1], memory.shape[1]
    out = attend(q, k, v, jnp.arange(sq), jnp.arange(sk),
                 window=0, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=reduce_dtype(out.dtype))


# ---------------------------------------------------------------------------
# decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """KV cache for one attention layer.

    SWA archs use a ring buffer of ``window`` slots — the whole point of
    the sub-quadratic carve-out: long_500k keeps a 4096-slot cache.
    """
    slots = min(max_seq, cfg.window) if cfg.attention == "swa" else max_seq
    shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec_axes():
    return ("batch", "cache_seq", "kv_heads", "head_dim")


def decode_attention(cfg: ModelConfig, params, x, cache, index
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (b, 1, d); cache k/v: (b, S, kv, hd); index: scalar int32 count of
    tokens already in cache. Returns (out (b,1,d), new_cache)."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v_new = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    if cfg.qk_norm:
        q = _qk_norm(params["q_norm"], q, cfg.norm_eps)
        k_new = _qk_norm(params["k_norm"], k_new, cfg.norm_eps)
    if cfg.rope:
        pos = jnp.full((1, 1), index, jnp.int32)
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k_new = layers.apply_rope(k_new, pos, cfg.rope_theta)

    slots = cache["k"].shape[1]
    slot = index % slots if cfg.attention == "swa" else index
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)

    h_eff = q.shape[2]
    kvh = k.shape[2]
    g = h_eff // kvh
    qg = q.reshape(b, 1, kvh, g, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("bqngd,bknd->bnqgk", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    slot_ids = jnp.arange(slots)
    if cfg.attention == "swa":
        valid = (slot_ids <= index) | (index >= slots)   # ring: all valid once full
    else:
        valid = slot_ids <= index
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqgk,bknd->bqngd", probs.astype(v.dtype), v)
    out = out.reshape(b, 1, h_eff, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"],
                      preferred_element_type=reduce_dtype(out.dtype))
    return y, {"k": k, "v": v}
