"""Composable member-tower factory for the VFL protocols (DESIGN.md §12).

A tower is a sequence of *block configs* (xformers-style: each block is
a small dict of ``kind`` + hyperparameters) resolved against concrete
input/output widths into a :class:`TowerSpec`. The split-NN protocol
builds both its bottom (member) and top (master) models through this
factory; the legacy ``hidden``/``embedding_dim`` MLP path is just the
one-block tower ``mlp_tower(...)`` and stays bit-identical to the
historical ``mlp_init``/``mlp_apply`` pair (seed traces enforce it).

Block kinds
-----------

``embed``      feature chunking + bucketized embedding lookup: the flat
               feature vector is split into ``tokens`` chunks, each
               chunk gets a dense value projection plus a learned
               per-(token, bucket) embedding keyed on the chunk mean,
               plus a positional embedding.  Output is a
               ``(batch, tokens, dim)`` sequence.  Must be first.
``attn_block`` pre-norm transformer block (self-attention + relu MLP,
               both residual) on a 3-D sequence. ``kernel=auto`` runs
               the pallas flash-attention forward on TPU and the
               reference jnp math elsewhere; the backward pass is
               always the reference VJP (pallas_call has no autodiff).
``quantize``   straight-through int8 fake-quantization of activations
               (per-row symmetric, same grid as the wire codec) — lets
               a tower train against the precision it will be served
               and exchanged at.
``mlp``        the legacy relu MLP.  Mean-pools a 3-D sequence first.
               The final block of every tower must be an ``mlp`` (it
               owns the output width).

Blocks are written either as dicts or as compact strings
``"kind:key=val,key=val"`` with ``|``-separated integer tuples::

    ("embed:tokens=8,dim=32", "attn_block:heads=4", "mlp:hidden=64|32")

``resolve(blocks, in_dim, out_dim)`` normalizes both forms and
validates the chain; ``init``/``apply`` are the pure param functions;
``logical_axes``/``shard_tower``/``make_tower_rules`` place a large
tower on the local mesh (``launch/mesh.py`` + ``sharding/rules.py``);
``tower_flops`` is the analytic forward cost used by the roofline
accounting (``launch/roofline.py``).
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

BLOCK_KINDS = ("embed", "attn_block", "quantize", "mlp")

# embed-block bucketization: chunk means of standardized features live
# almost entirely in [-2.5, 2.5]; that range maps linearly onto the
# bucket grid and the ends clip.
_BUCKET_SPAN = 5.0

BlockLike = Union[str, Dict[str, Any]]


# ---------------------------------------------------------------------------
# spec parsing / resolution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TowerSpec:
    """A resolved tower: normalized block dicts + concrete widths.

    Produced by :func:`resolve` (or the :func:`mlp_tower` /
    :func:`legacy_dims_tower` helpers) — block dicts here always carry
    every hyperparameter explicitly, so ``init``/``apply`` never apply
    defaults.
    """

    blocks: Tuple[Dict[str, Any], ...]
    in_dim: int
    out_dim: int

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(b["kind"] for b in self.blocks)


def parse_block(block: BlockLike) -> Dict[str, Any]:
    """Normalize one block config (string DSL or dict) to a plain dict.

    Strings look like ``"mlp:hidden=64|32"`` or ``"attn_block:heads=4"``;
    ``|`` separates tuple elements, values parse as int when possible.
    """
    if isinstance(block, dict):
        out = dict(block)
        if "kind" not in out:
            raise ValueError(f"tower block {block!r} has no 'kind'")
    elif isinstance(block, str):
        head, _, rest = block.partition(":")
        out = {"kind": head.strip()}
        if rest.strip():
            for item in rest.split(","):
                if "=" not in item:
                    raise ValueError(
                        f"tower block {block!r}: expected key=val, got "
                        f"{item!r}")
                k, _, v = item.partition("=")
                out[k.strip()] = _parse_val(v.strip())
    else:
        raise ValueError(f"tower block must be str or dict, got "
                         f"{type(block).__name__}")
    kind = out["kind"]
    if kind == "attn":               # common shorthand
        kind = out["kind"] = "attn_block"
    if kind not in BLOCK_KINDS:
        raise ValueError(f"unknown tower block kind {kind!r} "
                         f"(expected one of {BLOCK_KINDS})")
    return out


def _parse_val(v: str) -> Any:
    if "|" in v:
        return tuple(_parse_val(e) for e in v.split("|"))
    try:
        return int(v)
    except ValueError:
        return v


_BLOCK_KEYS = {
    "embed": {"tokens", "dim", "buckets"},
    "attn_block": {"heads", "mlp", "kernel"},
    "quantize": {"kernel"},
    "mlp": {"hidden", "final_act"},
}


def check_blocks(blocks: Sequence[BlockLike]) -> List[Dict[str, Any]]:
    """Validate block structure without knowing concrete widths.

    Used by the cluster-spec validator, where ``in_dim`` depends on the
    data provider and is not yet known. Returns the parsed dicts.
    Raises ``ValueError`` on malformed chains.
    """
    if not blocks:
        raise ValueError("tower must have at least one block")
    parsed = [parse_block(b) for b in blocks]
    for i, b in enumerate(parsed):
        kind = b["kind"]
        extra = set(b) - {"kind"} - _BLOCK_KEYS[kind]
        if extra:
            raise ValueError(
                f"tower block {i} ({kind}): unknown keys {sorted(extra)}")
        if kind == "embed" and i != 0:
            raise ValueError("'embed' must be the first tower block")
        if kind == "attn_block":
            if not parsed[:i] or parsed[0]["kind"] != "embed":
                raise ValueError(
                    "'attn_block' needs an 'embed' block first "
                    "(attention runs on the token sequence it "
                    "produces)")
            if any(p["kind"] == "mlp" for p in parsed[:i]):
                raise ValueError(
                    "'attn_block' must come before any 'mlp' block — "
                    "'mlp' mean-pools the token sequence to flat "
                    "features, leaving no sequence to attend over")
        if b.get("kernel", "auto") not in ("auto", "pallas", "ref"):
            raise ValueError(
                f"tower block {i} ({kind}): kernel must be "
                f"auto|pallas|ref, got {b.get('kernel')!r}")
    last_real = [b for b in parsed if b["kind"] != "quantize"]
    if not last_real or last_real[-1]["kind"] != "mlp":
        raise ValueError(
            "the last (non-quantize) tower block must be 'mlp' — it "
            "owns the output width")
    return parsed


def resolve(blocks: Sequence[BlockLike], in_dim: int,
            out_dim: int) -> TowerSpec:
    """Resolve block configs + concrete widths into a :class:`TowerSpec`.

    Fills every default, threads widths through the chain, and
    validates shape compatibility (e.g. ``dim % heads == 0``).
    """
    parsed = check_blocks(blocks)
    resolved: List[Dict[str, Any]] = []
    width = int(in_dim)               # current feature width (last axis)
    seq = 0                           # current token count (0 = flat 2-D)
    for i, b in enumerate(parsed):
        kind = b["kind"]
        if kind == "embed":
            tokens = int(b.get("tokens", 8))
            dim = int(b.get("dim", 32))
            buckets = int(b.get("buckets", 32))
            if tokens < 1 or dim < 1 or buckets < 2:
                raise ValueError(
                    f"embed block: tokens/dim >= 1 and buckets >= 2 "
                    f"required, got {tokens}/{dim}/{buckets}")
            chunk = max(1, math.ceil(width / tokens))
            resolved.append({"kind": "embed", "tokens": tokens,
                             "dim": dim, "buckets": buckets,
                             "chunk": chunk, "in_dim": width})
            width, seq = dim, tokens
        elif kind == "attn_block":
            heads = int(b.get("heads", 4))
            ff = int(b.get("mlp", 4 * width))
            if width % heads != 0:
                raise ValueError(
                    f"attn_block: dim {width} not divisible by "
                    f"heads {heads}")
            resolved.append({"kind": "attn_block", "heads": heads,
                             "mlp": ff, "dim": width, "seq": seq,
                             "kernel": b.get("kernel", "auto")})
        elif kind == "quantize":
            resolved.append({"kind": "quantize",
                             "kernel": b.get("kernel", "auto")})
        else:  # mlp
            hidden = b.get("hidden", ())
            if isinstance(hidden, int):
                hidden = (hidden,)
            hidden = tuple(int(h) for h in hidden)
            dims = (width,) + hidden + (int(out_dim),)
            resolved.append({"kind": "mlp", "dims": dims,
                             "final_act": bool(b.get("final_act",
                                                     True))})
            width, seq = int(out_dim), 0
    return TowerSpec(blocks=tuple(resolved), in_dim=int(in_dim),
                     out_dim=int(out_dim))


def mlp_tower(in_dim: int, hidden: Sequence[int], out_dim: int,
              final_act: bool = True) -> TowerSpec:
    """The legacy MLP as a one-block tower (bit-identical params/math)."""
    return resolve(({"kind": "mlp", "hidden": tuple(hidden),
                     "final_act": final_act},), in_dim, out_dim)


_warned_dims = False


def legacy_dims_tower(dims: Sequence[int],
                      final_act: bool = True) -> TowerSpec:
    """Deprecated-compat shim: a ``bottom_dims``/``top_dims`` tuple as
    an equivalent one-block MLP tower. Warns once per process."""
    global _warned_dims
    if not _warned_dims:
        _warned_dims = True
        warnings.warn(
            "bottom_dims/top_dims tuples are deprecated; express the "
            "model as a TowerSpec (repro.models.tower) instead",
            DeprecationWarning, stacklevel=2)
    dims = tuple(int(d) for d in dims)
    if len(dims) < 2:
        raise ValueError(f"need >= 2 dims, got {dims}")
    return mlp_tower(dims[0], dims[1:-1], dims[-1], final_act=final_act)


# ---------------------------------------------------------------------------
# kernels: reference/pallas forward, reference backward
# ---------------------------------------------------------------------------


def _use_pallas(kernel: str) -> bool:
    if kernel == "pallas":
        return True
    if kernel == "ref":
        return False
    # auto: the pallas kernels run everywhere via interpret mode, but
    # interpret unrolls the grid Python-side — only worth it on TPU.
    return jax.devices()[0].platform == "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _attention(q, k, v, kernel: str = "ref"):
    """Bidirectional multi-head attention, (b, h, s, dh) layout.

    Forward through ``kernels.ops.flash_attention`` (pallas) or the
    reference math; backward is always the reference VJP because
    ``pallas_call`` is not reverse-differentiable.
    """
    return _attention_fwd(q, k, v, kernel)[0]


def _attention_fwd(q, k, v, kernel):
    if _use_pallas(kernel):
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=False)
    else:
        from repro.kernels.ref import attention_ref
        out = attention_ref(q, k, v, causal=False)
    return out, (q, k, v)


def _attention_bwd(kernel, res, g):
    from repro.kernels.ref import attention_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=False),
        q, k, v)
    return vjp(g)


_attention.defvjp(_attention_fwd, _attention_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x, kernel: str = "ref"):
    """Straight-through int8 fake-quantization (per-row symmetric).

    Forward quantizes+dequantizes on the wire codec's grid (pallas
    ``quantize_int8`` or the reference); backward is identity (STE).
    """
    return _fq_fwd(x, kernel)[0]


def _fq_fwd(x, kernel):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _use_pallas(kernel):
        from repro.kernels.ops import quantize_int8
        q, scale = quantize_int8(x2, block_r=math.gcd(x2.shape[0], 256))
    else:
        from repro.kernels.ref import quantize_int8_ref
        q, scale = quantize_int8_ref(x2)
    y = (q.astype(jnp.float32) * scale[:, None]).astype(x.dtype)
    return y.reshape(shape), None


def _fq_bwd(kernel, _, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------


def init(spec: TowerSpec, key) -> List[Any]:
    """Initialize tower params: one pytree entry per block.

    Key discipline: a single-block tower consumes ``key`` directly so
    the one-mlp tower reproduces the historical ``mlp_init(key, dims)``
    stream bit-for-bit; multi-block towers fold in the block index.
    """
    params: List[Any] = []
    for bi, b in enumerate(spec.blocks):
        bkey = key if len(spec.blocks) == 1 else jax.random.fold_in(
            key, bi)
        params.append(_BLOCK_INIT[b["kind"]](b, bkey))
    return params


def _init_mlp(b, key):
    # exact legacy mlp_init: fold_in per layer, normal/sqrt(fan_in)
    layers = []
    dims = b["dims"]
    for i, (a, o) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": jax.random.normal(k, (a, o), jnp.float32) / np.sqrt(a),
            "b": jnp.zeros((o,), jnp.float32),
        })
    return layers


def _init_embed(b, key):
    t, c, d, nb = b["tokens"], b["chunk"], b["dim"], b["buckets"]
    k1, k2, k3 = (jax.random.fold_in(key, i) for i in range(3))
    return {
        "w": jax.random.normal(k1, (t, c, d), jnp.float32) / np.sqrt(c),
        "table": 0.02 * jax.random.normal(k2, (t * nb, d), jnp.float32),
        "pos": 0.02 * jax.random.normal(k3, (t, d), jnp.float32),
    }


def _init_attn(b, key):
    d, f = b["dim"], b["mlp"]
    ks = [jax.random.fold_in(key, i) for i in range(6)]
    n = jax.random.normal
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "wq": n(ks[0], (d, d), jnp.float32) / np.sqrt(d),
        "wk": n(ks[1], (d, d), jnp.float32) / np.sqrt(d),
        "wv": n(ks[2], (d, d), jnp.float32) / np.sqrt(d),
        "wo": n(ks[3], (d, d), jnp.float32) / np.sqrt(d),
        "ln2": jnp.ones((d,), jnp.float32),
        "w1": n(ks[4], (d, f), jnp.float32) / np.sqrt(d),
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": n(ks[5], (f, d), jnp.float32) / np.sqrt(f),
        "b2": jnp.zeros((d,), jnp.float32),
    }


_BLOCK_INIT = {"mlp": _init_mlp, "embed": _init_embed,
               "attn_block": _init_attn,
               "quantize": lambda b, key: {}}


def apply(spec: TowerSpec, params: Sequence[Any], x,
          rules=None):
    """Pure forward pass. ``rules`` (a ``MeshRules`` or None) is threaded
    explicitly — contextvars don't survive jit tracing boundaries."""
    for b, p in zip(spec.blocks, params):
        x = _BLOCK_APPLY[b["kind"]](b, p, x)
        if x.ndim == 3:
            x = _constrain(x, ("batch", None, None), rules)
        else:
            x = _constrain(x, ("batch", "mlp"), rules)
    return x


def _constrain(x, logical, rules):
    if rules is None:
        return x
    spec = rules.act_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))


def _apply_mlp(b, p, x):
    if x.ndim == 3:                   # sequence -> pooled features
        x = jnp.mean(x, axis=1)
    # exact legacy mlp_apply loop
    n = len(p)
    for i, layer in enumerate(p):
        x = x @ layer["w"] + layer["b"]
        if i < n - 1 or b["final_act"]:
            x = jax.nn.relu(x)
    return x


def _apply_embed(b, p, x):
    t, c, nb = b["tokens"], b["chunk"], b["buckets"]
    pad = t * c - x.shape[-1]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    xr = x.reshape(x.shape[0], t, c)
    val = jnp.einsum("ntc,tcd->ntd", xr, p["w"])
    mean = jnp.mean(xr, axis=-1)
    ids = jnp.clip(((mean + _BUCKET_SPAN / 2) * (nb / _BUCKET_SPAN))
                   .astype(jnp.int32), 0, nb - 1)
    look = p["table"][jnp.arange(t)[None, :] * nb + ids]
    return val + look + p["pos"][None, :, :]


def _rmsnorm(scale, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _apply_attn(b, p, x):
    n, t, d = x.shape
    h = b["heads"]
    dh = d // h
    y = _rmsnorm(p["ln1"], x)
    # (n, t, d) -> (n, h, t, dh) for the flash-attention layout
    q = (y @ p["wq"]).reshape(n, t, h, dh).transpose(0, 2, 1, 3)
    k = (y @ p["wk"]).reshape(n, t, h, dh).transpose(0, 2, 1, 3)
    v = (y @ p["wv"]).reshape(n, t, h, dh).transpose(0, 2, 1, 3)
    o = _attention(q, k, v, b["kernel"])
    o = o.transpose(0, 2, 1, 3).reshape(n, t, d) @ p["wo"]
    x = x + o
    y = _rmsnorm(p["ln2"], x)
    y = jax.nn.relu(y @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return x + y


def _apply_quant(b, p, x):
    return fake_quant(x, b["kernel"])


_BLOCK_APPLY = {"mlp": _apply_mlp, "embed": _apply_embed,
                "attn_block": _apply_attn, "quantize": _apply_quant}


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def logical_axes(spec: TowerSpec) -> List[Any]:
    """Per-param logical axis names, matching the ``init`` tree."""
    axes: List[Any] = []
    for b in spec.blocks:
        kind = b["kind"]
        if kind == "mlp":
            axes.append([{"w": ("embed", "mlp"), "b": ("mlp",)}
                         for _ in range(len(b["dims"]) - 1)])
        elif kind == "embed":
            axes.append({"w": (None, None, "mlp"),
                         "table": ("vocab", None),
                         "pos": (None, None)})
        elif kind == "attn_block":
            axes.append({"ln1": (None,),
                         "wq": ("embed", "heads"),
                         "wk": ("embed", "heads"),
                         "wv": ("embed", "heads"),
                         "wo": ("heads", "embed"),
                         "ln2": (None,),
                         "w1": ("embed", "mlp"), "b1": ("mlp",),
                         "w2": ("mlp", "embed"), "b2": (None,)})
        else:
            axes.append({})
    return axes


def make_tower_rules(shard: int):
    """MeshRules for an N-way model-parallel tower over local devices,
    or None when ``shard <= 1`` (the common unsharded path)."""
    if shard <= 1:
        return None
    ndev = len(jax.devices())
    if ndev < shard:
        raise ValueError(
            f"tower_shard={shard} but only {ndev} local device(s); "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"for CPU testing")
    from repro.launch.mesh import make_local_mesh
    from repro.sharding.rules import MeshRules
    return MeshRules(mesh=make_local_mesh(1, shard))


def shard_tower(params: Sequence[Any], spec: TowerSpec, rules):
    """Place tower params per their logical axes (no-op without rules)."""
    if rules is None:
        return list(params)
    axes = logical_axes(spec)
    return jax.tree.map(
        lambda ax, p: jax.device_put(p, rules.param_sharding(ax, p.shape)),
        axes, list(params),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# analytic cost (roofline)
# ---------------------------------------------------------------------------


def tower_flops(spec: TowerSpec, batch: int) -> float:
    """Analytic forward FLOPs (matmuls only; 2*M*N*K per GEMM)."""
    fl = 0.0
    n = float(batch)
    for b in spec.blocks:
        if b["kind"] == "mlp":
            dims = b["dims"]
            fl += sum(2.0 * n * a * o
                      for a, o in zip(dims[:-1], dims[1:]))
        elif b["kind"] == "embed":
            fl += 2.0 * n * b["tokens"] * b["chunk"] * b["dim"]
        elif b["kind"] == "attn_block":
            t, d, f = b["seq"], b["dim"], b["mlp"]
            fl += 8.0 * n * t * d * d          # qkv + out projections
            fl += 4.0 * n * t * t * d          # scores + weighted sum
            fl += 4.0 * n * t * d * f          # relu MLP
    return fl


def params_bytes(params) -> int:
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(params)))
