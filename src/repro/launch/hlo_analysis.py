"""Post-SPMD HLO text analysis: collective inventory with while-loop trip
multipliers.

``compiled.as_text()`` is the partitioned per-device module. Collectives
inside ``lax.scan``-lowered while loops execute trip-count times but
appear once in the text, so we:

1. split the module into named computations,
2. find every ``while`` op, recover the trip count from the largest
   integer constant in its condition computation (scan conditions are
   ``lt(iter, N)``),
3. propagate multipliers from ENTRY through while bodies / calls /
   conditionals,
4. sum bytes of every collective op, scaled by its computation's
   multiplier.

Byte conventions (ring algorithms, per device): all-gather -> result
bytes; all-reduce -> 2x result bytes; reduce-scatter -> result bytes x
group size (input volume); all-to-all / collective-permute -> result
bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-_]+)(?:\.clone)? \(.*\) -> ")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-_]+).*?body=%?([\w.\-_]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-_,% ]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every array shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveRecord:
    op: str
    bytes: int
    mult: int
    computation: str

    @property
    def total(self) -> int:
        return self.bytes * self.mult


@dataclass
class HloReport:
    collectives: List[CollectiveRecord] = field(default_factory=list)
    loop_trip_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def collective_bytes(self) -> int:
        return sum(c.total for c in self.collectives)

    def by_op(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for c in self.collectives:
            out[c.op] += c.total
        return dict(out)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HDR.match(line.rstrip())
        if m and line and not line.startswith(" "):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if cur is not None and stripped and stripped != "}":
            comps[cur].append(stripped)
        if stripped == "}":
            cur = None
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for line in cond_lines for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def analyze_hlo(hlo: str) -> HloReport:
    comps = _split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:   # fall back: last computation is usually ENTRY
        entry_name = list(comps)[-1]
        entry = comps[entry_name]

    report = HloReport()
    mult: Dict[int, int] = {}        # id(lines) -> multiplier
    visited: Dict[str, int] = {}

    def visit(lines: List[str], m: int, name: str):
        if name in visited and visited[name] >= m:
            return
        visited[name] = m
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                report.loop_trip_counts[body] = trips
                if body in comps:
                    visit(comps[body], m * trips, body)
                continue
            if " = " in line:
                rhs = line.split(" = ", 1)[1]
                for coll in COLLECTIVES:
                    # opcode occurs right before '(' in the rhs; skip the
                    # async -start half (count the -done результат once)
                    if f"{coll}-start(" in rhs:
                        break  # counted at the matching -done
                    if f"{coll}(" in rhs or f"{coll}-done(" in rhs:
                        shape_txt = rhs.split(coll)[0]
                        nbytes = _shape_bytes(shape_txt)
                        if coll == "all-reduce":
                            nbytes *= 2
                        report.collectives.append(
                            CollectiveRecord(coll, nbytes, m, name))
                        break
            cm = _CALL_RE.search(line)
            if cm and "while" not in line:
                for callee in re.split(r"[ ,%]+", cm.group(1)):
                    callee = callee.strip()
                    if callee and callee in comps and callee != name:
                        visit(comps[callee], m, callee)

    visit(entry, 1, "__entry__")
    return report


def summarize(report: HloReport) -> str:
    lines = [f"collective bytes/device: {report.collective_bytes:,}"]
    for op, b in sorted(report.by_op().items(), key=lambda kv: -kv[1]):
        lines.append(f"  {op:>22s}: {b:,}")
    if report.loop_trip_counts:
        trips = ", ".join(f"{k}x{v}" for k, v in
                          list(report.loop_trip_counts.items())[:6])
        lines.append(f"  loops: {trips}")
    return "\n".join(lines)
