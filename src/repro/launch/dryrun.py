import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede any other import (jax locks the device
# count at first init). 512 placeholder host devices let jax.make_mesh
# build the production meshes: single-pod (16,16)=256, multi-pod
# (2,16,16)=512. Nothing is allocated: inputs/params are
# ShapeDtypeStructs and we stop at .lower().compile().
"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh)
combination on the production mesh, then emit memory/cost/collective
analysis for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 10x4x2 sweep
"""
import argparse
import gc
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.configs.base import InputShape, ModelConfig
from repro.launch import flops as F
from repro.launch import specs as S
from repro.launch import steps as ST
from repro.launch.hlo_analysis import analyze_hlo, summarize
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh, mesh_chips)
from repro.sharding.rules import MeshRules
from repro.train import optimizer as O

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"


def _mem_analysis(compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "peak_memory_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:                              # pragma: no cover
        out["error"] = repr(e)
    return out


def _per_device_gib(mem: Dict[str, Any], chips: int) -> float:
    """Per-device HBM estimate. argument/output sizes are per-device
    (they follow the shardings); on the CPU host backend temp_size is the
    host-wide total across all placeholder devices, so divide by chips.
    """
    return (mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0) / max(chips, 1)) / 2 ** 30


def recommended_opts(cfg: ModelConfig, shape: InputShape) -> str:
    """Per-(arch, shape) recommended levers from the §Perf hillclimbs."""
    opts = []
    if cfg.moe is not None:
        opts.append("moegroup")
        if cfg.moe.d_expert <= 1024:
            opts.append("moedp")       # small experts: DP beats EP
    if shape.kind == "decode":
        opts.append("noweightfsdp")    # FSDP gathers dominate decode
        # partial-softmax decode needs a data-shardable batch; at
        # batch=1 (long_500k) it degenerates (measured regression)
        if (cfg.attention == "full" and cfg.uses_attention
                and shape.global_batch >= 16):
            opts.append("decodeps")
    return ",".join(opts)


def _apply_opts(cfg: ModelConfig, rules: MeshRules, opts: str):
    """Beyond-paper optimization levers (EXPERIMENTS.md §Perf):
    --opt moegroup,seqshard,padheads=48 — or --opt auto."""
    import dataclasses
    for opt in filter(None, (opts or "").split(",")):
        if opt == "moegroup":
            cfg = dataclasses.replace(cfg, moe_group_dispatch=True)
        elif opt == "moedp":
            cfg = dataclasses.replace(cfg, moe_expert_parallel=False)
        elif opt == "seqshard":
            rules.act_rules["seq"] = ("model",)
        elif opt == "bf16reduce":
            rules.bf16_collectives = True
        elif opt == "decodeps":
            cfg = dataclasses.replace(cfg, decode_partial_softmax=True)
        elif opt.startswith("accum="):
            rules.accum_steps = int(opt.split("=")[1])
        elif opt == "noweightfsdp":
            # decode: keep params TP-sharded only — FSDP weight gathers
            # dominate small-batch decode and the TP shard fits HBM
            rules.param_rules["embed"] = None
        elif opt.startswith("padheads="):
            cfg = dataclasses.replace(cfg,
                                      pad_heads_to=int(opt.split("=")[1]))
        else:
            raise ValueError(f"unknown --opt {opt!r}")
    return cfg


def run_one(arch: str, shape_name: str, mesh_kind: str,
            save: bool = True, verbose: bool = True,
            opts: str = "", tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind + tag,
        "opts": opts,
        "params_b": cfg.param_count() / 1e9,
        "active_params_b": cfg.active_param_count() / 1e9,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        if save:
            _save(record)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh_chips(mesh)
    rules = MeshRules(mesh)
    if opts == "auto":
        opts = recommended_opts(cfg, shape)
        record["opts"] = opts
    cfg = _apply_opts(cfg, rules, opts)
    param_dtype = jnp.bfloat16
    t0 = time.time()
    try:
        abstract_params, axes, _ = ST.resolve_param_shardings(
            cfg, rules, param_dtype)
        if shape.kind == "train":
            opt = O.make_optimizer(cfg.optimizer)
            opt_sds = ST.opt_state_specs(opt, abstract_params, axes, rules)
            step = ST.make_train_step(cfg, opt, rules=rules,
                                      accum_steps=getattr(rules, "accum_steps", 1))
            batch = S.batch_specs(cfg, shape, rules, with_labels=True)
            with mesh:
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    abstract_params, opt_sds, batch)
        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg, rules=rules)
            batch = S.batch_specs(cfg, shape, rules, with_labels=False)
            with mesh:
                lowered = jax.jit(step).lower(abstract_params, batch)
        else:  # decode
            with_memory = cfg.encoder is not None
            step = ST.make_decode_step(cfg, rules=rules,
                                       with_memory=with_memory)
            token = S._sds((shape.global_batch, 1), jnp.int32, rules,
                           ("batch", "seq"))
            cache = S.cache_specs(cfg, shape, rules)
            index = jax.ShapeDtypeStruct((), jnp.int32)
            args = [abstract_params, token, cache, index]
            if with_memory:
                args.append(S._sds(
                    (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
                    jnp.bfloat16, rules, ("batch", "frames", "embed")))
            with mesh:
                lowered = jax.jit(step, donate_argnums=(2,)).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:
        record["status"] = "error"
        record["error"] = traceback.format_exc(limit=20)
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
        if save:
            _save(record)
        return record

    cost = compiled.cost_analysis() or {}
    hlo_report = analyze_hlo(compiled.as_text())
    mem = _mem_analysis(compiled)
    mem["per_device_gib_estimate"] = round(_per_device_gib(mem, chips), 3)
    record.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "transcendentals", "optimal_seconds")},
        "collective_bytes_per_device": hlo_report.collective_bytes,
        "collectives_by_op": hlo_report.by_op(),
        "loop_trip_counts": dict(
            list(hlo_report.loop_trip_counts.items())[:12]),
        "sharding_fallbacks": rules.fallbacks[:20],
    })

    # ---- roofline terms (single-pod table; see EXPERIMENTS.md) ----------
    fwd = F.step_flops(cfg, shape)
    total_flops = F.train_flops(cfg, shape) if shape.kind == "train" else fwd
    opt_bpe = 8 if cfg.optimizer == "adamw" else 0
    total_bytes = F.step_bytes(cfg, shape, 2, opt_bpe)
    coll_bytes = hlo_report.collective_bytes      # per device
    record["roofline"] = {
        "analytic_flops": total_flops,
        "analytic_hbm_bytes": total_bytes,
        "model_flops": F.model_flops(cfg, shape),
        "compute_s": total_flops / (chips * PEAK_FLOPS_BF16),
        "memory_s": total_bytes / (chips * HBM_BW),
        "collective_s": coll_bytes / ICI_BW,      # per-device bytes / link bw
    }
    terms = {k: record["roofline"][k]
             for k in ("compute_s", "memory_s", "collective_s")}
    record["roofline"]["dominant"] = max(terms, key=terms.get)
    if verbose:
        print(f"[OK] {arch} x {shape_name} x {mesh_kind} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print(f"  mem/device: {mem.get('per_device_gib_estimate', 0):.2f} GiB"
              f"  HLO flops(once): {cost.get('flops', 0):.3e}")
        print(f"  roofline: compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"-> {record['roofline']['dominant']}")
        print("  " + summarize(hlo_report).replace("\n", "\n  "))
    if save:
        _save(record)
    del compiled, lowered
    gc.collect()
    return record


def _save(record: Dict[str, Any]):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    (RESULTS_DIR / name).write_text(json.dumps(record, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep all (arch, shape) on --mesh")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: moegroup,seqshard,padheads=<n>")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (opt variants)")
    args = ap.parse_args()

    if args.all:
        for arch in list_archs():
            for shape in sorted(SHAPES):
                out = RESULTS_DIR / f"{arch}__{shape}__{args.mesh}.json"
                if args.skip_done and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                run_one(arch, shape, args.mesh, opts=args.opt, tag=args.tag)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    run_one(args.arch, args.shape, args.mesh, opts=args.opt, tag=args.tag)


if __name__ == "__main__":
    main()
