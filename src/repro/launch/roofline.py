"""Roofline aggregation: dryrun JSONs -> the EXPERIMENTS.md §Roofline
markdown table.

  PYTHONPATH=src python -m repro.launch.roofline [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

from repro.launch.dryrun import RESULTS_DIR
from repro.launch.mesh import PEAK_FLOPS_BF16

NOTES = {
    "compute_s": "compute-bound: more chips or lower precision",
    "memory_s": "HBM-bound: fuse reads / shrink cache or state traffic",
    "collective_s": "collective-bound: resharding or dispatch schedule "
                    "(see §Perf)",
}


def rows_for(mesh: str) -> List[Dict]:
    rows = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def table(mesh: str) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | fits/chip | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows_for(mesh):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR "
                       f"| — | — | |")
            continue
        rf = r["roofline"]
        # MODEL_FLOPS / analytic HLO-equivalent flops (useful-compute frac)
        ratio = rf["model_flops"] / max(rf["analytic_flops"], 1)
        mem = r["memory"].get("per_device_gib_estimate", 0)
        dom = rf["dominant"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} | "
            f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
            f"{dom.replace('_s', '')} | {ratio:.2f} | "
            f"{mem:.2f} GiB | {NOTES[dom][:46]} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(table(args.mesh))


if __name__ == "__main__":
    main()
