"""Roofline accounting.

Two layers share this module:

* **LLM dry-run aggregation** (the original): dryrun JSONs -> the
  EXPERIMENTS.md §Roofline markdown table
  (``python -m repro.launch.roofline [--mesh single]``).
* **Per-step VFL accounting** (:func:`step_account`): the training
  driver snapshots its CommStats counters around the fit phase and
  resolves them into a per-step compute-vs-wire split, surfaced in
  ``Driver.result()["roofline"]`` and the cluster launcher's
  ``summary.json``. This is what makes pipeline-depth wins
  explainable: depth helps exactly when neither fraction dominates.
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

NOTES = {
    "compute_s": "compute-bound: more chips or lower precision",
    "memory_s": "HBM-bound: fuse reads / shrink cache or state traffic",
    "collective_s": "collective-bound: resharding or dispatch schedule "
                    "(see §Perf)",
}


def step_account(wall_s: float, steps: int, comm_delta: Dict[str, float],
                 profile: Optional[Dict[str, float]] = None
                 ) -> Dict[str, float]:
    """Resolve one role's fit phase into per-step roofline terms.

    ``comm_delta`` holds the CommStats counter deltas across the phase
    (``recv_wait_s``, ``send_s``, ``queued_s``, ``wire_s``,
    ``sent_bytes``). The split:

    * ``compute_s`` — wall time the role was NOT blocked on the
      exchange: wall minus recv waits and blocking-send time. This is
      model compute plus driver overhead, the numerator of any
      pipelining win.
    * ``wire_s`` — time the exchange engine spent moving this role's
      bytes (sender-thread queue + wire time, plus blocking sends).
      Under pipelining this overlaps ``compute_s``; the two fractions
      can sum past 1.0 — that overlap IS the pipeline win.
    * ``stall_s`` — recv waits: the part of the exchange the role
      could not hide.

    ``profile`` (``VFLProtocol.roofline_profile()``) adds the analytic
    side: flops/bytes per step and arithmetic intensity, so the
    measured split can be sanity-checked against the model's shape.
    """
    steps = max(1, int(steps))
    wall = max(0.0, float(wall_s))
    stall = max(0.0, float(comm_delta.get("recv_wait_s", 0.0)))
    send = max(0.0, float(comm_delta.get("send_s", 0.0)))
    wire = send + max(0.0, float(comm_delta.get("queued_s", 0.0))) \
        + max(0.0, float(comm_delta.get("wire_s", 0.0)))
    compute = max(0.0, wall - stall - send)
    out = {
        "steps": steps,
        "wall_s_per_step": wall / steps,
        "compute_s_per_step": compute / steps,
        "wire_s_per_step": wire / steps,
        "stall_s_per_step": stall / steps,
        "compute_frac": compute / wall if wall else 0.0,
        "wire_frac": wire / wall if wall else 0.0,
        "stall_frac": stall / wall if wall else 0.0,
        "sent_bytes_per_step":
            float(comm_delta.get("sent_bytes", 0)) / steps,
    }
    out["dominant"] = "compute" if compute >= wire else "wire"
    if profile:
        fl = float(profile.get("flops_per_step", 0.0))
        by = float(profile.get("bytes_per_step", 0.0))
        out["model_flops_per_step"] = fl
        out["model_bytes_per_step"] = by
        if by:
            # flops per wire byte: the VFL analogue of arithmetic
            # intensity — low values say the exchange will dominate
            # long before the model does
            out["exchange_intensity"] = fl / by
        if compute:
            out["achieved_flops"] = fl * steps / max(compute, 1e-9)
        if "params_bytes" in profile:
            out["params_bytes"] = float(profile["params_bytes"])
    for k, v in list(out.items()):
        if isinstance(v, float):
            out[k] = round(v, 6)
    return out


def rows_for(mesh: str) -> List[Dict]:
    from repro.launch.dryrun import RESULTS_DIR
    rows = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def table(mesh: str) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | fits/chip | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows_for(mesh):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR "
                       f"| — | — | |")
            continue
        rf = r["roofline"]
        # MODEL_FLOPS / analytic HLO-equivalent flops (useful-compute frac)
        ratio = rf["model_flops"] / max(rf["analytic_flops"], 1)
        mem = r["memory"].get("per_device_gib_estimate", 0)
        dom = rf["dominant"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} | "
            f"{rf['memory_s']:.3f} | {rf['collective_s']:.3f} | "
            f"{dom.replace('_s', '')} | {ratio:.2f} | "
            f"{mem:.2f} GiB | {NOTES[dom][:46]} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print(table(args.mesh))


if __name__ == "__main__":
    main()
