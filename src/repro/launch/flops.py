"""Analytic FLOP / byte models per (arch x shape).

``jax.stages.Compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified empirically — scan flops are n_repeats-fold undercounted), so
the roofline's compute/memory terms come from this analytic model, with
cost_analysis recorded alongside as a loop-bodies-once cross-check and
the HLO text parse (hlo_analysis.py) supplying collective bytes with
loop-trip multipliers.

Conventions: a matmul (m,k)x(k,n) costs 2mkn; train = 3x forward
(fwd + dL/dx + dL/dw); causal attention halves the score work;
SWA caps context at ``window``; MoE compute includes the capacity factor
(dispatch buffers are padded to capacity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import InputShape, ModelConfig


@dataclass
class CostModel:
    flops: float              # total FLOPs for one step (all chips)
    hbm_bytes: float          # total HBM traffic for one step (all chips)
    model_flops: float        # 6*N*D reference (active params for MoE)


def _attn_ctx(cfg: ModelConfig, s: int, kind: str, cache_len: int) -> float:
    """Average attended context length per query token."""
    if kind == "decode":
        ctx = cache_len
        if cfg.attention == "swa":
            ctx = min(ctx, cfg.window)
        return float(ctx)
    if cfg.attention == "swa":
        return float(min(s / 2, cfg.window))
    return s / 2  # causal


def _mixer_flops(cfg: ModelConfig, mixer: str, t: float, s: int,
                 kind: str, cache_len: int) -> float:
    d = cfg.d_model
    if mixer == "attn":
        if cfg.attention == "mla":
            m = cfg.mla
            h = cfg.eff_heads
            qdim = h * (m.nope_head_dim + m.rope_head_dim)
            f = 0.0
            if m.q_lora_rank:
                f += 2 * t * d * m.q_lora_rank + 2 * t * m.q_lora_rank * qdim
            else:
                f += 2 * t * d * qdim
            f += 2 * t * d * (m.kv_lora_rank + m.rope_head_dim)
            ctx = _attn_ctx(cfg, s, kind, cache_len)
            if kind == "decode":
                # absorbed: scores in latent space + rope, readout in latent
                f += 2 * t * h * m.nope_head_dim * m.kv_lora_rank  # absorb q
                f += 2 * t * ctx * h * (m.kv_lora_rank + m.rope_head_dim)
                f += 2 * t * ctx * h * m.kv_lora_rank
                f += 2 * t * h * m.kv_lora_rank * m.v_head_dim
            else:
                f += 2 * t * m.kv_lora_rank * h * (m.nope_head_dim
                                                   + m.v_head_dim)
                f += 2 * t * ctx * h * (m.nope_head_dim + m.rope_head_dim)
                f += 2 * t * ctx * h * m.v_head_dim
            f += 2 * t * h * m.v_head_dim * d  # output proj
            return f
        h, kv, hd = cfg.eff_heads, cfg.n_kv_heads, cfg.head_dim
        f = 2 * t * d * h * hd + 2 * 2 * t * d * kv * hd \
            + 2 * t * h * hd * d
        ctx = _attn_ctx(cfg, s, kind, cache_len)
        f += 2 * 2 * t * ctx * h * hd          # qk + pv
        return f
    if mixer == "mamba":
        mb = cfg.mamba
        di = mb.d_inner(d)
        f = 2 * t * d * 2 * di                       # in_proj
        f += 2 * mb.d_conv * t * di                  # conv
        f += 2 * t * di * (mb.dt_rank + 2 * mb.d_state)
        f += 2 * t * mb.dt_rank * di                 # dt proj
        f += 8 * t * di * mb.d_state                 # scan update + readout
        f += 2 * t * di * d                          # out proj
        return f
    if mixer == "rwkv":
        r = cfg.rwkv
        dh = r.head_dim
        f = 5 * 2 * t * d * d                        # r,k,v,g,o projections
        f += 2 * t * d * r.decay_lora * 2            # decay lora
        f += 6 * t * d * dh                          # state update + read
        return f
    raise ValueError(mixer)


def _ffn_flops(cfg: ModelConfig, ffn: str, t: float) -> float:
    d = cfg.d_model
    if ffn == "moe":
        m = cfg.moe
        f = 2 * t * d * m.num_experts                       # router
        f += 3 * 2 * t * m.top_k * m.capacity_factor * d * m.d_expert
        if m.num_shared:
            f += 3 * 2 * t * d * m.num_shared * m.d_expert
        return f
    n_mats = 2 if cfg.encoder is not None else 3            # whisper: no gate
    return n_mats * 2 * t * d * cfg.d_ff


def step_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Forward FLOPs for one step of this (arch, shape)."""
    kind = shape.kind
    b = shape.global_batch
    if kind == "decode":
        t, s, cache_len = float(b), 1, shape.seq_len
    else:
        t, s, cache_len = float(b) * shape.seq_len, shape.seq_len, 0

    total = 0.0
    for mixer, ffn in (cfg.prefix_pattern
                       + cfg.block_pattern * cfg.n_repeats):
        total += _mixer_flops(cfg, mixer, t, s, kind, cache_len)
        total += _ffn_flops(cfg, ffn, t)
        if cfg.encoder is not None:  # cross attention per decoder layer
            h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            d, fr = cfg.d_model, cfg.encoder.n_frames
            total += 2 * t * d * h * hd * 2                  # q, o proj
            total += 2 * 2 * float(b) * fr * d * kv * hd     # k, v over frames
            total += 2 * 2 * t * fr * h * hd                 # scores + pv
    total += 2 * t * cfg.d_model * cfg.vocab                 # lm head

    if cfg.encoder is not None and kind != "decode":
        # encoder runs once per step on (b, frames)
        te = float(b) * cfg.encoder.n_frames
        d, h, hd, fr = cfg.d_model, cfg.n_heads, cfg.head_dim, \
            cfg.encoder.n_frames
        enc = 2 * te * d * h * hd * 4 + 2 * 2 * te * fr * h * hd \
            + 2 * 2 * te * d * cfg.d_ff
        total += enc * cfg.encoder.n_layers
    return total


def train_flops(cfg: ModelConfig, shape: InputShape) -> float:
    return 3.0 * step_flops(cfg, shape)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """The 6*N*D (dense) / 6*N_active*D (MoE) reference."""
    n = cfg.active_param_count()
    if shape.kind == "decode":
        d_tokens = shape.global_batch
        return 2.0 * n * d_tokens          # inference: 2*N per token
    d_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * d_tokens
    return 6.0 * n * d_tokens


# ---------------------------------------------------------------------------
# HBM traffic model
# ---------------------------------------------------------------------------


def _param_bytes(cfg: ModelConfig, bytes_per_param: int) -> float:
    return float(cfg.param_count()) * bytes_per_param


def step_bytes(cfg: ModelConfig, shape: InputShape,
               param_bytes_per_el: int = 2,
               opt_bytes_per_el: int = 0) -> float:
    """Coarse HBM traffic: weights + optimizer slots + activations + cache.

    Documented model (EXPERIMENTS.md §Roofline): training reads weights
    twice (fwd, bwd) and writes once, reads+writes optimizer slots, and
    streams ~8 activation tensors of (tokens, d_model) per layer per pass;
    decode reads all weights once per token plus the KV cache.
    """
    pw = _param_bytes(cfg, param_bytes_per_el)
    d = cfg.d_model
    if shape.kind == "decode":
        cache = 0.0
        for mixer, _ in (cfg.prefix_pattern
                         + cfg.block_pattern * cfg.n_repeats):
            if mixer == "attn":
                if cfg.attention == "mla":
                    m = cfg.mla
                    row = m.kv_lora_rank + m.rope_head_dim
                elif cfg.attention == "swa":
                    row = min(shape.seq_len, cfg.window) / shape.seq_len \
                        * cfg.n_kv_heads * cfg.head_dim * 2
                else:
                    row = cfg.n_kv_heads * cfg.head_dim * 2
                cache += shape.global_batch * shape.seq_len * row * 2
            elif mixer == "mamba":
                cache += shape.global_batch * cfg.mamba.d_inner(d) \
                    * cfg.mamba.d_state * 4 * 2        # read + write fp32
            elif mixer == "rwkv":
                hd = cfg.rwkv.head_dim
                cache += shape.global_batch * (d // hd) * hd * hd * 4 * 2
        return pw + cache
    tokens = shape.global_batch * shape.seq_len
    act = 8.0 * tokens * d * 2
    layers = cfg.n_layers
    if shape.kind == "train":
        return 3 * pw + 2 * opt_bytes_per_el / max(param_bytes_per_el, 1) \
            * pw + 2 * act * layers
    return pw + act * layers          # prefill
