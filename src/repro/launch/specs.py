"""ShapeDtypeStruct stand-ins for every model input — the shannon/kernels
pattern: weak-type-correct, shardable, no device allocation.

``input_specs(cfg, shape, rules)`` returns the kwargs for the step
function being lowered:

- train:    {"batch": {tokens, labels, [frames|patches]}}
- prefill:  {"batch": {tokens, [frames|patches]}}
- decode:   {"token", "cache", "index", ["memory"]}
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import attention, transformer
from repro.sharding.rules import MeshRules


def _sds(shape, dtype, rules: Optional[MeshRules], logical):
    if rules is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = rules.act_spec(logical, shape)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(rules.mesh, spec))


def text_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Text-token length such that total sequence == shape.seq_len."""
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        return shape.seq_len - cfg.frontend.num_tokens
    return shape.seq_len


def batch_specs(cfg: ModelConfig, shape: InputShape,
                rules: Optional[MeshRules], with_labels: bool,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    b = shape.global_batch
    s = text_len(cfg, shape)
    batch = {"tokens": _sds((b, s), jnp.int32, rules, ("batch", "seq"))}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32, rules, ("batch", "seq"))
    if cfg.frontend is not None:
        if cfg.frontend.kind == "vision":
            batch["patches"] = _sds((b, cfg.frontend.num_tokens, cfg.d_model),
                                    dtype, rules, ("batch", "seq", "embed"))
        else:  # audio: encoder frames
            batch["frames"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                                   dtype, rules, ("batch", "frames", "embed"))
    elif cfg.encoder is not None:
        batch["frames"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                               dtype, rules, ("batch", "frames", "embed"))
    return batch


# ---------------------------------------------------------------------------
# cache axes (mirror transformer.init_cache structure)
# ---------------------------------------------------------------------------


def _block_cache_axes(cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        if cfg.attention == "mla":
            return {"ckv": ("batch", "cache_seq", "kv_lora"),
                    "k_rope": ("batch", "cache_seq", "head_dim")}
        return {"k": attention.cache_spec_axes(),
                "v": attention.cache_spec_axes()}
    if mixer == "mamba":
        return {"h": ("batch", "d_inner", "state"),
                "conv": ("batch", "conv", "d_inner")}
    if mixer == "rwkv":
        return {"x_prev": ("batch", "embed"),
                "s": ("batch", "heads", "head_dim", None)}
    raise ValueError(mixer)


def cache_axes(cfg: ModelConfig):
    axes: Dict[str, Any] = {}
    for i, (mixer, _) in enumerate(cfg.prefix_pattern):
        axes[f"prefix{i}"] = _block_cache_axes(cfg, mixer)
    stacked = {}
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        one = _block_cache_axes(cfg, mixer)
        stacked[f"pos{i}"] = jax.tree.map(
            lambda ax: ("layers",) + ax, one,
            is_leaf=lambda x: isinstance(x, tuple))
    axes["blocks"] = stacked
    return axes


def cache_specs(cfg: ModelConfig, shape: InputShape,
                rules: Optional[MeshRules], dtype=jnp.bfloat16):
    abstract = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch,
                                       shape.seq_len, dtype))
    if rules is None:
        return abstract
    ax = cache_axes(cfg)
    return jax.tree.map(
        lambda sds, a: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(rules.mesh,
                                   rules.act_spec(a, sds.shape))),
        abstract, ax,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
