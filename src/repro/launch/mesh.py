"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests see 1 CPU device; only dryrun.py
sets XLA_FLAGS for 512 host devices).
"""
from __future__ import annotations

import jax

# hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types, version-compat: AxisType
    landed after jax 0.4.x; older versions default to Auto anyway."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests)."""
    return make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
