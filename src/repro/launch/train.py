"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 100 --batch 8 --seq 128

Full-size configs on real hardware would use the same entry point with
--mesh (the dry-run proves those lower; this CPU container trains only
--reduced variants).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data.synthetic import make_lm_batches
from repro.launch.mesh import make_local_mesh
from repro.sharding.rules import MeshRules
from repro.train.trainer import TrainJob, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--metrics-dir", default=None)
    ap.add_argument("--mesh", action="store_true",
                    help="use a local (1,1) mesh with sharding rules")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rules = MeshRules(make_local_mesh()) if args.mesh else None
    job = TrainJob(cfg=cfg, lr=args.lr, steps=args.steps, seed=args.seed,
                   ckpt_dir=args.ckpt_dir, metrics_dir=args.metrics_dir,
                   rules=rules, log_every=max(1, args.steps // 20))
    batches = make_lm_batches(cfg.vocab, args.batch, args.seq,
                              args.steps + 1, seed=args.seed)
    res = train(job, batches)
    print(f"{args.arch}: final metrics {res['metrics']}")


if __name__ == "__main__":
    main()
