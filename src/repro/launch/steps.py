"""Step builders: train_step / prefill_step / decode_step factories with
sharding resolution — used by the trainer, the serving engine, and the
multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch import specs as S
from repro.models import params as PRM, transformer as T
from repro.sharding.rules import MeshRules, param_shardings, use_rules
from repro.train import optimizer as O


def resolve_param_shardings(cfg: ModelConfig, rules: Optional[MeshRules],
                            param_dtype=jnp.bfloat16):
    spec = T.model_spec(cfg)
    abstract = PRM.abstract_tree(spec, param_dtype)
    axes = PRM.axes_tree(spec)
    if rules is None:
        return abstract, axes, None
    sh = param_shardings(rules, axes, abstract)
    abstract = jax.tree.map(
        lambda sds, s: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=s),
        abstract, sh)
    return abstract, axes, sh


def _axes_to_shardings(rules: MeshRules, axes_tree, abstract_tree):
    return jax.tree.map(
        lambda sds, ax: NamedSharding(
            rules.mesh, rules.spec(ax, sds.shape, rules.param_rules,
                                   "opt_state")),
        abstract_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_state_specs(opt: O.Optimizer, abstract_params, axes,
                    rules: Optional[MeshRules]):
    abstract_state = jax.eval_shape(opt.init, abstract_params)
    if rules is None:
        return abstract_state
    state_axes = opt.state_axes(axes)
    return jax.tree.map(
        lambda sds, ax: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(
                rules.mesh,
                rules.spec(tuple(ax), sds.shape, rules.param_rules, "opt"))),
        abstract_state, state_axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# step functions (pure; rules bound at trace time via use_rules)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt: O.Optimizer, lr: float = 3e-4,
                    rules: Optional[MeshRules] = None,
                    compute_dtype=jnp.bfloat16, accum_steps: int = 1):
    """accum_steps > 1: microbatch gradient accumulation — the global
    batch is split along the batch dim and grads are averaged in fp32
    over a lax.scan. Exact for equal microbatches (tested); trades
    activation memory for accum_steps-fold more FSDP weight gathers."""

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch, compute_dtype),
            has_aux=True)(params)

    def train_step(params, opt_state, batch
                   ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
        with use_rules(rules):
            if accum_steps == 1:
                (loss, metrics), grads = grad_fn(params, batch)
            else:
                def micro(b):
                    return jax.tree.map(
                        lambda x: x.reshape(
                            (accum_steps, x.shape[0] // accum_steps)
                            + x.shape[1:]), b)

                def body(acc, mb):
                    (loss, metrics), grads = grad_fn(params, mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                        acc, grads)
                    return acc, metrics

                zero = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, metrics_stack = jax.lax.scan(body, zero,
                                                    micro(batch))
                grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                     grads, params)
                metrics = jax.tree.map(lambda m: m.mean(), metrics_stack)
            new_params, new_state = opt.update(grads, opt_state, params,
                                               jnp.asarray(lr, jnp.float32))
        return new_params, new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Optional[MeshRules] = None,
                      compute_dtype=jnp.bfloat16):
    def prefill_step(params, batch) -> jax.Array:
        with use_rules(rules):
            logits, _ = T.forward(cfg, params, batch, compute_dtype)
            # serving returns only the last-position logits
            return logits[:, -1, :]
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Optional[MeshRules] = None,
                     compute_dtype=jnp.bfloat16, with_memory: bool = False):
    def decode_step(params, token, cache, index, memory=None):
        with use_rules(rules):
            logits, new_cache = T.decode_step(
                cfg, params, token, cache, index, memory, compute_dtype)
        return logits, new_cache
    if not with_memory:
        return lambda params, token, cache, index: \
            decode_step(params, token, cache, index)
    return decode_step
