"""Repo-local test CA: self-signed certificates for the TLS transports.

Drives the ``openssl`` CLI (no Python dependency) to mint a throwaway
certificate authority plus per-agent EC certificates, so TLS'd
deployments — and CI — never need real PKI. Every leaf certificate
carries the SAN list the :class:`~repro.comm.base.TLSSpec` hostname
check verifies against (``localhost`` + ``127.0.0.1`` by default; pass
the real hostnames/IPs for multi-machine runs).

Library use::

    from repro.launch.certs import TestCA

    ca = TestCA("certs")                     # creates ca.crt / ca.key
    spec = ca.tls_spec("master")             # issues master.crt/.key
    job = VFLJob(cfg, master, members, mode="grpc",
                 comm_cfg=CommCfg(tls=spec))

CLI (what the docs/deploy.md walkthrough and the CI cluster job run)::

    python -m repro.launch.certs --dir certs \\
        --agents master member0 alpha beta --hosts localhost 127.0.0.1

These certificates are for testing and benchmarking only — production
deployments should use organization-issued certificates; the
``TLSSpec`` consumes any PEM chain.
"""
from __future__ import annotations

import argparse
import pathlib
import shutil
import subprocess
from typing import Optional, Sequence, Tuple

from repro.comm.base import TLSSpec

DEFAULT_HOSTS = ("localhost", "127.0.0.1")


def have_openssl() -> bool:
    """Is the ``openssl`` CLI on PATH? (Tests skip TLS cases if not.)"""
    return shutil.which("openssl") is not None


def _run(*args: str) -> None:
    proc = subprocess.run(["openssl", *args], capture_output=True,
                          text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"openssl {' '.join(args[:2])} failed:\n"
                           f"{proc.stderr.strip()}")


def _san(hosts: Sequence[str]) -> str:
    parts = []
    for h in hosts:
        kind = "IP" if h.replace(".", "").replace(":", "").isdigit() \
            or ":" in h else "DNS"
        parts.append(f"{kind}:{h}")
    return "subjectAltName=" + ",".join(parts)


class TestCA:
    """A directory-backed throwaway CA issuing per-agent certificates.

    The CA keypair is created on first use and reused afterwards, so
    repeated calls (e.g. every pytest session) are cheap; issued leaf
    certificates are cached by name. Keys are prime256v1 EC (fast to
    generate, universally supported by ``ssl``).

    Example::

        ca = TestCA("/tmp/certs", hosts=("localhost", "127.0.0.1"))
        cert, key = ca.issue("member0")
        spec = ca.tls_spec("member0")    # TLSSpec(cert, key, ca.crt)
    """

    __test__ = False          # not a pytest class, despite the name

    def __init__(self, directory, hosts: Sequence[str] = DEFAULT_HOSTS):
        if not have_openssl():
            raise RuntimeError("the openssl CLI is required to mint "
                               "test certificates")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.hosts = tuple(hosts)
        self.ca_cert = str(self.dir / "ca.crt")
        self.ca_key = str(self.dir / "ca.key")
        if not (self.dir / "ca.crt").exists():
            _run("ecparam", "-name", "prime256v1", "-genkey", "-noout",
                 "-out", self.ca_key)
            _run("req", "-x509", "-new", "-key", self.ca_key, "-out",
                 self.ca_cert, "-days", "3650", "-sha256", "-subj",
                 "/CN=repro-test-ca")
            # a fresh CA invalidates any leaves left from a previous
            # one — drop them so issue() regenerates under this CA
            # instead of reusing certificates that no longer chain
            for leaf in self.dir.glob("*.crt"):
                if leaf.name != "ca.crt":
                    leaf.unlink()

    def issue(self, name: str,
              hosts: Optional[Sequence[str]] = None) -> Tuple[str, str]:
        """Issue (or reuse) a certificate for agent ``name``; returns
        ``(cert_path, key_path)``. ``hosts`` lists the SAN entries the
        peer's hostname check must accept. A cached certificate is
        reused only when its recorded SAN list matches — re-minting
        with new hostnames (e.g. moving from localhost to real
        machines) regenerates instead of silently handing back a stale
        localhost-only certificate."""
        cert = self.dir / f"{name}.crt"
        key = self.dir / f"{name}.key"
        ext = self.dir / f"{name}.ext"     # kept: records the SAN list
        san = _san(hosts or self.hosts) + "\n"
        if not cert.exists() or not ext.exists() \
                or ext.read_text() != san:
            csr = self.dir / f"{name}.csr"
            ext.write_text(san)
            _run("ecparam", "-name", "prime256v1", "-genkey", "-noout",
                 "-out", str(key))
            _run("req", "-new", "-key", str(key), "-out", str(csr),
                 "-subj", f"/CN={name}")
            _run("x509", "-req", "-in", str(csr), "-CA", self.ca_cert,
                 "-CAkey", self.ca_key, "-CAcreateserial", "-out",
                 str(cert), "-days", "825", "-sha256", "-extfile",
                 str(ext))
            csr.unlink()
        return str(cert), str(key)

    def tls_spec(self, name: str,
                 hosts: Optional[Sequence[str]] = None,
                 server_hostname: Optional[str] = None,
                 check_hostname: bool = True) -> TLSSpec:
        """Issue a certificate for ``name`` and wrap it in a ready
        :class:`~repro.comm.base.TLSSpec` trusting this CA."""
        cert, key = self.issue(name, hosts)
        return TLSSpec(cert=cert, key=key, ca=self.ca_cert,
                       server_hostname=server_hostname,
                       check_hostname=check_hostname)

    def templated_spec(self, server_hostname: Optional[str] = None,
                       check_hostname: bool = True) -> TLSSpec:
        """A :class:`TLSSpec` with ``{agent}`` placeholder paths — one
        spec shared by every agent, each resolving its own issued
        certificate (the shape cluster specs and ``VFLJob`` use)."""
        return TLSSpec(cert=str(self.dir / "{agent}.crt"),
                       key=str(self.dir / "{agent}.key"),
                       ca=self.ca_cert,
                       server_hostname=server_hostname,
                       check_hostname=check_hostname)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.certs",
        description="Mint a test CA + per-agent TLS certificates "
                    "(testing only — not production PKI).")
    ap.add_argument("--dir", default="certs",
                    help="output directory (default: ./certs)")
    ap.add_argument("--agents", nargs="+", required=True,
                    help="certificate names to issue (agent ids and "
                         "launcher host names)")
    ap.add_argument("--hosts", nargs="+", default=list(DEFAULT_HOSTS),
                    help="SAN hostnames/IPs every certificate is valid "
                         "for (default: localhost 127.0.0.1)")
    args = ap.parse_args(argv)
    ca = TestCA(args.dir, hosts=args.hosts)
    for name in args.agents:
        cert, _ = ca.issue(name)
        print(f"issued {cert}")
    print(f"CA at {ca.ca_cert}; point TLSSpec.ca (and [comm.tls] in "
          f"cluster specs) at it")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
