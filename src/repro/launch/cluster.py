"""Multi-host cluster launcher: one spec file, one command per host.

The paper pitches "easily deploy learning in a distributed environment";
this module is the piece that makes the ``*_proc`` transport modes span
real machines. A single TOML/JSON *cluster spec* names every agent's
``host:port``, the transport (framing, timeouts, TLS, WAN shaping), the
protocol configuration and the data provider; each participating host
then runs::

    python -m repro.launch.cluster spec.toml --host alpha

and the launcher spawns/supervises that host's agents:

* **Rendezvous** — agents bind their listeners first, then launchers
  exchange readiness over a control channel (riding the transports'
  connect-retry, so independently booting hosts link up in any order).
* **Supervision** — a crashed agent's real traceback reaches the local
  launcher within its 0.2 s poll tick and is fanned out to every peer
  launcher over the control channel, so ALL launchers exit non-zero
  within seconds instead of hanging until a transport timeout (the
  cross-machine extension of the in-process dead-process watchdog).
* **Shutdown** — SIGTERM to a launcher fans out SIGTERM to its agents
  and notifies peers; per-agent stdout/stderr is captured under
  ``--log-dir`` (``<role>.log``, plus ``pids.json`` and, on success,
  ``summary.json``).

Exit codes: 0 success · 1 agent failure (local or remote) · 2 spec or
usage error · 3 rendezvous timeout · 143 terminated by signal.

See docs/deploy.md for the spec schema and a two-machine walkthrough;
``python -m repro.launch.certs`` mints the TLS material. For testing a
spec without any launcher, ``VFLJob.from_spec(spec)`` runs the whole
federation in-process over the spec's transport settings.
"""
from __future__ import annotations

import argparse
import importlib
import json
import multiprocessing as mp
import os
import pathlib
import queue
import signal
import sys
import time
import traceback
from dataclasses import dataclass, field, fields
from typing import (Any, Callable, Dict, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.comm.base import CommCfg, LinkSpec, TLSSpec
from repro.comm.grpc import GrpcCommunicator
from repro.comm.sock import SocketCommunicator
from repro.core.protocols.driver import Callback, Checkpointer, ElasticCfg

# ---------------------------------------------------------------------------
# minimal TOML (Python 3.10 has no tomllib; the subset below covers
# cluster specs: [table.sub] headers, strings, numbers, bools, arrays)
# ---------------------------------------------------------------------------


def _toml_scalar(s: str) -> Any:
    s = s.strip()
    if s.startswith('"') and s.endswith('"') and len(s) >= 2:
        return s[1:-1]
    if s.startswith("'") and s.endswith("'") and len(s) >= 2:
        return s[1:-1]
    if s == "true":
        return True
    if s == "false":
        return False
    if s.startswith("[") and s.endswith("]"):
        body = s[1:-1].strip()
        if not body:
            return []
        parts, depth, cur = [], 0, ""
        for ch in body:
            if ch == "," and depth == 0:
                parts.append(cur)
                cur = ""
                continue
            if ch == "[":
                depth += 1
            elif ch == "]":
                depth -= 1
            cur += ch
        parts.append(cur)
        # TOML allows a trailing comma in arrays
        if parts and not parts[-1].strip():
            parts.pop()
        return [_toml_scalar(p) for p in parts]
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    raise ValueError(f"unsupported TOML value: {s!r}")


def parse_toml(text: str) -> Dict[str, Any]:
    """Parse the cluster-spec TOML subset (uses :mod:`tomllib` when the
    interpreter has it, Python >= 3.11)."""
    try:
        import tomllib
        return tomllib.loads(text)
    except ModuleNotFoundError:
        pass
    def _strip_comment(val: str) -> str:
        out, quote = "", None
        for ch in val:
            if quote:
                if ch == quote:
                    quote = None
            elif ch in "\"'":
                quote = ch
            elif ch == "#":
                break
            out += ch
        return out

    root: Dict[str, Any] = {}
    table = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        ln, line = i + 1, lines[i].strip()
        i += 1
        if not line or line.startswith("#"):
            continue
        if line.startswith("[") and line.endswith("]"):
            table = root
            for part in line[1:-1].strip().split("."):
                table = table.setdefault(part.strip(), {})
            continue
        if "=" not in line:
            raise ValueError(f"TOML line {ln}: expected key = value, "
                             f"got {line!r} (parser supports the "
                             f"cluster-spec subset; see docs/deploy.md)")
        key, _, val = line.partition("=")
        val = _strip_comment(val)
        # multi-line arrays: keep consuming lines until brackets close
        while val.count("[") > val.count("]"):
            if i >= len(lines):
                raise ValueError(f"TOML line {ln}: unterminated array "
                                 f"for key {key.strip()!r}")
            val += " " + _strip_comment(lines[i].strip())
            i += 1
        table[key.strip()] = _toml_scalar(val)
    return root


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------


def _addr(s: Union[str, Sequence[Any]]) -> Tuple[str, int]:
    if isinstance(s, str):
        host, _, port = s.rpartition(":")
        return host, int(port)
    host, port = s
    return str(host), int(port)


@dataclass
class HostSpec:
    """One launcher invocation: its control endpoint + owned agents."""
    control: Tuple[str, int]
    agents: List[str]


@dataclass
class RestartPolicy:
    """Per-role supervision policy from the spec's ``[restart]`` table.

    ``policy="never"`` (default) keeps PR 5's fail-fast: any crash
    aborts every launcher. ``policy="on_failure"`` makes the owning
    launcher respawn the agent up to ``max_restarts`` times with
    exponential backoff (``backoff_s * 2^attempt``, capped at
    ``backoff_max_s``); the restarted agent resumes from its local
    checkpoint (written every ``checkpoint_every`` rounds) and rejoins
    the paused master, which waits up to ``wait_s`` for the rejoin
    hello. Only members may be restartable — crashes of the master or
    arbiter, and any crash before rendezvous or outside the fit phase,
    stay fail-fast. See docs/deploy.md.
    """

    policy: str = "never"              # "never" | "on_failure"
    max_restarts: int = 3
    backoff_s: float = 0.5
    backoff_max_s: float = 10.0
    wait_s: float = 60.0               # master-side rejoin wait
    checkpoint_every: int = 1


@dataclass
class ChaosSpec:
    """Fault injection from the spec's ``[chaos]`` table: at global
    step ``step`` on agent ``role`` (a name or a list of names — a
    list injects the same fault on every named agent in the same
    round, the *correlated* failure case), run ``scenario`` —

    * ``"crash"`` — raise inside the driver loop (the process dies;
      pair with ``[restart]`` to exercise the rejoin path),
    * ``"partition"`` — blackhole the agent's outbound link
      (``LinkSpec(loss=loss)``, default drop-everything),
    * ``"slow"`` — inflate the agent's outbound latency to
      ``latency_ms`` mid-run (the straggler scenario; pair with
      ``round_deadline_s`` at depth >= 2 to see stale substitution).

    ``repeat=true`` re-arms the fault on every supervisor respawn —
    the restarted agent resumes from a checkpoint at/past the chaos
    step and crashes again immediately, the crash-loop that must end
    in an attributed restart-budget exhaustion, not a hang.
    """

    role: Union[str, List[str]]
    step: int
    scenario: str = "crash"            # "crash" | "partition" | "slow"
    latency_ms: float = 250.0          # "slow" link latency
    loss: float = 1.0                  # "partition" drop probability
    repeat: bool = False               # re-arm on supervisor respawn

    @property
    def roles(self) -> List[str]:
        """The fault's victims, normalized to a list."""
        return [self.role] if isinstance(self.role, str) \
            else list(self.role)


@dataclass
class ServeSpec:
    """The spec's ``[serve]`` table: deploy a persistent federated
    inference service (docs/serving.md) when ``"serve"`` appears in
    ``[run] phases``. The master hosts a
    :class:`~repro.serve.federated.FederatedServer` behind a TCP
    frontend; members stay parked in the serve session answering
    coalesced query rounds."""

    port: int = 18080                 # frontend port on the master's host
    host: str = "0.0.0.0"             # frontend bind address
    max_batch: int = 64               # rows per federated round
    max_wait_ms: float = 2.0          # batcher hold for an under-full round
    admission_limit: int = 4096       # queued-row bound before shedding
    cache_rows: int = 0               # member embed-cache capacity (rows)
    duration_s: float = 0.0           # serve window; 0 = until stop_file
    stop_file: str = ""               # path whose appearance ends serving


@dataclass
class ClusterSpec:
    """Parsed cluster spec — everything a launcher (or
    :meth:`~repro.core.party.VFLJob.from_spec`) needs to run the
    federation.

    Built from a TOML/JSON file via :func:`load_spec`; see
    docs/deploy.md for the on-disk schema. All fields are plain
    dataclasses, so a spec pickles into spawned agent processes as-is.

    Example (``make_communicator`` needs the spec's TLS certificates
    on disk — see ``python -m repro.launch.certs``)::

        spec = load_spec("examples/cluster/quickstart_cluster.toml")
        spec.validate()                            # no files touched
        comm = spec.make_communicator("member0")   # TLS'd, full map
        data = spec.build_data("member0")
    """

    cfg: Any                                  # VFLConfig
    agents: Dict[str, Tuple[str, int]]
    hosts: Dict[str, HostSpec]
    comm: CommCfg = CommCfg()
    framing: str = "grpc"                     # "sock" | "grpc"
    run_phases: List[str] = field(default_factory=lambda: ["fit"])
    data_provider: str = "repro.launch.cluster:quickstart_data"
    data_kwargs: Dict[str, Any] = field(default_factory=dict)
    barrier_timeout: float = 60.0
    control_tls: bool = True
    chaos: Optional[ChaosSpec] = None
    # per-role restart policies; "*" is the member-wide default set by
    # flat [restart] keys, explicit [restart.<role>] entries override
    restart: Dict[str, RestartPolicy] = field(default_factory=dict)
    serve: Optional[ServeSpec] = None
    # per-link overrides: [comm.<a>.<b>] tables, keyed by the (a, b)
    # role pair. Edges are symmetric (shape both directions) and each
    # pair appears once; values hold only edge-scoped keys (timeout,
    # latency_ms, bandwidth_mbps, jitter_ms, loss) — resolved against
    # the flat [comm] defaults by :meth:`comm_for`
    comm_edges: Dict[Tuple[str, str], Dict[str, Any]] = \
        field(default_factory=dict)

    # -- structure -----------------------------------------------------------
    @property
    def n_members(self) -> int:
        return sum(1 for a in self.agents if a.startswith("member"))

    def world(self) -> List[str]:
        from repro.core.party import world_for
        return world_for(self.cfg, self.n_members)

    def agents_of(self, host: str) -> List[str]:
        if host not in self.hosts:
            raise KeyError(f"host {host!r} not in spec "
                           f"(hosts: {sorted(self.hosts)})")
        return list(self.hosts[host].agents)

    def restart_of(self, role: str) -> RestartPolicy:
        """Effective restart policy for ``role``: its explicit
        ``[restart.<role>]`` entry, else the member-wide flat
        ``[restart]`` default (members only), else fail-fast."""
        rp = self.restart.get(role)
        if rp is None and role.startswith("member"):
            rp = self.restart.get("*")
        return rp if rp is not None else RestartPolicy()

    def restartable_roles(self) -> List[str]:
        return [r for r in sorted(self.agents)
                if self.restart_of(r).policy == "on_failure"]

    def validate(self) -> None:
        expected = set(self.world())
        have = set(self.agents)
        if have != expected:
            raise ValueError(
                f"[agents] must name exactly the protocol's world "
                f"{sorted(expected)}; got {sorted(have)}")
        if self.framing not in ("sock", "grpc"):
            raise ValueError(f"[comm] framing must be 'sock' or "
                             f"'grpc', got {self.framing!r}")
        assigned: List[str] = []
        for hs in self.hosts.values():
            assigned += hs.agents
        if sorted(assigned) != sorted(have):
            dup = {a for a in assigned if assigned.count(a) > 1}
            missing = have - set(assigned)
            unknown = set(assigned) - have
            raise ValueError(
                f"[hosts] must assign every agent to exactly one "
                f"host (duplicates: {sorted(dup)}, unassigned: "
                f"{sorted(missing)}, unknown: {sorted(unknown)})")
        for phase in self.run_phases:
            if phase not in ("fit", "evaluate", "predict", "serve"):
                raise ValueError(f"[run] unknown phase {phase!r}")
        if "serve" in self.run_phases:
            ss = self.serve or ServeSpec()
            if ss.duration_s <= 0 and not ss.stop_file:
                raise ValueError(
                    "[serve] needs a bounded lifetime: set duration_s "
                    "> 0 and/or stop_file (the service ends when the "
                    "window closes or the file appears)")
        if self.chaos is not None:
            if not self.chaos.roles:
                raise ValueError("[chaos] role must name at least one "
                                 "agent")
            for cr in self.chaos.roles:
                if cr not in have:
                    raise ValueError(f"[chaos] role {cr!r} is not an "
                                     f"agent")
            if self.chaos.scenario not in ("crash", "partition", "slow"):
                raise ValueError(
                    f"[chaos] unknown scenario {self.chaos.scenario!r} "
                    f"(valid: crash, partition, slow)")
        for key, rp in self.restart.items():
            if rp.policy not in ("never", "on_failure"):
                raise ValueError(f"[restart] unknown policy "
                                 f"{rp.policy!r} for {key!r} "
                                 f"(valid: never, on_failure)")
            if key != "*" and key not in have:
                raise ValueError(f"[restart] role {key!r} is not an "
                                 f"agent")
        restartable = self.restartable_roles()
        bad = [r for r in restartable if not r.startswith("member")]
        if bad:
            raise ValueError(
                f"[restart] only members may use policy='on_failure' "
                f"(got {bad}); the master coordinates the rejoin and "
                f"cannot itself be elastic")
        if restartable and (self.cfg.secure_agg
                            or self.cfg.protocol == "secure_agg"):
            raise ValueError(
                "[restart] elastic members are unsupported with secure "
                "aggregation: a restarted member's pairwise masks "
                "desync from the survivors'")
        for (a, b) in self.comm_edges:
            for r in (a, b):
                if r not in have:
                    raise ValueError(
                        f"[comm.{a}.{b}] {r!r} is not an agent "
                        f"(agents: {sorted(have)})")
            if a == b:
                raise ValueError(f"[comm.{a}.{b}] is a self-edge")
            if (b, a) in self.comm_edges:
                raise ValueError(
                    f"[comm.{a}.{b}] duplicates [comm.{b}.{a}] — "
                    f"edges are symmetric, name each pair once")
        # composable towers (repro.models.tower): block structure is
        # checkable now; concrete widths resolve at setup time from
        # the data provider's feature slices
        from repro.models.tower import check_blocks
        for attr in ("tower", "top_tower"):
            blocks = getattr(self.cfg, attr, ())
            if blocks:
                try:
                    check_blocks(blocks)
                except ValueError as e:
                    raise ValueError(
                        f"[protocol] {attr}: {e}") from None
        if getattr(self.cfg, "tower_shard", 1) < 1:
            raise ValueError("[protocol] tower_shard must be >= 1")

    # -- construction --------------------------------------------------------
    _EDGE_LINK_KEYS = ("latency_ms", "bandwidth_mbps", "jitter_ms",
                       "loss")

    def comm_for(self, role: str) -> CommCfg:
        """``role``'s effective :class:`CommCfg`: the flat ``[comm]``
        defaults, plus ``peer_overrides`` for every ``[comm.a.b]``
        edge touching ``role`` (edges are symmetric — both endpoints
        shape the same link). An override carries only the fields its
        edge table actually sets: a timeout-only edge keeps
        ``link=None`` so the transport leaves it on the shared world
        link (and runtime ``set_link`` swaps still reach it) instead
        of pinning a private copy. Identical to ``self.comm`` when
        the spec has no edge tables."""
        from dataclasses import replace
        over: Dict[str, CommCfg] = {}
        for (a, b), ed in self.comm_edges.items():
            peer = b if a == role else a if b == role else None
            if peer is None:
                continue
            lk = {k: float(ed[k]) for k in self._EDGE_LINK_KEYS
                  if k in ed}
            over[peer] = replace(
                self.comm,
                link=replace(self.comm.link or LinkSpec(), **lk)
                if lk else None,
                timeout=float(ed["timeout"]) if "timeout" in ed
                else None,
                peer_overrides=None)
        if not over:
            return self.comm
        return replace(self.comm, peer_overrides=over)

    def make_communicator(self, role: str):
        """Build ``role``'s transport communicator with the full
        address map and the spec's :class:`CommCfg` (TLS and per-link
        ``[comm.a.b]`` overrides included)."""
        cls = SocketCommunicator if self.framing == "sock" \
            else GrpcCommunicator
        comm = self.comm_for(role)
        if self.restartable_roles():
            # elastic clusters need drop attribution even for clean
            # EOFs: a SIGKILL'd agent's kernel closes its sockets
            # tidily, and the master must notice within milliseconds
            from dataclasses import replace
            comm = replace(comm, strict_eof=True)
        return cls(role, dict(self.agents), comm_cfg=comm)

    def control_comm(self, host: str) -> SocketCommunicator:
        """The launcher↔launcher control channel: a tiny sock-framed
        world of the host names, TLS'd like the data plane (unless
        ``control_tls=false``)."""
        addrs = {h: hs.control for h, hs in self.hosts.items()}
        cfg = CommCfg(timeout=self.barrier_timeout,
                      tls=self.comm.tls if self.control_tls else None)
        return SocketCommunicator(host, addrs, comm_cfg=cfg)

    def build_data(self, role: str):
        """Call the spec's data provider for ``role`` (each host builds
        its own agents' data locally — nothing raw crosses the wire)."""
        modname, _, fname = self.data_provider.partition(":")
        if not fname:
            raise ValueError("[data] provider must be 'module:function'"
                             f", got {self.data_provider!r}")
        fn: Callable = getattr(importlib.import_module(modname), fname)
        return fn(role, **self.data_kwargs)


def load_spec(spec: Union[str, pathlib.Path, Dict[str, Any],
                          ClusterSpec]) -> ClusterSpec:
    """Load a cluster spec from a ``.toml``/``.json`` path, an
    already-parsed dict, or pass a :class:`ClusterSpec` through.

    Relative TLS certificate paths are resolved against the spec
    file's directory (an ``{agent}`` placeholder survives resolution
    and is substituted per agent by the transport).

    Example::

        spec = load_spec("examples/cluster/quickstart_cluster.toml")
        print(spec.world(), spec.framing)
    """
    if isinstance(spec, ClusterSpec):
        return spec
    base = pathlib.Path(".")
    if isinstance(spec, (str, pathlib.Path)):
        path = pathlib.Path(spec)
        base = path.parent
        text = path.read_text()
        raw = json.loads(text) if path.suffix == ".json" \
            else parse_toml(text)
    else:
        raw = dict(spec)
    return _spec_from_dict(raw, base)


def _spec_from_dict(raw: Dict[str, Any],
                    base: pathlib.Path) -> ClusterSpec:
    from repro.core.protocols.base import VFLConfig
    proto = dict(raw.get("protocol") or {})
    name = proto.pop("name", None)
    if name:
        proto["protocol"] = name
    valid = {f.name for f in fields(VFLConfig)}
    unknown = set(proto) - valid
    if unknown:
        raise ValueError(f"[protocol] unknown VFLConfig fields "
                         f"{sorted(unknown)} (valid: {sorted(valid)})")
    proto = {k: tuple(v) if isinstance(v, list) else v
             for k, v in proto.items()}
    cfg = VFLConfig(**proto)

    comm_raw = dict(raw.get("comm") or {})
    framing = comm_raw.pop("framing", "grpc")
    link = comm_raw.pop("link", None)
    tls = comm_raw.pop("tls", None)
    ckw: Dict[str, Any] = {}
    for k in ("timeout", "nodelay", "encode_offload"):
        if k in comm_raw:
            ckw[k] = comm_raw.pop(k)
    barrier = comm_raw.pop("barrier_timeout", 60.0)
    control_tls = comm_raw.pop("control_tls", True)
    # per-link overrides: [comm.a.b] tables scope edge settings to the
    # a<->b link; flat [comm] keys stay the every-edge default
    edge_keys = ("timeout", "latency_ms", "bandwidth_mbps",
                 "jitter_ms", "loss")
    edges: Dict[Tuple[str, str], Dict[str, Any]] = {}
    for a in [k for k, v in comm_raw.items() if isinstance(v, dict)]:
        sub = comm_raw.pop(a)
        for b, ed in sub.items():
            if not isinstance(ed, dict):
                raise ValueError(
                    f"[comm.{a}] expected per-peer tables "
                    f"([comm.{a}.<role>]), got key {b!r}")
            unknown = set(ed) - set(edge_keys)
            if unknown:
                raise ValueError(
                    f"[comm.{a}.{b}] unknown keys {sorted(unknown)} "
                    f"(valid: {sorted(edge_keys)}; connection-level "
                    f"settings like tls/nodelay stay in flat [comm])")
            edges[(a, b)] = dict(ed)
    if comm_raw:
        raise ValueError(f"[comm] unknown keys {sorted(comm_raw)}")
    if link is not None:
        ckw["link"] = LinkSpec(**link)
    if tls is not None:
        def _p(p: str) -> str:
            return p if os.path.isabs(p) else str(base / p)
        ckw["tls"] = TLSSpec(
            cert=_p(tls["cert"]), key=_p(tls["key"]), ca=_p(tls["ca"]),
            server_hostname=tls.get("server_hostname"),
            check_hostname=tls.get("check_hostname", True))

    agents = {a: _addr(v) for a, v in (raw.get("agents") or {}).items()}
    hosts = {h: HostSpec(control=_addr(hv["control"]),
                         agents=list(hv.get("agents", [])))
             for h, hv in (raw.get("hosts") or {}).items()}

    run = dict(raw.get("run") or {})
    data = dict(raw.get("data") or {})
    provider = data.pop("provider",
                        "repro.launch.cluster:quickstart_data")
    chaos_raw = raw.get("chaos")
    chaos = None
    if chaos_raw:
        ckeys = {f.name for f in fields(ChaosSpec)}
        unknown = set(chaos_raw) - ckeys
        if unknown:
            raise ValueError(f"[chaos] unknown keys {sorted(unknown)} "
                             f"(valid: {sorted(ckeys)})")
        chaos = ChaosSpec(**{**chaos_raw, "step": int(chaos_raw["step"])})

    serve_raw = raw.get("serve")
    serve = None
    if serve_raw:
        skeys = {f.name for f in fields(ServeSpec)}
        unknown = set(serve_raw) - skeys
        if unknown:
            raise ValueError(f"[serve] unknown keys {sorted(unknown)} "
                             f"(valid: {sorted(skeys)})")
        serve = ServeSpec(**serve_raw)
        if serve.cache_rows:
            # the member-side embed cache is a protocol knob — every
            # agent's VFLConfig must agree on it
            cfg.serve_cache_rows = int(serve.cache_rows)

    restart_raw = dict(raw.get("restart") or {})
    rkeys = {f.name for f in fields(RestartPolicy)}

    def _policy(d: Dict[str, Any], where: str) -> RestartPolicy:
        unknown = set(d) - rkeys
        if unknown:
            raise ValueError(f"[restart{where}] unknown keys "
                             f"{sorted(unknown)} (valid: "
                             f"{sorted(rkeys)})")
        return RestartPolicy(**d)

    per_role = {k: v for k, v in restart_raw.items()
                if isinstance(v, dict)}
    flat = {k: v for k, v in restart_raw.items()
            if not isinstance(v, dict)}
    restart: Dict[str, RestartPolicy] = {}
    if flat:
        restart["*"] = _policy(flat, "")
    for role, d in per_role.items():
        restart[role] = _policy({**flat, **d}, f".{role}")

    return ClusterSpec(
        cfg=cfg, agents=agents, hosts=hosts, comm=CommCfg(**ckw),
        framing=framing,
        run_phases=list(run.get("phases", ["fit"])),
        data_provider=provider, data_kwargs=data,
        barrier_timeout=float(barrier), control_tls=bool(control_tls),
        chaos=chaos, restart=restart, serve=serve, comm_edges=edges)


# ---------------------------------------------------------------------------
# built-in data providers (each host rebuilds its slice locally from
# the shared seed — deterministic, nothing raw crosses the wire)
# ---------------------------------------------------------------------------


def quickstart_data(role: str, seed: int = 0, **_: Any):
    """The quickstart's SBOL-like two-silo recommendation dataset,
    sliced for ``role`` (the cluster-spec default provider)."""
    from repro.configs.vfl_recsys import VFLRecsysConfig
    from repro.core.protocols.base import MasterData, MemberData
    from repro.data.synthetic import make_recsys_silos
    data = make_recsys_silos(VFLRecsysConfig().reduced(), seed=seed)
    if role == "master":
        return MasterData(data.ids, data.labels.astype(np.float64),
                          data.features)
    if role.startswith("member"):
        i = int(role[len("member"):])
        return MemberData(data.member_ids[i], data.member_features[i])
    return None


def linreg_demo_data(role: str, n: int = 192, d: int = 12,
                     items: int = 2, widths: Sequence[int] = (4, 3),
                     seed: int = 0, **_: Any):
    """Tiny synthetic vertically-partitioned regression set — the
    cheapest cluster smoke workload (no jax compute)."""
    from repro.core.protocols.base import MasterData  # noqa: F401
    from repro.data.vertical import vertical_partition
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y,
                                         widths=list(widths),
                                         overlap=1.0, seed=1)
    if role == "master":
        return master
    if role.startswith("member"):
        return members[int(role[len("member"):])]
    return None


def logreg_he_demo_data(role: str, n: int = 192, d: int = 12,
                        widths: Sequence[int] = (5, 5),
                        seed: int = 0, **_: Any):
    """Synthetic vertically-partitioned binary-classification set for
    ``logreg_he`` cluster smokes (master keeps the remainder columns
    plus the labels; arbiter roles — however many the spec's
    ``n_arbiters`` asks for — get no data at all)."""
    from repro.data.vertical import vertical_partition
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, 1))
    y = (1.0 / (1.0 + np.exp(-(x @ w))) > 0.5).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y,
                                         widths=list(widths),
                                         overlap=1.0, seed=1)
    if role == "master":
        return master
    if role.startswith("member"):
        return members[int(role[len("member"):])]
    return None


# ---------------------------------------------------------------------------
# agent child process
# ---------------------------------------------------------------------------


def _json_safe(obj: Any, _depth: int = 0) -> Any:
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    if isinstance(obj, (np.integer, np.floating)):
        return obj.item()
    if isinstance(obj, dict) and _depth < 4:
        out = {}
        for k, v in obj.items():
            v = _json_safe(v, _depth + 1)
            if v is not ...:
                out[str(k)] = v
        return out
    if isinstance(obj, (list, tuple)) and _depth < 4 and len(obj) <= 64:
        vals = [_json_safe(v, _depth + 1) for v in obj]
        return [v for v in vals if v is not ...]
    return ...                                 # dropped (arrays, objects)


class _ChaosCrash(Callback):
    """Driver callback that crashes its agent at a given step — the
    knob the chaos CI job (and any user validating their supervision
    story) flips via the spec's ``[chaos]`` table."""

    def __init__(self, step: int):
        self.step = step

    def on_batch_end(self, driver, step, epoch, loss) -> None:
        if step >= self.step:
            raise RuntimeError(
                f"chaos: injected crash at step {step}")


class _ChaosLink(Callback):
    """Driver callback that swaps the agent's outbound link spec once
    at a given step — the ``partition`` (blackhole) and ``slow``
    (latency-inflation) chaos scenarios."""

    def __init__(self, step: int, link: LinkSpec):
        self.step = step
        self.link = link
        self._fired = False

    def on_batch_end(self, driver, step, epoch, loss) -> None:
        if not self._fired and step >= self.step:
            self._fired = True
            print(f"chaos: link -> {self.link} at step {step}",
                  flush=True)
            driver.ch.comm.set_link(self.link)


def _chaos_callbacks(spec: ClusterSpec, role: str) -> List[Callback]:
    ch = spec.chaos
    if ch is None or role not in ch.roles:
        return []
    if ch.scenario == "crash":
        return [_ChaosCrash(ch.step)]
    if ch.scenario == "partition":
        return [_ChaosLink(ch.step, LinkSpec(loss=ch.loss))]
    if ch.scenario == "slow":
        return [_ChaosLink(ch.step, LinkSpec(latency_ms=ch.latency_ms))]
    raise ValueError(f"unknown chaos scenario {ch.scenario!r}")


def _serve_phase(spec: ClusterSpec, agent) -> Dict[str, Any]:
    """Master-side ``serve`` phase: host the federated inference
    service behind its TCP frontend until the spec's lifetime ends
    (``duration_s`` elapsed and/or ``stop_file`` appeared), then return
    the final ServeStats snapshot for the summary."""
    from repro.serve.federated import (FederatedServer, ServeCfg,
                                       ServeFrontend)
    ss = spec.serve or ServeSpec()
    scfg = ServeCfg(max_batch=ss.max_batch, max_wait_ms=ss.max_wait_ms,
                    admission_limit=ss.admission_limit,
                    cache_rows=ss.cache_rows)
    srv = FederatedServer(agent, scfg).start()
    fe = ServeFrontend(srv, host=ss.host, port=ss.port)
    try:
        print(f"[master] serving on {ss.host}:{fe.port} "
              f"(max_batch={ss.max_batch} "
              f"max_wait_ms={ss.max_wait_ms})", flush=True)
        deadline = time.monotonic() + ss.duration_s \
            if ss.duration_s > 0 else None
        stop = pathlib.Path(ss.stop_file) if ss.stop_file else None
        while True:
            time.sleep(0.25)
            if deadline is not None and time.monotonic() > deadline:
                break
            if stop is not None and stop.exists():
                break
    finally:
        fe.close()
    return srv.stop()


def _cluster_agent_main(spec: ClusterSpec, role: str, log_path: str,
                        status_q, rejoin: bool = False) -> None:
    """Entry point of one spawned agent process (module-level for
    spawn picklability). Reports ("ready"|"ok"|"error", role, info) on
    ``status_q``; stdout/stderr land in ``log_path``. ``rejoin=True``
    marks a supervisor respawn: the agent restores state from its
    checkpoint directory and enters the master's paused fit via the
    rejoin handshake."""
    lf = open(log_path, "ab", buffering=0)
    os.dup2(lf.fileno(), 1)
    os.dup2(lf.fileno(), 2)
    sys.stdout = os.fdopen(1, "w", buffering=1, closefd=False)
    sys.stderr = os.fdopen(2, "w", buffering=1, closefd=False)
    comm = None
    try:
        from repro.core.party import Arbiter, PartyMaster, PartyMember
        comm = spec.make_communicator(role)
        status_q.put(("ready", role, os.getpid()))
        data = spec.build_data(role)
        # a chaos fault is injected ONCE by default — the supervisor's
        # respawn of the victim must not re-arm it (it would crash
        # again instantly and burn the whole restart budget on one
        # scripted fault). [chaos] repeat=true opts into exactly that
        # burn: the crash-loop scenario that must end in an attributed
        # restart-budget exhaustion rather than a hang.
        rearm = spec.chaos is not None and spec.chaos.repeat
        callbacks = _chaos_callbacks(spec, role) \
            if (not rejoin or rearm) else []
        restartable = spec.restartable_roles()
        elastic = None
        resume_dir = None
        if restartable and role == "master":
            elastic = ElasticCfg(
                roles=frozenset(restartable),
                wait_s=max(spec.restart_of(r).wait_s
                           for r in restartable))
        elif role in restartable:
            # the agent's checkpoint directory sits beside its log;
            # save_on_start guarantees a rejoinable cut exists from
            # step 0. Only a supervisor respawn resumes from it — a
            # fresh run ignores (and then overwrites) leftovers.
            rp = spec.restart_of(role)
            ckpt = str(pathlib.Path(log_path).parent / "ckpt")
            callbacks.append(Checkpointer(
                ckpt, every_steps=rp.checkpoint_every,
                save_on_start=True))
            if rejoin:
                resume_dir = ckpt
        if role == "master":
            agent = PartyMaster(comm, spec.cfg, callbacks=callbacks,
                                elastic=elastic)
            summary: Dict[str, Any] = {}
            for phase in spec.run_phases:
                print(f"[{role}] phase {phase}", flush=True)
                if phase == "fit":
                    r = agent.fit(data)
                    h = r["history"]
                    summary["fit"] = {
                        "n_common": r["n_common"], "steps": len(h),
                        "first_loss": h[0]["loss"] if h else None,
                        "final_loss": h[-1]["loss"] if h else None,
                        "wall_s": h[-1]["wall_s"] if h else None}
                    if r.get("recoveries"):
                        summary["recoveries"] = _json_safe(
                            r["recoveries"])
                elif phase == "evaluate":
                    summary["evaluate"] = _json_safe(agent.evaluate())
                elif phase == "predict":
                    scores = agent.predict()
                    summary["predict"] = {"rows": int(scores.shape[0])}
                elif phase == "serve":
                    summary["serve"] = _serve_phase(spec, agent)
            res = agent.shutdown()
            summary["comm"] = _json_safe(res.get("comm"))
            if res.get("roofline"):
                # per-step compute-vs-wire split (launch/roofline.py)
                summary["roofline"] = _json_safe(res["roofline"])
            status_q.put(("ok", role, summary))
        else:
            agent = PartyMember(comm, spec.cfg, callbacks=callbacks,
                                resume_dir=resume_dir) \
                if role.startswith("member") \
                else Arbiter(comm, spec.cfg, callbacks=callbacks)
            res = agent.serve(data, rejoin=rejoin) \
                if role.startswith("member") else agent.serve()
            out = {"comm": _json_safe(res.get("comm"))}
            if res.get("roofline"):
                out["roofline"] = _json_safe(res["roofline"])
            status_q.put(("ok", role, out))
    except BaseException:
        tb = traceback.format_exc()
        print(tb, file=sys.stderr, flush=True)
        # the traceback must reach the supervisor BEFORE this process
        # dies — the launcher turns it into its own exit diagnostics
        status_q.put(("error", role, tb))
        raise
    finally:
        if comm is not None:
            comm.close()


# ---------------------------------------------------------------------------
# the launcher
# ---------------------------------------------------------------------------


class _ClusterFailed(Exception):
    def __init__(self, code: int):
        self.code = code


class ClusterLauncher:
    """Spawn + supervise one host's agents from a :class:`ClusterSpec`.

    ``run()`` blocks until every local agent finished (exit 0), any
    agent — local or on a peer launcher — failed (exit 1), rendezvous
    timed out (exit 3), or :meth:`request_stop` was called (exit 143).
    The CLI (``python -m repro.launch.cluster``) is a thin wrapper that
    adds SIGTERM/SIGINT handling.

    Example::

        spec = load_spec("spec.toml")
        rc = ClusterLauncher(spec, host="alpha",
                             log_dir="runs/alpha").run()
    """

    POLL_S = 0.2

    def __init__(self, spec: ClusterSpec, host: str,
                 log_dir: Union[str, pathlib.Path] = "runs/cluster"):
        spec.validate()
        self.spec = spec
        self.host = host
        self.roles = spec.agents_of(host)
        self.log_dir = pathlib.Path(log_dir)
        self.peers = [h for h in spec.hosts if h != host]
        self._stop = False
        self._procs: Dict[str, mp.process.BaseProcess] = {}
        self._ok: Dict[str, Any] = {}
        self._exit_seen: Dict[str, float] = {}
        self._ctl: Optional[SocketCommunicator] = None
        self._fail_futs: Dict[str, Any] = {}
        # elastic supervision: restart attempts per role and scheduled
        # respawn times (monotonic)
        self._restarts: Dict[str, int] = {}
        self._pending_restart: Dict[str, float] = {}
        self._pids: Dict[str, int] = {}
        self._ctx = None

    def request_stop(self) -> None:
        """Ask ``run()`` to terminate local agents and exit 143 (wired
        to SIGTERM/SIGINT by the CLI)."""
        self._stop = True

    # -- internals -----------------------------------------------------------
    def _log(self, msg: str) -> None:
        print(f"[launcher {self.host}] {msg}", flush=True)

    def _terminate_local(self) -> None:
        for p in self._procs.values():
            if p.is_alive():
                p.terminate()                 # SIGTERM fan-out
        deadline = time.monotonic() + 5.0
        for p in self._procs.values():
            p.join(timeout=max(0.1, deadline - time.monotonic()))
        for p in self._procs.values():
            if p.is_alive():
                p.kill()
                p.join(timeout=5.0)

    def _broadcast_fail(self, role: str, tb: str) -> None:
        if self._ctl is None:
            return
        try:
            futs = self._ctl.broadcast(
                "ctl/fail", {"ok": np.zeros(1)},
                meta={"role": role, "traceback": tb[-16000:]},
                wait=False)
            for f in futs:
                try:
                    f.result(5.0)
                except (TimeoutError, OSError):
                    pass                       # peer already gone
        except (OSError, RuntimeError):
            pass

    def _fail(self, role: str, tb: str, remote: bool = False) -> None:
        origin = "peer launcher reported" if remote else "local"
        self._log(f"agent {role} FAILED ({origin}); terminating "
                  f"{len(self._procs)} local agent(s)")
        sys.stderr.write(f"\n--- agent {role} failure ---\n{tb}\n")
        sys.stderr.flush()
        if not remote:
            self._broadcast_fail(role, tb)
        self._terminate_local()
        raise _ClusterFailed(1)

    def _check_peers(self) -> None:
        for peer, fut in self._fail_futs.items():
            if fut.done():
                msg = fut.result(1.0)
                self._fail(msg.meta.get("role", f"<{peer}>"),
                           msg.meta.get("traceback", "(no traceback)"),
                           remote=True)

    def _maybe_restart(self, role: str, why: str) -> bool:
        """Death/error handling for a restartable role: schedule a
        backed-off respawn and return True, or return False when the
        policy (or the remaining budget, or the phase) says fail-fast."""
        if role in self._pending_restart:
            return True                   # already scheduled (a death
        #                                   and its error msg both land)
        rp = self.spec.restart_of(role)
        # the policy only arms once the agent has reported ready (its
        # listener bound, data plane up): crashes before that are
        # deploy problems — bad spec, bad certs, import errors — that a
        # respawn would only repeat. The agent's own fit may begin (and
        # a chaos fault may fire) before the LAUNCHERS' control barrier
        # completes, so readiness, not the cross-host barrier, is the
        # arming point.
        if rp.policy != "on_failure" or role not in self._pids:
            return False
        n = self._restarts.get(role, 0)
        if n >= rp.max_restarts:
            self._log(f"agent {role} exhausted its restart budget "
                      f"({rp.max_restarts})")
            return False
        self._restarts[role] = n + 1
        backoff = min(rp.backoff_s * (2 ** n), rp.backoff_max_s)
        self._log(f"agent {role} died ({why}); restart "
                  f"{n + 1}/{rp.max_restarts} in {backoff:.1f}s")
        self._pending_restart[role] = time.monotonic() + backoff
        if self._ctl is not None:
            # informational only — peer supervision loops ignore it,
            # but it lands in their logs for cross-host debugging
            try:
                self._ctl.broadcast("ctl/rejoin", {"ok": np.ones(1)},
                                    meta={"role": role}, wait=False)
            except (OSError, RuntimeError):
                pass
        return True

    def _forget_proc(self, role: str) -> None:
        p = self._procs.pop(role, None)
        if p is not None and p.is_alive():
            p.join(timeout=5.0)
        self._exit_seen.pop(role, None)

    def _spawn(self, role: str, rejoin: bool = False) -> None:
        p = self._ctx.Process(
            target=_cluster_agent_main,
            args=(self.spec, role, str(self.log_dir / f"{role}.log"),
                  self._status_q, rejoin))
        p.daemon = True
        self._procs[role] = p
        p.start()

    def _respawn_due(self) -> None:
        now = time.monotonic()
        for role, due in list(self._pending_restart.items()):
            if now >= due:
                del self._pending_restart[role]
                self._log(f"respawning agent {role} (rejoin)")
                self._spawn(role, rejoin=True)

    def _drain_status(self, ready: Optional[set] = None) -> None:
        while True:
            try:
                kind, role, info = self._status_q.get_nowait()
            except queue.Empty:
                return
            if kind == "ready":
                self._pids[role] = info
                if ready is not None:
                    ready.add(role)
                else:
                    # a respawned agent re-bound its listener: refresh
                    # pids.json so tooling kills the right process
                    (self.log_dir / "pids.json").write_text(
                        json.dumps(self._pids))
            elif kind == "ok":
                self._ok[role] = info
                self._log(f"agent {role} finished ok")
            elif kind == "error":
                if self._maybe_restart(role, "reported an error"):
                    self._forget_proc(role)
                else:
                    self._fail(role, info)

    def _check_deaths(self) -> None:
        for role, p in list(self._procs.items()):
            if role in self._ok or p.exitcode is None:
                continue
            code = p.exitcode
            # a dead agent's last "ok"/"error" message can still be in
            # flight through the status queue's feeder thread — give
            # it a grace window before calling the silence a failure,
            # so a crash reports its REAL traceback, not this generic
            # one. Clean exits get longer (the ok message may trail a
            # big result); crashes flush their traceback pre-mortem,
            # so a short window suffices and SIGKILL detection (which
            # has nothing queued) stays fast.
            grace = 5.0 if code == 0 else 1.5
            first = self._exit_seen.setdefault(role, time.monotonic())
            if time.monotonic() - first < grace:
                continue
            try:
                why = f"signal {signal.Signals(-code).name}" \
                    if code < 0 else f"exit code {code}"
            except ValueError:
                why = f"exit code {code}"
            if self._maybe_restart(role, why):
                self._forget_proc(role)
                continue
            self._fail(role, f"agent process {role!r} died with "
                             f"{why} before reporting a result "
                             f"(no traceback available)")

    def _tick(self, ready: Optional[set] = None) -> None:
        if self._stop:
            self._log("stop requested; terminating local agents")
            self._broadcast_fail(
                f"<{self.host}>", f"launcher on {self.host} was "
                f"terminated by signal; cluster cannot continue")
            self._terminate_local()
            raise _ClusterFailed(143)
        self._drain_status(ready)
        self._check_deaths()
        self._check_peers()
        self._respawn_due()
        time.sleep(self.POLL_S)

    # -- main ----------------------------------------------------------------
    def run(self) -> int:
        try:
            return self._run()
        except _ClusterFailed as e:
            return e.code
        finally:
            if self._ctl is not None:
                try:
                    self._ctl.close()
                except OSError:
                    pass

    def _run(self) -> int:
        spec = self.spec
        self.log_dir.mkdir(parents=True, exist_ok=True)
        self._pids: Dict[str, int] = {}
        ctx = mp.get_context("spawn")
        self._ctx = ctx
        self._status_q = ctx.Queue()

        # control channel first, so peers can rendezvous with us while
        # our agents are still importing
        if self.peers:
            self._ctl = spec.control_comm(self.host)
            self._fail_futs = {p: self._ctl.irecv(p, "ctl/fail")
                               for p in self.peers}
            ready_futs = {p: self._ctl.irecv(p, "ctl/ready")
                          for p in self.peers}

        self._log(f"spawning {self.roles} (logs in {self.log_dir})")
        for role in self.roles:
            self._spawn(role)

        # local readiness: every agent constructed its communicator
        # (listener bound) — then join the cross-host barrier
        ready: set = set()
        deadline = time.monotonic() + spec.barrier_timeout
        while len(ready) < len(self.roles):
            self._tick(ready)
            if time.monotonic() > deadline:
                self._log("local agents not ready before "
                          f"barrier_timeout={spec.barrier_timeout}s")
                self._terminate_local()
                return 3
        (self.log_dir / "pids.json").write_text(json.dumps(self._pids))

        if self.peers:
            # non-blocking: a blocking broadcast could wedge for the
            # full comm timeout retrying a peer that just died, while
            # that peer's ctl/fail sits completed in _fail_futs — the
            # supervision loop below must keep polling it so crash
            # propagation preempts a stuck rendezvous send
            try:
                ready_sends = list(self._ctl.broadcast(
                    "ctl/ready", {"ok": np.ones(1)},
                    meta={"host": self.host}, wait=False))
            except (OSError, RuntimeError) as e:
                self._log(f"rendezvous failed: {e}")
                self._terminate_local()
                return 3
            waiting = set(self.peers)
            while waiting:
                self._tick()
                for f in list(ready_sends):
                    if not f.done():
                        continue
                    try:
                        f.result(0)
                    except (OSError, TimeoutError) as e:
                        self._log(f"rendezvous failed: {e}")
                        self._terminate_local()
                        return 3
                    ready_sends.remove(f)
                waiting = {p for p in waiting
                           if not ready_futs[p].done()}
                if time.monotonic() > deadline:
                    self._log(f"peers {sorted(waiting)} not ready "
                              f"before barrier_timeout="
                              f"{spec.barrier_timeout}s")
                    self._terminate_local()
                    return 3
            self._log(f"rendezvous complete: "
                      f"{sorted(spec.hosts)} all ready")

        # supervise until every local agent reported ok
        while len(self._ok) < len(self.roles):
            self._tick()

        summary = {"host": self.host, "agents": self._ok}
        (self.log_dir / "summary.json").write_text(
            json.dumps(summary, indent=1))
        if "master" in self._ok:
            print("CLUSTER-RESULT " + json.dumps(summary), flush=True)
        if self._ctl is not None:
            try:
                self._ctl.broadcast("ctl/done", {"ok": np.ones(1)},
                                    wait=False)
                self._ctl.flush_sends(2.0)
            except (OSError, TimeoutError, RuntimeError):
                pass
        self._log("all local agents finished ok")
        return 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.cluster",
        description="Launch and supervise this host's share of a VFL "
                    "cluster from a shared spec file "
                    "(docs/deploy.md).")
    ap.add_argument("spec", help="path to the cluster spec "
                                 "(.toml or .json)")
    ap.add_argument("--host", help="which [hosts.<name>] entry this "
                                   "invocation runs (optional when "
                                   "the spec has exactly one host)")
    ap.add_argument("--log-dir", default=None,
                    help="per-agent log directory "
                         "(default: runs/cluster/<host>)")
    ap.add_argument("--check", action="store_true",
                    help="validate the spec, print the launch plan, "
                         "and exit")
    args = ap.parse_args(argv)
    try:
        spec = load_spec(args.spec)
        spec.validate()
    except (OSError, ValueError, KeyError) as e:
        print(f"spec error: {e}", file=sys.stderr)
        return 2
    if args.check:
        print(f"protocol: {spec.cfg.protocol}  framing: {spec.framing}"
              f"  tls: {'on' if spec.comm.tls else 'off'}")
        for h, hs in spec.hosts.items():
            print(f"host {h}: control {hs.control[0]}:{hs.control[1]}"
                  f"  agents {hs.agents}")
        for a, (ah, ap_) in spec.agents.items():
            print(f"agent {a}: {ah}:{ap_}")
        print("spec OK")
        return 0
    host = args.host
    if host is None:
        if len(spec.hosts) != 1:
            print(f"--host required (spec has hosts "
                  f"{sorted(spec.hosts)})", file=sys.stderr)
            return 2
        host = next(iter(spec.hosts))
    if host not in spec.hosts:
        print(f"unknown host {host!r} (spec has {sorted(spec.hosts)})",
              file=sys.stderr)
        return 2
    launcher = ClusterLauncher(
        spec, host,
        log_dir=args.log_dir or f"runs/cluster/{host}")
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: launcher.request_stop())
    return launcher.run()


if __name__ == "__main__":
    raise SystemExit(main())
