"""Serving launcher CLI: batched generation with per-family KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 16 --new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import params as PRM, transformer as T
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    spec = T.model_spec(cfg)
    params = PRM.init_tree(spec, jax.random.key(args.seed), jnp.float32)
    memory = None
    if cfg.encoder is not None:
        frames = jnp.zeros((args.batch, cfg.encoder.n_frames, cfg.d_model),
                           jnp.float32)
        memory = T.encode(cfg, params, frames)
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.new + 1)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new, temperature=args.temperature,
                          seed=args.seed, memory=memory)
    dt = time.perf_counter() - t0
    print(f"{args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print(out[0, args.prompt_len:])


if __name__ == "__main__":
    main()
