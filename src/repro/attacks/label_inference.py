"""Label-inference attacks over captured VFL exchanges (offline).

Both attacks instantiate the practical threat class the VFL surveys
single out (Li et al. 2023; Liu et al. 2022): a party — or a wire
adversary at a party's vantage point — infers the master's private
labels from the per-round tensors that legitimately cross the split.

* :func:`gradient_direction_attack` — the **member** adversary in
  arbitered logreg. Each round it receives its decrypted gradient
  ``g = X_b^T r`` (r the batch residual ``(sigma(z) - y)/B``), knows
  its own feature slice ``X_b``, and can re-derive the batch rows from
  the announced ``(epoch, lo, hi)`` because ``batch_order`` is shared
  and deterministic. A min-norm solve recovers the projection of ``r``
  onto the rowspace of ``X_b``; since ``r_i < 0`` *iff* ``y_i = 1``
  (sigma is strictly inside (0, 1)), the sign of the reconstruction is
  label evidence, accumulated over rounds. With batch size <= the
  member's feature width the solve is exact and labels leak outright.

* :func:`cluster_attack` / :func:`probe_attack` — the **aggregator /
  wire** adversary in split-NN. Bottom activations are forced by
  training to become linearly separable in the label; averaging each
  sample's late-round embeddings and clustering (no labels needed) or
  fitting a tiny logistic probe (a handful of leaked aux labels)
  reads them back out.

Attacks return one score per matched sample; leakage is reported as
ROC-AUC of those scores against the true labels
(:func:`repro.train.evals.auc`), so 0.5 = no leak, 1.0 = full label
reconstruction.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.protocols import base
from repro.core.protocols.driver import OP_RUN

Capture = Dict[str, object]       # ExchangeCapture.as_dict() shape


# ---------------------------------------------------------------------------
# offline round reconstruction from a capture
# ---------------------------------------------------------------------------


def run_rounds(capture: Capture, cfg: base.VFLConfig, n: int, *,
               peer: str, direction: str) -> List[np.ndarray]:
    """Batch rows of every announced RUN round, in announcement order.

    Rows never cross the wire during fit — ``ctrl/step`` carries only
    ``(op, epoch, lo, hi)`` — but the adversary re-derives them exactly
    like any party does: ``batch_order(n, cfg, epoch)[lo:hi]``. Pass
    the vantage point: a member reconstructs from its *received* steps
    (``peer="master", direction="recv"``); the master's capture holds
    one *sent* copy per broadcast target, so filter on one peer."""
    out: List[np.ndarray] = []
    perms: Dict[int, np.ndarray] = {}
    for rec in capture["records"]:
        if rec["name"] != "ctrl/step" or rec["dir"] != direction \
                or rec["peer"] != peer:
            continue
        payload = rec["payload"]
        if int(np.asarray(payload["op"])[0]) != OP_RUN:
            continue
        epoch = int(np.asarray(payload["epoch"])[0])
        lo = int(np.asarray(payload["lo"])[0])
        hi = int(np.asarray(payload["hi"])[0])
        perm = perms.get(epoch)
        if perm is None:
            perm = perms[epoch] = base.batch_order(n, cfg, epoch)
        out.append(perm[lo:hi])
    return out


def captured_field(capture: Capture, name: str, field: str, *,
                   peer: Optional[str] = None,
                   direction: Optional[str] = None) -> List[np.ndarray]:
    """All captured tensors of one message field, in arrival order —
    stepped sequence numbers make that order the round order, so the
    t-th tensor pairs with the t-th reconstructed RUN round."""
    return [np.asarray(rec["payload"][field])
            for rec in capture["records"]
            if rec["name"] == name
            and (peer is None or rec["peer"] == peer)
            and (direction is None or rec["dir"] == direction)]


# ---------------------------------------------------------------------------
# gradient-direction attack (arbitered logreg)
# ---------------------------------------------------------------------------


def gradient_direction_attack(x_member: np.ndarray,
                              rounds: Sequence[np.ndarray],
                              grads: Sequence[np.ndarray]) -> np.ndarray:
    """Per-sample label scores from the member's decrypted gradients.

    For each round, solve ``X_b^T r = g`` in the least-squares sense
    (the min-norm reconstruction of the residual the master encrypted)
    and credit each batch sample ``-r_hat_i`` — positive evidence for
    ``y_i = 1``. Scores average over every round a sample appeared in,
    so epochs sharpen the estimate even when the solve is
    underdetermined (batch larger than the member's width)."""
    x = np.asarray(x_member, np.float64)
    scores = np.zeros(x.shape[0])
    seen = np.zeros(x.shape[0])
    for rows, g in zip(rounds, grads):
        g = np.asarray(g, np.float64).ravel()
        xb = x[rows]
        if g.shape[0] != xb.shape[1]:
            continue      # key-sharded arbiter slice — not this demo
        r_hat = np.linalg.lstsq(xb.T, g, rcond=None)[0]
        scores[rows] += -r_hat
        seen[rows] += 1
    return scores / np.maximum(seen, 1)


# ---------------------------------------------------------------------------
# embedding attacks (split-NN)
# ---------------------------------------------------------------------------


def mean_embeddings(rounds: Sequence[np.ndarray],
                    embeds: Sequence[np.ndarray], n: int,
                    late_frac: float = 0.5
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Average each sample's embedding over the last ``late_frac`` of
    rounds (early-epoch activations are still near init and only dilute
    the signal). Returns ``(u_bar (n, d), seen mask)``."""
    start = int(len(rounds) * (1.0 - late_frac))
    acc: Optional[np.ndarray] = None
    cnt = np.zeros(n)
    for rows, u in list(zip(rounds, embeds))[start:]:
        u = np.asarray(u, np.float64)
        if acc is None:
            acc = np.zeros((n, u.shape[1]))
        m = min(len(rows), len(u))    # stale substitution shape safety
        acc[rows[:m]] += u[:m]
        cnt[rows[:m]] += 1
    if acc is None:
        raise ValueError("no captured rounds to attack")
    return acc / np.maximum(cnt, 1)[:, None], cnt > 0


def _standardize(u: np.ndarray) -> np.ndarray:
    u = np.asarray(u, np.float64)
    return (u - u.mean(0)) / (u.std(0) + 1e-9)


def cluster_attack(u: np.ndarray, iters: int = 25) -> np.ndarray:
    """Unsupervised 2-means over standardized embeddings. Deterministic
    init: centroids at the mean +/- the top principal direction (power
    iteration), then Lloyd steps. Returns the signed margin
    ``d(u, c0) - d(u, c1)``; cluster naming is arbitrary, so leakage is
    ``max(auc, 1 - auc)`` at the caller."""
    z = _standardize(u)
    cov = z.T @ z / len(z)
    v = np.ones(z.shape[1]) / np.sqrt(z.shape[1])
    for _ in range(50):
        v = cov @ v
        v /= np.linalg.norm(v) + 1e-12
    c = np.stack([z.mean(0) - v, z.mean(0) + v])
    for _ in range(iters):
        d = ((z[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        assign = d.argmin(1)
        for k in (0, 1):
            if (assign == k).any():
                c[k] = z[assign == k].mean(0)
    d = ((z[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    return d[:, 0] - d[:, 1]


def probe_attack(u: np.ndarray, y: np.ndarray, aux: np.ndarray,
                 iters: int = 400, lr: float = 0.5,
                 l2: float = 1e-3) -> np.ndarray:
    """Supervised probe: fit a logistic regression on the ``aux``
    samples (the handful of labels the adversary is assumed to know —
    e.g. its own users) and score everyone. Full-batch GD in numpy;
    returns sigmoid scores for all rows. Leakage must be evaluated on
    ``~aux`` rows only."""
    z = _standardize(u)
    x = np.concatenate([z, np.ones((len(z), 1))], axis=1)
    xa, ya = x[aux], np.asarray(y, np.float64).ravel()[aux]
    w = np.zeros(x.shape[1])
    for _ in range(iters):
        p = 1.0 / (1.0 + np.exp(-(xa @ w)))
        w -= lr * (xa.T @ (p - ya) / len(ya) + l2 * w)
    return 1.0 / (1.0 + np.exp(-(x @ w)))
