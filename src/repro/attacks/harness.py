"""AttackHarness: run a VFLJob with exchange capture on, then evaluate
label-inference attacks offline over what crossed the wire.

The harness is deliberately a *consumer* of the normal job API — it
flips ``cfg.capture_exchanges`` on, runs fit + evaluate through
:class:`~repro.core.party.VFLJob` in any execution mode, and collects
each role's :class:`~repro.core.protocols.driver.ExchangeCapture`
export from the per-role result dicts. Attacks then replay the capture
(:mod:`repro.attacks.label_inference`); nothing here hooks live
channels or changes protocol math, so measured leakage is exactly what
the production exchange leaks.

Example::

    h = AttackHarness(VFLConfig(protocol="logreg_he", ...),
                      master_data, [member_data]).run()
    rep = h.grad_attack()          # {"leakage_auc": ..., ...}
    rep["leakage_auc"] >= 0.75     # undefended logreg leaks labels
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.attacks import label_inference as li
from repro.core.party import VFLJob
from repro.core.protocols import base
from repro.train.evals import auc


class AttackHarness:
    """One adversarial measurement run: job + capture + attacks.

    Parameters mirror :class:`VFLJob`; the config is copied with
    ``capture_exchanges=True`` so callers pass their production config
    unchanged. ``run()`` executes fit + evaluate + shutdown and stores
    ``metrics`` (the protocol's utility metrics, e.g. ``auc``) and
    ``results`` (per-role result dicts, each carrying its capture)."""

    def __init__(self, cfg: base.VFLConfig, master_data,
                 member_datas: List, mode: str = "thread", **job_kw):
        self.cfg = dataclasses.replace(cfg, capture_exchanges=True)
        self.master_data = master_data
        self.member_datas = list(member_datas)
        self.mode = mode
        self.job_kw = dict(job_kw)
        self.metrics: Dict[str, float] = {}
        self.results: Dict[str, Any] = {}

    # -- run -----------------------------------------------------------------
    def run(self) -> "AttackHarness":
        with VFLJob(self.cfg, self.master_data, self.member_datas,
                    mode=self.mode, **self.job_kw) as job:
            job.fit()
            self.metrics = job.evaluate()
            self.results = job.shutdown()
        return self

    # -- capture / data plumbing --------------------------------------------
    def capture(self, role: str) -> Dict[str, Any]:
        cap = self.results.get(role, {}).get("capture")
        if cap is None:
            raise KeyError(f"no capture in {role!r} result — was the "
                           f"job run with this harness?")
        return cap

    @property
    def order(self) -> List[str]:
        """The matched sample order, re-derived offline: every match
        path (PSI or salted-hash) agrees on sorted common ids, so the
        adversary needs no wire data to know it."""
        common = set(self.master_data.ids)
        for md in self.member_datas:
            common &= set(md.ids)
        return sorted(common)

    @property
    def n(self) -> int:
        return len(self.order)

    def labels(self, item: Optional[int] = None) -> np.ndarray:
        """Binary target in matched order. Multi-item label matrices
        (the recsys demo) attack the most class-balanced item column
        unless ``item`` says otherwise."""
        y = base._select(self.master_data.ids, self.order,
                         np.asarray(self.master_data.y))
        if y.ndim == 1:
            y = y[:, None]
        if item is None:
            item = int(np.argmin(np.abs(y.mean(0) - 0.5)))
        return y[:, item].astype(np.float64)

    def member_x(self, member: str = "member0") -> np.ndarray:
        md = self.member_datas[int(member.replace("member", ""))]
        return base._select(md.ids, self.order, np.asarray(md.x))

    # -- attacks -------------------------------------------------------------
    def grad_attack(self, member: str = "member0") -> Dict[str, Any]:
        """Gradient-direction label inference from ``member``'s vantage
        point (arbitered logreg): its received ``ctrl/step`` stream
        gives the batch rows, its received decrypted gradients give the
        residual projections."""
        cap = self.capture(member)
        rounds = li.run_rounds(cap, self.cfg, self.n,
                               peer="master", direction="recv")
        grads = li.captured_field(cap, "logreg/grad", "g",
                                  direction="recv")
        scores = li.gradient_direction_attack(self.member_x(member),
                                              rounds, grads)
        y = self.labels()
        return {"attack": "grad_direction", "adversary": member,
                "leakage_auc": auc(scores, y),
                "rounds": len(grads),
                "utility_auc": float(self.metrics.get("auc", 0.5))}

    def embed_attack(self, member: str = "member0",
                     method: str = "probe", aux_frac: float = 0.2,
                     late_frac: float = 0.5, seed: int = 0,
                     item: Optional[int] = None) -> Dict[str, Any]:
        """Embedding label inference from the aggregator's vantage
        point (split-NN): the master's capture holds ``member``'s
        per-round bottom activations exactly as delivered — masked
        under secure_agg, quantized under int8 — so defenses are
        measured, not assumed."""
        cap = self.capture("master")
        rounds = li.run_rounds(cap, self.cfg, self.n,
                               peer=member, direction="send")
        us = li.captured_field(cap, "splitnn/u", "u", peer=member,
                               direction="recv")
        u_bar, seen = li.mean_embeddings(rounds, us, self.n,
                                         late_frac=late_frac)
        y = self.labels(item)
        if method == "cluster":
            scores = li.cluster_attack(u_bar[seen])
            a = auc(scores, y[seen])
            leak = max(a, 1.0 - a)
        else:
            rng = np.random.default_rng(seed)
            idx = np.flatnonzero(seen)
            aux_n = max(2, int(len(idx) * aux_frac))
            aux_idx = rng.permutation(idx)[:aux_n]
            aux = np.zeros(self.n, bool)
            aux[aux_idx] = True
            scores = li.probe_attack(u_bar[seen], y[seen], aux[seen])
            hold = ~aux[seen]
            leak = auc(scores[hold], y[seen][hold])
        return {"attack": f"embed_{method}", "adversary": "master",
                "leakage_auc": float(leak), "rounds": len(us),
                "utility_auc": float(self.metrics.get("auc", 0.5))}
