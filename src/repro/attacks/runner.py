"""Privacy defense matrix: attack x defense x protocol -> measured
leakage, written as machine-readable rows for the CI gate.

Each row runs one :class:`~repro.attacks.harness.AttackHarness` job —
the arbitered-logreg gradient-direction attack and the split-NN
embedding probe/cluster attacks — under one defense:

==============  ==========================================================
``none``        the undefended exchange (the leakage baseline)
``noise``       ``cfg.noise_sigma`` Gaussian noising (docs/privacy.md)
``int8``        ``cfg.compress`` int8 + error feedback (split-NN only)
``secure_agg``  ``protocol="secure_agg"`` pairwise-mask aggregation
==============  ==========================================================

Rows carry ``leakage_auc`` (attack ROC-AUC vs the true labels),
``utility_auc`` and ``utility_delta`` (vs the same protocol's
undefended run), and land in ``benchmarks/results/privacy.json``.
``benchmarks/check_regression.py --privacy`` turns them into hard CI
assertions: undefended logreg must leak (>= 0.75 — the attack works),
noised / masked runs must not (< 0.6) while costing <= 0.02 utility.
int8 is measured but NOT required to defend — quantization error is
far too small to hide label structure, and the row documents that.

Run it::

    PYTHONPATH=src python -m repro.attacks.runner \
        --out benchmarks/results/privacy.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

import numpy as np

from repro.attacks.harness import AttackHarness
from repro.configs.vfl_recsys import VFLRecsysConfig
from repro.core.protocols.base import MasterData, MemberData, VFLConfig
from repro.data.synthetic import make_recsys_silos

# noising levels the matrix measures: strong enough to break the
# attacks below AUC 0.6. For logreg the noise rides the *gradient* and
# SGD averages it out (utility moves ~0.01 AUC — gated at 0.02); for
# split-NN it rides the *activations* through the top model's
# nonlinearity and measurably costs utility (~0.05 AUC) — recorded,
# documented in docs/privacy.md, and exactly why secure_agg (utility
# delta 0.0) is the defense the gate requires for split-NN.
LOGREG_NOISE_SIGMA = 2.0
SPLITNN_NOISE_SIGMA = 1.5


def logreg_case(n: int = 256, d_master: int = 8, d_member: int = 8,
                seed: int = 5):
    """Binary-label vertical split sized so the attack's linear algebra
    is exact: batch_size (8) <= the member width (8) makes the
    per-round residual solve determined — the canonical worst case the
    surveys warn about for unprotected gradient returns."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d_master + d_member))
    w = rng.normal(size=(d_master + d_member,))
    z = x @ (w / np.sqrt(len(w)))
    y = (z + rng.normal(scale=0.5, size=n) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master = MasterData(ids, y[:, None], x[:, :d_master])
    members = [MemberData(ids, x[:, d_master:])]
    cfg = VFLConfig(protocol="logreg_he", epochs=3, batch_size=8,
                    lr=0.3, seed=7, use_psi=False, he_bits=256)
    return cfg, master, members


def splitnn_case(seed: int = 0):
    """The quickstart recsys demo workload, widened to two member silos
    (pairwise masking needs a pair) and to enough users that two epochs
    both converge (one gradient step per ~32 samples) and keep the
    attack honest: per-round masks are fresh, so few epochs means the
    probe cannot average secure-agg masks away across a sample's many
    appearances — the regime where masking holds is part of the
    measured claim (docs/privacy.md)."""
    rcfg = VFLRecsysConfig(
        n_users=2_048, n_items=19, n_interactions=16_384,
        n_other_features=64, member_features=(16, 16),
        id_overlap=0.85, bottom_dims=(32, 16), top_dims=(16, 8),
        embedding_dim=16)
    data = make_recsys_silos(rcfg, seed=seed)
    master = MasterData(data.ids, data.labels, data.features)
    members = [MemberData(mids, mx) for mids, mx in
               zip(data.member_ids, data.member_features)]
    cfg = VFLConfig(protocol="split_nn", epochs=2, batch_size=32,
                    lr=0.4, seed=3, use_psi=False, embedding_dim=8,
                    hidden=(16,))
    return cfg, master, members


def _row(protocol: str, defense: str, rep: Dict[str, Any],
         base_utility: Optional[float]) -> Dict[str, Any]:
    util = rep["utility_auc"]
    return {"protocol": protocol, "attack": rep["attack"],
            "defense": defense,
            "leakage_auc": round(float(rep["leakage_auc"]), 4),
            "utility_auc": round(float(util), 4),
            "utility_delta": round(float(
                util - (base_utility if base_utility is not None
                        else util)), 4),
            "rounds": rep["rounds"]}


def run_privacy_matrix(mode: str = "thread",
                       verbose: bool = True) -> List[Dict[str, Any]]:
    """Run every (attack, defense) cell; returns the privacy.json rows."""
    import dataclasses
    rows: List[Dict[str, Any]] = []

    def log(msg: str) -> None:
        if verbose:
            print(msg, flush=True)

    # -- arbitered logreg: gradient-direction attack ------------------------
    cfg, master, members = logreg_case()
    base_util: Optional[float] = None
    for defense, dcfg in (
            ("none", cfg),
            ("noise", dataclasses.replace(
                cfg, noise_sigma=LOGREG_NOISE_SIGMA))):
        rep = AttackHarness(dcfg, master, members,
                            mode=mode).run().grad_attack()
        if defense == "none":
            base_util = rep["utility_auc"]
        rows.append(_row("logreg_he", defense, rep, base_util))
        log(f"logreg_he/grad_direction/{defense}: "
            f"leakage={rows[-1]['leakage_auc']:.3f} "
            f"utility={rows[-1]['utility_auc']:.3f}")

    # -- split-NN: embedding probe + cluster attacks ------------------------
    cfg, master, members = splitnn_case()
    base_util = None
    for defense, dcfg in (
            ("none", cfg),
            ("noise", dataclasses.replace(
                cfg, noise_sigma=SPLITNN_NOISE_SIGMA)),
            ("int8", dataclasses.replace(cfg, compress=True)),
            ("secure_agg", dataclasses.replace(cfg,
                                               protocol="secure_agg"))):
        h = AttackHarness(dcfg, master, members, mode=mode).run()
        probe = h.embed_attack(method="probe")
        if defense == "none":
            base_util = probe["utility_auc"]
        rows.append(_row("split_nn", defense, probe, base_util))
        log(f"split_nn/embed_probe/{defense}: "
            f"leakage={rows[-1]['leakage_auc']:.3f} "
            f"utility={rows[-1]['utility_auc']:.3f}")
        cluster = h.embed_attack(method="cluster")
        rows.append(_row("split_nn", defense, cluster, base_util))
        log(f"split_nn/embed_cluster/{defense}: "
            f"leakage={rows[-1]['leakage_auc']:.3f}")
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="benchmarks/results/privacy.json")
    ap.add_argument("--mode", default="thread",
                    help="VFLJob execution mode (default thread)")
    args = ap.parse_args(argv)
    rows = run_privacy_matrix(mode=args.mode)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
        f.write("\n")
    print(f"wrote {len(rows)} privacy rows -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
