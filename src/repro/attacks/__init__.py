"""Adversarial VFL harness (docs/privacy.md): label-inference attacks
run offline over captured exchanges, and the defense matrix that turns
the repo's privacy posture into regression-tested numbers.

The package never touches a live channel: :class:`AttackHarness` runs a
normal :class:`~repro.core.party.VFLJob` with
``cfg.capture_exchanges=True`` (the driver-level exchange-capture hook)
and replays the recorded per-round embeddings / decrypted gradients
through the attacks in :mod:`repro.attacks.label_inference`. The
defense sweep lives in :mod:`repro.attacks.runner` and writes
``benchmarks/results/privacy.json``, gated by
``benchmarks/check_regression.py --privacy``.
"""
from repro.attacks.harness import AttackHarness
from repro.attacks.label_inference import (cluster_attack,
                                           gradient_direction_attack,
                                           probe_attack)
from repro.attacks.runner import run_privacy_matrix

__all__ = ["AttackHarness", "gradient_direction_attack",
           "cluster_attack", "probe_attack", "run_privacy_matrix"]
