from repro.sharding.rules import (  # noqa: F401
    MeshRules, constrain, current_rules, use_rules,
    TRAIN_RULES, DECODE_RULES, logical_to_spec,
)
