"""Logical-axis sharding: rules resolve logical names -> mesh axes with
divisibility fallback.

Params and activations carry *logical* axis names ("embed", "heads",
"mlp", ...). A :class:`MeshRules` binds them to mesh axes ("pod", "data",
"model"). Resolution drops a mesh axis when the dimension size is not
divisible by it (e.g. glm4's 2 KV heads on a 16-way model axis fall back
to replication) — every fallback is recorded so the dry-run can report it.

FSDP-style: the "embed" dim of weights shards over the data axis (ZeRO-3
analogue), tensor-parallel dims ("heads", "mlp", "experts", "vocab") over
the model axis, batch over (pod, data).
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: the top-level API (and its
    check_vma kwarg) landed after 0.4.x; older jax ships it as
    jax.experimental.shard_map with check_rep. Replication checking is
    disabled either way (our psum-of-masks patterns confuse it)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)

# logical axis -> preferred mesh axes (tried in order, tuple = joint)
PARAM_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "embed": ("data",),          # FSDP shard of weight matrices
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert_mlp": None,          # experts already shard over model
    "experts": ("model",),
    "experts_dp": None,          # data-parallel experts (§Perf lever)
    "vocab": ("model",),
    "kv_lora": None,
    "q_lora": None,
    "head_dim": None,
    "layers": None,
    "state": None,
    "conv": None,
    # dt_rank must stay replicated: sharding it makes the dt_proj
    # contraction emit a 4 GB fp32 all-reduce of the full d_inner
    # activation per mamba layer (EXPERIMENTS.md §Perf, jamba iter 3)
    "dt_rank": None,
    "d_inner": ("model",),
    "frames": None,
}

TRAIN_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "experts_dp": None,
    "expert_mlp": None,
    "vocab": ("model",),
    "head_dim": None,
    "kv_lora": None,
    "q_lora": None,
    "state": None,
    "d_inner": ("model",),
    "cache_seq": ("model",),
    "frames": None,
}

# decode: batch over data only (pod reserved for parties / spare DP),
# KV-cache sequence over model (partial-softmax combine by SPMD).
DECODE_RULES = dict(TRAIN_RULES)
DECODE_RULES["batch"] = ("data",)


@dataclass
class MeshRules:
    mesh: Mesh
    param_rules: Dict[str, Optional[Tuple[str, ...]]] = field(
        default_factory=lambda: dict(PARAM_RULES))
    act_rules: Dict[str, Optional[Tuple[str, ...]]] = field(
        default_factory=lambda: dict(TRAIN_RULES))
    fallbacks: List[str] = field(default_factory=list)
    # §Perf lever: accumulate TP out-projections in bf16 so the SPMD
    # partial-sum all-reduces move bf16 instead of the f32 accumulator
    # (halves TP collective bytes; documented numerics trade-off)
    bf16_collectives: bool = False

    def _axis_size(self, names: Sequence[str]) -> int:
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int],
             rules: Dict[str, Optional[Tuple[str, ...]]],
             what: str = "") -> P:
        used: set = set()
        parts = []
        for name, dim in zip(logical, shape):
            target = rules.get(name) if name else None
            if target is None:
                parts.append(None)
                continue
            target = tuple(a for a in target
                           if a in self.mesh.shape and a not in used)
            if not target or dim % self._axis_size(target) != 0:
                if target:
                    self.fallbacks.append(
                        f"{what}: dim {name}={dim} not divisible by "
                        f"{target} (size {self._axis_size(target)}) -> replicated")
                parts.append(None)
                continue
            used.update(target)
            parts.append(target if len(target) > 1 else target[0])
        return P(*parts)

    def param_sharding(self, logical, shape) -> NamedSharding:
        return NamedSharding(
            self.mesh, self.spec(logical, shape, self.param_rules, "param"))

    def act_spec(self, logical, shape) -> P:
        return self.spec(logical, shape, self.act_rules, "act")


_current: contextvars.ContextVar[Optional[MeshRules]] = \
    contextvars.ContextVar("mesh_rules", default=None)


def current_rules() -> Optional[MeshRules]:
    return _current.get()


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    tok = _current.set(rules)
    try:
        yield rules
    finally:
        _current.reset(tok)


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint if mesh rules are active, else no-op.

    Model code calls this at block boundaries; smoke tests (no mesh) are
    unaffected.
    """
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.act_spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def reduce_dtype(x_dtype):
    """preferred_element_type for TP out-projections (None = default)."""
    import jax.numpy as jnp
    r = current_rules()
    if r is not None and r.bf16_collectives and x_dtype == jnp.bfloat16:
        return jnp.bfloat16
    return None


def logical_to_spec(rules: Optional[MeshRules], logical, shape,
                    for_params: bool = True) -> P:
    if rules is None:
        return P()
    table = rules.param_rules if for_params else rules.act_rules
    return rules.spec(logical, shape, table,
                      "param" if for_params else "act")


def param_shardings(rules: MeshRules, axes_tree, abstract_params):
    """Resolve a whole axes tree to NamedShardings (matching SDS tree)."""
    return jax.tree.map(
        lambda ax, sds: rules.param_sharding(ax, sds.shape),
        axes_tree, abstract_params,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
