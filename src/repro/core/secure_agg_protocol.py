"""Pairwise-masked secure aggregation over the PartyCommunicator
(Bonawitz et al. style), for the message-passing execution modes.

Key agreement: every member publishes g^a mod p (the PSI group prime) to
every other member through the communicator; each pair derives the
shared secret g^{ab}, hashes it into a seed, and uses a counter-based
PRG to produce per-round masks. Member i adds +PRG(seed_ij, round) for
j > i and -PRG for j < i; the sum over members telescopes to zero, so
the master — who only ever receives masked tensors — learns exactly the
aggregate embedding and nothing about individual contributions.

Note the privacy model matches the paper's HE layer (protect individual
member data from the aggregator); with a single member there is no
second party to pair with and masking degenerates (as in the original
protocol).
"""
from __future__ import annotations

import hashlib
import secrets
from typing import Dict, List

import numpy as np

from repro.comm.base import PartyCommunicator
from repro.core.psi import group_prime


class PairwiseMasker:
    """One member's side of the key agreement + mask generation."""

    def __init__(self, comm: PartyCommunicator, me: str,
                 members: List[str]):
        self.me = me
        self.members = sorted(members)
        self.idx = self.members.index(me)
        p = group_prime()
        g = 4  # square => generator of the QR subgroup
        self._secret = secrets.randbits(256)
        mine = pow(g, self._secret, p)
        blob = np.frombuffer(mine.to_bytes(96, "big"), np.uint8)
        for other in self.members:
            if other != me:
                comm.send(other, "secagg/pub", {"v": blob})
        self.seeds: Dict[str, int] = {}
        for other in self.members:
            if other == me:
                continue
            their = int.from_bytes(
                bytes(bytearray(comm.recv(other, "secagg/pub").tensor("v"))),
                "big")
            shared = pow(their, self._secret, p)
            self.seeds[other] = int.from_bytes(
                hashlib.sha256(shared.to_bytes(96, "big")).digest()[:8],
                "big")

    # PRG masks live on a fixed dyadic grid: gaussians clipped to
    # |z| <= 8 and rounded to multiples of 2^-10. Every grid value and
    # every sum of a few thousand of them is exactly representable in
    # float32 (magnitudes stay far below 2^23 ulp-1 territory), so the
    # +/- streams of a pair cancel to exactly 0.0 in ANY summation
    # order — the masked sum equals the plain sum bit-for-bit whenever
    # the data itself sums exactly (tests/test_secure_agg_props.py).
    # Clipping 8-sigma tails costs nothing statistically and is what
    # bounds the sums into the exact range.
    _GRID = np.float32(1024.0)

    def _prg(self, seed: int, rnd: int, shape) -> np.ndarray:
        rng = np.random.default_rng(np.uint64((seed + rnd) % 2**63))
        z = rng.standard_normal(shape).astype(np.float32)
        return np.round(np.clip(z, -8.0, 8.0) * self._GRID) / self._GRID

    def mask(self, rnd: int, shape) -> np.ndarray:
        m = np.zeros(shape, np.float32)
        for other, seed in self.seeds.items():
            sign = 1.0 if self.me < other else -1.0
            m += sign * self._prg(seed, rnd, shape)
        return m
