"""Agent roles (PartyMaster / PartyMember / Arbiter) and the execution-
mode runner.

``run_vfl(...)`` runs one protocol across all agents in any of the three
paper modes — "thread" (in-process queues), "process"
(multiprocessing), "socket" (TCP + safetensors framing) — with identical
protocol code; mode equivalence is a tested claim (EXPERIMENTS.md
§Functional). A fourth beyond-paper mode, the TPU mesh step, lives in
core/vfl_step.py.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.comm.base import PartyCommunicator
from repro.comm.local import ThreadBus
from repro.comm.process import ProcessBus
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core.protocols import PROTOCOLS, VFLConfig
from repro.core.protocols.base import MasterData, MemberData

# ensure built-in protocols register
from repro.core.protocols import linreg as _linreg        # noqa: F401
from repro.core.protocols import logreg as _logreg        # noqa: F401
from repro.core.protocols import split_nn as _split_nn    # noqa: F401


@dataclass
class VFLAgent:
    """Explicit role object (paper Fig. 1). Thin wrapper over the
    functional protocol layer, for API fidelity with Stalactite."""

    comm: PartyCommunicator
    cfg: VFLConfig

    def _fn(self, role: str):
        return PROTOCOLS[self.cfg.protocol][role]


class PartyMaster(VFLAgent):
    def fit(self, data: MasterData) -> Dict[str, Any]:
        return self._fn("master")(self.comm, data, self.cfg)


class PartyMember(VFLAgent):
    def fit(self, data: MemberData) -> Dict[str, Any]:
        return self._fn("member")(self.comm, data, self.cfg)


class Arbiter(VFLAgent):
    def serve(self) -> Dict[str, Any]:
        return self._fn("arbiter")(self.comm, None, self.cfg)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def world_for(cfg: VFLConfig, n_members: int) -> List[str]:
    world = ["master"] + [f"member{i}" for i in range(n_members)]
    if PROTOCOLS[cfg.protocol]["needs_arbiter"]:
        world.append("arbiter")
    return world


def _role_entry(role: str, comm: PartyCommunicator, cfg: VFLConfig,
                data, out: Dict[str, Any]):
    proto = PROTOCOLS[cfg.protocol]
    try:
        if role == "master":
            out[role] = proto["master"](comm, data, cfg)
        elif role == "arbiter":
            out[role] = proto["arbiter"](comm, data, cfg)
        else:
            out[role] = proto["member"](comm, data, cfg)
    except BaseException as e:   # propagate to the runner
        out[role] = {"error": e}
        raise
    finally:
        comm.close()


def _mp_entry(role: str, bus_boxes, world, cfg, data, q):
    # module-level for picklability (spawn)
    from repro.comm.process import ProcessBus, ProcessCommunicator
    bus = ProcessBus.__new__(ProcessBus)
    bus.world = world
    bus.boxes = bus_boxes
    comm = ProcessCommunicator(role, bus)
    out: Dict[str, Any] = {}
    _role_entry(role, comm, cfg, data, out)
    q.put((role, out[role]))


def run_vfl(cfg: VFLConfig, master_data: MasterData,
            member_datas: List[MemberData], mode: str = "thread",
            ) -> Dict[str, Any]:
    """Run a full VFL job (matching + training) in the given mode."""
    world = world_for(cfg, len(member_datas))
    datas: Dict[str, Any] = {"master": master_data}
    for i, md in enumerate(member_datas):
        datas[f"member{i}"] = md
    if "arbiter" in world:
        datas["arbiter"] = None

    results: Dict[str, Any] = {}
    if mode == "thread":
        bus = ThreadBus(world)
        threads = [threading.Thread(
            target=_role_entry,
            args=(w, bus.communicator(w), cfg, datas[w], results))
            for w in world]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
    elif mode == "socket":
        addrs = local_addresses(world)
        comms = {w: SocketCommunicator(w, addrs) for w in world}
        threads = [threading.Thread(
            target=_role_entry, args=(w, comms[w], cfg, datas[w], results))
            for w in world]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)
    elif mode == "process":
        ctx = mp.get_context("spawn")
        bus = ProcessBus(world, ctx)
        q = ctx.Queue()
        procs = [ctx.Process(target=_mp_entry,
                             args=(w, bus.boxes, world, cfg, datas[w], q))
                 for w in world]
        for p in procs:
            p.start()
        for _ in world:
            role, res = q.get(timeout=600)
            results[role] = res
        for p in procs:
            p.join(timeout=60)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    for role, res in results.items():
        if isinstance(res, dict) and isinstance(res.get("error"),
                                                BaseException):
            raise RuntimeError(f"agent {role} failed") from res["error"]
    missing = [w for w in world if w not in results]
    if missing:
        raise RuntimeError(f"agents did not finish: {missing}")
    return results
