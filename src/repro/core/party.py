"""Agent lifecycle runtime: role objects, the :class:`VFLJob` entry
point, and the execution-mode plumbing.

Every agent runs one :class:`~repro.core.protocols.driver.VFLProtocol`
instance under the shared :class:`~repro.core.protocols.driver.Driver`
(the single copy of the epoch/batch loop, callbacks, checkpointing and
the phase handshake — DESIGN.md §6). A protocol is a registered
subclass with lifecycle hooks; agents resolve it by ``cfg.protocol``
name (or a ``"module:Class"`` spec for user protocols).

``VFLJob`` keeps the whole federation alive across phases::

    job = VFLJob(cfg, master_data, member_datas, mode="socket")
    job.fit()                    # training phase (callbacks, checkpoints)
    scores = job.predict()       # joint inference — no retraining
    metrics = job.evaluate()     # predict + protocol metrics (e.g. AUC)
    results = job.shutdown()     # per-role result dicts

``run_vfl(...)`` is the one-shot compatibility wrapper (fit + shutdown)
and runs in every execution mode — "thread" (in-process queues),
"process" (multiprocessing), "socket"/"socket_proc" (TCP +
length-prefix framing), "grpc"/"grpc_proc" (TCP + HTTP/2-like gRPC
framing, DESIGN.md §8) — with identical protocol code; mode
equivalence is a tested claim (seed-trace bit-identity across all six
modes). A further beyond-paper mode, the TPU mesh step, lives in
core/vfl_step.py. ``comm_cfg=CommCfg(...)`` configures transports
(timeouts, encode offload, WAN link emulation); docs/transports.md is
the user-facing guide.
"""
from __future__ import annotations

import multiprocessing as mp
import queue
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence

from repro.comm.base import CommCfg, PartyCommunicator
from repro.comm.grpc import GrpcCommunicator
from repro.comm.local import ThreadBus
from repro.comm.schema import TypedChannel
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core.protocols import PROTOCOLS, VFLConfig      # noqa: F401
from repro.core.protocols.base import (MasterData, MemberData,
                                       resolve_protocol)
from repro.core.protocols.driver import Callback, Driver, load_checkpoint

# ensure built-in protocols register
from repro.core.protocols import linreg as _linreg        # noqa: F401
from repro.core.protocols import logreg as _logreg        # noqa: F401
from repro.core.protocols import split_nn as _split_nn    # noqa: F401
from repro.core.protocols import secure_agg as _sec_agg   # noqa: F401


def world_for(cfg: VFLConfig, n_members: int) -> List[str]:
    world = ["master"] + [f"member{i}" for i in range(n_members)]
    if resolve_protocol(cfg.protocol).needs_arbiter:
        # key-sharded decryption (DESIGN.md §10.3): n_arbiters >= 2
        # adds "arbiter1", ... — the bare "arbiter" name stays so
        # single-arbiter worlds (and their recorded traces) are
        # untouched
        n_arb = max(1, int(getattr(cfg, "n_arbiters", 1)))
        world += ["arbiter" if i == 0 else f"arbiter{i}"
                  for i in range(n_arb)]
    return world


def _force_comm_timeout(cfg: CommCfg, timeout: float) -> CommCfg:
    """``cfg`` with every per-message wait set to ``timeout`` — the
    world-level default AND any ``peer_overrides`` entry, so
    edge-pinned ``[comm.a.b]`` timeouts do not silently survive a
    job-level ``comm_timeout`` override."""
    import dataclasses
    over = cfg.peer_overrides
    if over:
        over = {p: dataclasses.replace(o, timeout=timeout)
                for p, o in over.items()}
    return dataclasses.replace(cfg, timeout=timeout,
                               peer_overrides=over)


def _wrap_exc(e: BaseException) -> RuntimeError:
    """Picklable stand-in carrying the remote traceback text."""
    tb = "".join(traceback.format_exception(type(e), e, e.__traceback__))
    return RuntimeError(f"{type(e).__name__}: {e}\n"
                        f"--- remote traceback ---\n{tb}")


# ---------------------------------------------------------------------------
# explicit role objects (paper Fig. 1) — for deployments where each
# agent is its own process/host and you hand it a communicator yourself
# ---------------------------------------------------------------------------


class VFLAgent:
    """One agent: protocol instance + driver over a communicator."""

    role: str = "?"

    def __init__(self, comm: PartyCommunicator, cfg: VFLConfig,
                 callbacks: Sequence[Callback] = (),
                 resume_dir: Optional[str] = None,
                 elastic=None):
        self.comm = comm
        self.cfg = cfg
        proto_cls = resolve_protocol(cfg.protocol)
        proto = proto_cls(cfg, TypedChannel(comm, compress=cfg.compress),
                          comm.me)
        resume = load_checkpoint(resume_dir, comm.me) if resume_dir \
            else None
        self.driver = Driver(proto, callbacks=callbacks,
                             resume_state=resume, elastic=elastic)


class PartyMaster(VFLAgent):
    """Drives the federation: call ``fit`` / ``predict`` / ``evaluate``
    in any order, then ``shutdown`` to release the other agents."""

    role = "master"

    def fit(self, data: MasterData, **kw) -> Dict[str, Any]:
        if self.driver.proto.data is None:
            self.driver.prepare(data)
        return self.driver.fit(**kw)

    def predict(self, rows=None, **kw):
        return self.driver.predict(rows, **kw)

    def evaluate(self, rows=None) -> Dict[str, Any]:
        return self.driver.evaluate(rows)

    # persistent serving session (docs/serving.md): open once, answer
    # many query rounds, close before the next fit/shutdown
    def serve_open(self) -> None:
        self.driver.serve_open()

    def serve_query(self, rows, **kw):
        return self.driver.serve_query(rows, **kw)

    def serve_close(self) -> None:
        self.driver.serve_close()

    def shutdown(self) -> Dict[str, Any]:
        self.driver.shutdown_world()
        self.driver.proto.close()
        return self.driver.result()


class PartyMember(VFLAgent):
    """Reactive agent: serves the master's phase announcements until
    shutdown, then returns its result dict."""

    role = "member"

    def serve(self, data: MemberData,
              rejoin: bool = False) -> Dict[str, Any]:
        """``rejoin=True`` is the restarted-agent entry: state was
        restored from ``resume_dir`` (the checkpoint carries the
        matched order, so ``prepare`` does no matching comm) and the
        member enters the master's paused fit via the ``ctrl/rejoin``
        handshake instead of waiting for a phase announcement."""
        try:
            self.driver.prepare(data)
            if rejoin:
                return self.driver.rejoin_follow()
            return self.driver.follow()
        finally:
            self.driver.proto.close()


class Arbiter(VFLAgent):
    role = "arbiter"

    def serve(self) -> Dict[str, Any]:
        try:
            self.driver.prepare(None)
            return self.driver.follow()
        finally:
            self.driver.proto.close()


# ---------------------------------------------------------------------------
# agent entry points
# ---------------------------------------------------------------------------


def _drive_master(driver: Driver, cmd_q, res_q) -> Dict[str, Any]:
    """Command loop for the master agent: the owning VFLJob feeds
    (phase, kwargs) pairs; each reply is ("ok", payload) or
    ("error", wrapped-exception)."""
    while True:
        cmd, kw = cmd_q.get()
        if cmd == "shutdown":
            driver.shutdown_world()
            res_q.put(("ok", None))
            break
        try:
            if cmd == "fit":
                r: Any = driver.fit(**kw)
            elif cmd == "predict":
                r = driver.predict(**kw)
            elif cmd == "evaluate":
                r = driver.evaluate(**kw)
            elif cmd == "serve_open":
                r = driver.serve_open()
            elif cmd == "serve_query":
                r = driver.serve_query(**kw)
            elif cmd == "serve_close":
                r = driver.serve_close()
            else:
                raise ValueError(f"unknown job command {cmd!r}")
        except BaseException as e:
            res_q.put(("error", _wrap_exc(e)))
            raise
        res_q.put(("ok", r))
    return driver.result()


def _agent_entry(role: str, comm: PartyCommunicator, cfg: VFLConfig,
                 data, out: Dict[str, Any], callbacks=None,
                 resume_dir=None, cmd_q=None, res_q=None) -> None:
    proto_cls = resolve_protocol(cfg.protocol)
    proto = proto_cls(cfg, TypedChannel(comm, compress=cfg.compress),
                      role)
    resume = load_checkpoint(resume_dir, role) if resume_dir else None
    driver = Driver(proto, callbacks=callbacks or (), resume_state=resume)
    try:
        driver.prepare(data)
        if role == "master":
            out[role] = _drive_master(driver, cmd_q, res_q)
        else:
            out[role] = driver.follow()
    except BaseException as e:   # propagate to the runner
        out[role] = {"error": e}
        if role == "master" and res_q is not None:
            res_q.put(("error", _wrap_exc(e)))
        raise
    finally:
        try:
            proto.close()
        finally:
            comm.close()


def _mp_entry(role, transport, world, cfg, data, q, callbacks=None,
              resume_dir=None, cmd_q=None, res_q=None,
              comm_cfg=None):
    # module-level for picklability (spawn). ``transport`` selects the
    # wire: ("bus", mp queue boxes), ("sock", address map) or
    # ("grpc", address map) — the address-map kinds run every agent as
    # its own OS process talking TCP, the paper's distributed
    # deployment (and the shape where pipelined rounds overlap with
    # real parallelism, GIL-free).
    kind, arg = transport
    tkw = {} if comm_cfg is None else {"comm_cfg": comm_cfg}
    if kind == "bus":
        from repro.comm.process import ProcessBus, ProcessCommunicator
        bus = ProcessBus.__new__(ProcessBus)
        bus.world = world
        bus.boxes = arg
        comm = ProcessCommunicator(role, bus, **tkw)
    elif kind == "sock":
        from repro.comm.sock import SocketCommunicator
        comm = SocketCommunicator(role, arg, **tkw)
    elif kind == "grpc":
        from repro.comm.grpc import GrpcCommunicator
        comm = GrpcCommunicator(role, arg, **tkw)
    else:
        raise ValueError(f"unknown transport {kind!r}")
    out: Dict[str, Any] = {}
    try:
        _agent_entry(role, comm, cfg, data, out, callbacks, resume_dir,
                     cmd_q, res_q)
    except BaseException as e:
        # the error must reach the parent's queue BEFORE this process
        # dies — otherwise run_vfl blocks its full timeout and reports
        # queue.Empty instead of the real traceback
        q.put((role, {"error": _wrap_exc(e)}))
        raise
    q.put((role, out[role]))


# ---------------------------------------------------------------------------
# the job
# ---------------------------------------------------------------------------


class VFLJob:
    """A live VFL federation with a phase API.

    Spawns every agent for ``cfg.protocol`` in the requested execution
    mode and keeps them alive between calls, so inference reuses the
    trained state — ``fit()`` then ``predict()`` with no retraining and
    no weight export. ``callbacks`` run on every role (checkpoints stay
    role-consistent); in process mode they are pickled into the workers,
    so their in-memory state does not flow back. ``resume_dir`` restores
    a :class:`~repro.core.protocols.driver.Checkpointer` cut: fit
    continues mid-epoch from the saved (epoch, batch) position.

    Example::

        cfg = VFLConfig(protocol="split_nn", epochs=3)
        with VFLJob(cfg, master, members, mode="grpc",
                    pipeline_depth=2) as job:
            fit = job.fit()              # callbacks, checkpoints
            scores = job.predict()       # joint inference, same agents
            metrics = job.evaluate()     # predict + protocol metrics
        # __exit__ ran job.shutdown() and released every agent
    """

    def __init__(self, cfg: VFLConfig, master_data: MasterData,
                 member_datas: List[MemberData], mode: str = "thread",
                 callbacks: Sequence[Callback] = (),
                 resume_dir: Optional[str] = None,
                 pipeline_depth: Optional[int] = None,
                 comm_timeout: Optional[float] = None,
                 comm_cfg: Optional[CommCfg] = None,
                 comm_cfgs: Optional[Dict[str, CommCfg]] = None):
        """``pipeline_depth`` overrides ``cfg.pipeline_depth`` (1 =
        synchronous lock-step, D >= 2 = bounded-staleness pipelining);
        ``comm_timeout`` overrides each transport's per-message wait
        (including any edge-pinned ``[comm.a.b]`` timeouts);
        ``comm_cfg`` configures the transports in full — timeouts,
        Nagle, encode offload, and WAN link emulation
        (:class:`~repro.comm.base.LinkSpec`), e.g.::

            wan = CommCfg(link=LinkSpec(latency_ms=20))
            VFLJob(cfg, master, members, mode="grpc", comm_cfg=wan)

        ``comm_cfgs`` overrides ``comm_cfg`` per role (keyed by agent
        id) — how per-link edge settings reach each agent's transport:
        ``ClusterSpec.comm_for(role)`` resolves a spec's
        ``[comm.a.b]`` tables into per-role cfgs whose
        ``peer_overrides`` shape just the named edges, and
        :meth:`from_spec` passes them here. Roles without an entry
        fall back to ``comm_cfg``.
        """
        import dataclasses
        if pipeline_depth is not None:
            cfg = dataclasses.replace(cfg, pipeline_depth=pipeline_depth)
        if comm_timeout is not None:
            comm_cfg = _force_comm_timeout(comm_cfg or CommCfg(),
                                           comm_timeout)
            if comm_cfgs is not None:
                comm_cfgs = {w: _force_comm_timeout(c, comm_timeout)
                             for w, c in comm_cfgs.items()}

        def _cfg_for(w: str) -> Optional[CommCfg]:
            if comm_cfgs is not None and w in comm_cfgs:
                return comm_cfgs[w]
            return comm_cfg
        self.cfg = cfg
        self.mode = mode
        self.world = world_for(cfg, len(member_datas))
        datas: Dict[str, Any] = {"master": master_data}
        for i, md in enumerate(member_datas):
            datas[f"member{i}"] = md
        for w in self.world:
            if w.startswith("arbiter"):
                datas[w] = None

        self._results: Dict[str, Any] = {}
        self._failed: Optional[BaseException] = None
        self._closed = False
        self._threads: List[threading.Thread] = []
        self._procs: Dict[str, mp.Process] = {}
        self._q = None                      # process-mode exit results

        if mode in ("thread", "socket", "grpc"):
            self._cmd_q: Any = queue.Queue()
            self._res_q: Any = queue.Queue()
            def _ckw(w: str) -> Dict[str, Any]:
                c = _cfg_for(w)
                return {} if c is None else {"comm_cfg": c}
            if mode == "thread":
                bus = ThreadBus(self.world)
                comms = {w: bus.communicator(w, **_ckw(w))
                         for w in self.world}
            else:
                tcls = SocketCommunicator if mode == "socket" \
                    else GrpcCommunicator
                addrs = local_addresses(self.world)
                comms = {w: tcls(w, addrs, **_ckw(w))
                         for w in self.world}
            for w in self.world:
                is_m = w == "master"
                t = threading.Thread(
                    target=_agent_entry,
                    args=(w, comms[w], cfg, datas[w], self._results,
                          list(callbacks), resume_dir,
                          self._cmd_q if is_m else None,
                          self._res_q if is_m else None),
                    daemon=True)
                self._threads.append(t)
                t.start()
        elif mode in ("process", "socket_proc", "grpc_proc"):
            ctx = mp.get_context("spawn")
            if mode == "process":
                from repro.comm.process import ProcessBus
                # the bus must outlive __init__: Process.start() drops
                # its args reference, and a GC'd mp.Queue unlinks its
                # named semaphores before slow-importing children
                # rebuild them
                self._bus = bus = ProcessBus(self.world, ctx)
                transport = ("bus", bus.boxes)
            else:
                # one OS process per agent over real TCP — the paper's
                # distributed deployment on one host; control replies
                # still ride mp queues
                kind = "sock" if mode == "socket_proc" else "grpc"
                transport = (kind, local_addresses(self.world))
            self._q = ctx.Queue()
            self._cmd_q = ctx.Queue()
            self._res_q = ctx.Queue()
            for w in self.world:
                is_m = w == "master"
                p = ctx.Process(
                    target=_mp_entry,
                    args=(w, transport, self.world, cfg, datas[w],
                          self._q, list(callbacks), resume_dir,
                          self._cmd_q if is_m else None,
                          self._res_q if is_m else None,
                          _cfg_for(w)))
                # daemonized: an abandoned job (no shutdown) must not
                # block interpreter exit on multiprocessing's atexit join
                p.daemon = True
                self._procs[w] = p
                p.start()
        else:
            raise ValueError(f"unknown mode {mode!r}")

    @classmethod
    def from_spec(cls, spec, mode: Optional[str] = None,
                  **kw) -> "VFLJob":
        """Run a whole cluster spec in-process — every agent from the
        spec's world, the spec's protocol/transport settings (TLS, link
        shaping, timeouts), data built by the spec's provider — so a
        deployment spec can be validated end-to-end on one machine
        before ``python -m repro.launch.cluster`` distributes it.

        The spec's ``[agents]``/``[hosts]`` address maps are ignored
        here (local ports are auto-assigned); ``mode`` overrides the
        execution mode (default: the spec's framing as threads,
        ``"socket"``/``"grpc"``; pass e.g. ``"grpc_proc"`` for one OS
        process per agent).

        Example (the spec's ``[comm.tls]`` certificates must exist —
        mint them once with the command in the spec's header, or drop
        the table for a plaintext run)::

            # python -m repro.launch.certs --dir examples/cluster/certs \\
            #     --agents master member0 alpha beta
            job = VFLJob.from_spec("examples/cluster/"
                                   "quickstart_cluster.toml")
            job.fit(); print(job.evaluate()["auc"]); job.shutdown()
        """
        from repro.launch.cluster import load_spec
        spec = load_spec(spec)
        spec.validate()
        datas = {r: spec.build_data(r) for r in spec.world()}
        members = [datas[f"member{i}"] for i in range(spec.n_members)]
        if mode is None:
            mode = "socket" if spec.framing == "sock" else "grpc"
        kw.setdefault("comm_cfg", spec.comm)
        if spec.comm_edges:
            # per-link [comm.a.b] overrides: each role's transport gets
            # its own resolved cfg (peer_overrides on the named edges)
            kw.setdefault("comm_cfgs",
                          {r: spec.comm_for(r) for r in spec.world()})
        return cls(spec.cfg, datas["master"], members, mode=mode, **kw)

    # -- phase API -----------------------------------------------------------
    # ``timeout`` bounds how long the job waits for the master's reply;
    # pass float("inf") for unbounded runs (e.g. --full demo scales).
    def fit(self, timeout: float = 3600.0, **kw) -> Dict[str, Any]:
        """Run the training phase; returns the master's fit summary
        (history, n_common, eval_history, early-stop reason)."""
        return self._call("fit", timeout=timeout, **kw)

    def predict(self, rows=None, timeout: float = 3600.0, **kw):
        """Joint inference over the matched samples (or a row subset):
        members answer feature-slice queries, the master assembles and
        returns the score matrix."""
        return self._call("predict", timeout=timeout, rows=rows, **kw)

    def evaluate(self, rows=None,
                 timeout: float = 3600.0) -> Dict[str, Any]:
        """Predict + the protocol's metrics vs the master's labels."""
        return self._call("evaluate", timeout=timeout, rows=rows)

    # -- persistent serving session (docs/serving.md) ------------------------
    def serve_open(self, timeout: float = 600.0) -> None:
        """Open a long-lived predict phase: members park in their round
        loop and every subsequent :meth:`serve_query` costs exactly one
        federated round (no per-query phase handshake). Pair with
        :meth:`serve_close`; :class:`repro.serve.federated.FederatedServer`
        drives this API with admission control and dynamic batching."""
        self._call("serve_open", timeout=timeout)

    def serve_query(self, rows, timeout: float = 3600.0, **kw):
        """One inference round inside an open serve session; returns
        scores in ``rows`` order (duplicates cross the wire once)."""
        return self._call("serve_query", timeout=timeout, rows=rows,
                          **kw)

    def serve_close(self, timeout: float = 600.0) -> None:
        """End the serve session opened by :meth:`serve_open`."""
        self._call("serve_close", timeout=timeout)

    def shutdown(self, timeout: float = 600.0) -> Dict[str, Any]:
        """End the federation and return per-role result dicts (the
        same shape the monolithic role functions used to return)."""
        if self._closed:
            return self._finish(timeout)
        self._cmd_q.put(("shutdown", {}))
        self._wait_reply(timeout)
        self._closed = True
        return self._finish(timeout)

    def __enter__(self) -> "VFLJob":
        return self

    def __exit__(self, *exc) -> None:
        if self._failed is None and not self._closed:
            self.shutdown()

    # -- plumbing ------------------------------------------------------------
    def _call(self, cmd: str, timeout: float = 3600.0, **kw):
        if self._failed is not None:
            raise RuntimeError("job already failed") from self._failed
        if self._closed:
            raise RuntimeError(f"job already shut down; cannot {cmd}")
        self._cmd_q.put((cmd, kw))
        status, payload = self._wait_reply(timeout)
        if status == "error":
            self._fail("master", payload)
        return payload

    def _wait_reply(self, timeout: float = 600.0):
        """Wait for the master's reply while watching every agent for
        failure — a crashed member surfaces its real traceback here
        instead of stalling the job until the comm timeout."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self._res_q.get(timeout=0.2)
            except queue.Empty:
                err = self._peek_agent_error()
                if err is not None:
                    self._fail(*err)
                if time.monotonic() > deadline:
                    self._abort()
                    raise TimeoutError("master agent did not reply")

    def _peek_agent_error(self):
        if self._q is not None:           # process mode: drain exits
            while True:
                try:
                    role, res = self._q.get_nowait()
                except queue.Empty:
                    break
                self._results[role] = res
        for role, res in list(self._results.items()):
            if isinstance(res, dict) and isinstance(res.get("error"),
                                                    BaseException):
                return role, res["error"]
        # a worker that died before it could even post (e.g. killed, or
        # crashed during interpreter spawn) would otherwise stall the
        # job until the comm timeout
        for role, p in self._procs.items():
            if role not in self._results and p.exitcode not in (None, 0):
                return role, RuntimeError(
                    f"agent process died with exit code {p.exitcode} "
                    f"before reporting a result")
        return None

    def _fail(self, role: str, err: BaseException):
        self._failed = err
        self._abort()
        raise RuntimeError(f"agent {role} failed") from err

    def _abort(self) -> None:
        self._closed = True
        for p in self._procs.values():
            if p.is_alive():
                p.terminate()
        for p in self._procs.values():
            p.join(timeout=10)

    def _finish(self, timeout: float) -> Dict[str, Any]:
        if self._procs:
            deadline = time.monotonic() + timeout
            while len(self._results) < len(self.world) \
                    and time.monotonic() < deadline:
                try:
                    role, res = self._q.get(timeout=1.0)
                    self._results[role] = res
                except queue.Empty:
                    if not any(p.is_alive()
                               for p in self._procs.values()):
                        break
            for p in self._procs.values():
                p.join(timeout=60)
        else:
            for t in self._threads:
                t.join(timeout=timeout)
        for role, res in self._results.items():
            if isinstance(res, dict) and isinstance(res.get("error"),
                                                    BaseException):
                raise RuntimeError(f"agent {role} failed") \
                    from res["error"]
        missing = [w for w in self.world if w not in self._results]
        if missing:
            raise RuntimeError(f"agents did not finish: {missing}")
        return dict(self._results)


def run_vfl(cfg: VFLConfig, master_data: MasterData,
            member_datas: List[MemberData], mode: str = "thread",
            callbacks: Sequence[Callback] = (),
            resume_dir: Optional[str] = None,
            pipeline_depth: Optional[int] = None,
            comm_cfg: Optional[CommCfg] = None) -> Dict[str, Any]:
    """One-shot job (matching + training + teardown) in the given mode.

    Compatibility wrapper over :class:`VFLJob` — returns the per-role
    result dicts the old ``(master_fn, member_fn, arbiter_fn)`` runner
    produced. Use VFLJob directly when you need predict/evaluate or
    multiple phases on live agents.

    Example::

        res = run_vfl(cfg, master, members, mode="grpc",
                      pipeline_depth=2)
        print(res["master"]["history"][-1]["loss"])
    """
    job = VFLJob(cfg, master_data, member_datas, mode=mode,
                 callbacks=callbacks, resume_dir=resume_dir,
                 pipeline_depth=pipeline_depth, comm_cfg=comm_cfg)
    job.fit()
    return job.shutdown()
