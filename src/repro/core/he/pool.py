"""Precomputed-randomness pool for Paillier encryption (DESIGN.md §3.4).

Paillier encryption is Enc(m) = (1 + m*n) * r^n mod n^2; the r^n blinding
factor is the entire cost (one full-width modexp) and is independent of
the message. This pool amortizes it two ways:

1. *Fixed-base comb*: blindings are generated as h^(n*k) for a one-time
   random base h: precompute table[i][j] = (h^n)^(j * 2^(w*i)) once,
   then each fresh r^n = prod over nonzero w-bit digits of k — ~n_bits/w
   modular mults and NO squarings, ~6x cheaper than a cold pow().
   (The blinding then ranges over the subgroup <h> rather than all of
   Z_n^*; an acceptable tradeoff for a prototyping toolbox, noted in
   DESIGN.md §3.4.)
2. *Background fill*: an optional daemon thread keeps the pool topped
   up between training steps, so hot-path encryption is two mults.

``take()`` never blocks: it pops a pooled value or generates inline.
"""
from __future__ import annotations

import math
import secrets
import threading
from collections import deque
from typing import Optional

from repro.core.he.paillier import PublicKey


class RandomnessPool:
    def __init__(self, pub: PublicKey, window: int = 4):
        self.pub = pub
        self._n_sq = pub.n_sq
        self._nbits = pub.n.bit_length()
        self._window = window
        self._mask = (1 << window) - 1
        self._nwin = (self._nbits + window - 1) // window
        while True:
            h = secrets.randbelow(pub.n - 3) + 2
            if math.gcd(h, pub.n) == 1:
                break
        base = pow(h, pub.n, self._n_sq)        # one-time full modexp
        # comb table: _tab[i][j] = base^(j << (w*i)), j in 0..2^w-1
        self._tab = []
        cur = base
        for _ in range(self._nwin):
            row = [1] * (1 << window)
            row[1] = cur
            for j in range(2, 1 << window):
                row[j] = (row[j - 1] * cur) % self._n_sq
            self._tab.append(row)
            cur = (row[-1] * cur) % self._n_sq  # cur^(2^w)
        self._items: deque = deque()
        self._cv = threading.Condition()
        self._alive = False
        self._thread: Optional[threading.Thread] = None
        self._generated = 0
        # hot-path accounting: a hit popped a pooled blinding (two-mult
        # encryption); a fallback generated inline on the caller's
        # critical path — sustained fallbacks mean the prefetch target
        # is too small for the training cadence (e.g. pipeline_depth
        # outpacing the background filler)
        self.hits = 0
        self.fallbacks = 0

    # -- generation ----------------------------------------------------------
    def _gen(self) -> int:
        k = 0
        while k == 0:
            k = secrets.randbits(self._nbits)
        acc = 1
        for i in range(self._nwin):
            d = (k >> (i * self._window)) & self._mask
            if d:
                acc = (acc * self._tab[i][d]) % self._n_sq
        self._generated += 1
        return acc                              # = (h^k)^n mod n^2

    # -- pool API ------------------------------------------------------------
    def take(self) -> int:
        with self._cv:
            rn = self._items.popleft() if self._items else None
            self._cv.notify_all()
        if rn is not None:
            self.hits += 1
            return rn
        self.fallbacks += 1
        return self._gen()

    def prefill(self, count: int) -> None:
        for _ in range(count):
            rn = self._gen()
            with self._cv:
                self._items.append(rn)

    def start(self, target: int = 64) -> None:
        """Spawn a background filler keeping ~target items pooled."""
        if self._thread is not None:
            return
        self._alive = True

        def loop():
            while self._alive:
                with self._cv:
                    while self._alive and len(self._items) >= target:
                        self._cv.wait(0.25)
                    if not self._alive:
                        return
                rn = self._gen()                # outside the lock
                with self._cv:
                    self._items.append(rn)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._alive = False
        with self._cv:
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __len__(self) -> int:
        with self._cv:
            return len(self._items)

    def stats(self) -> dict:
        """Hot-path counters: pooled hits vs inline fallbacks (and the
        total blindings generated, background + inline)."""
        return {"hits": self.hits, "fallbacks": self.fallbacks,
                "generated": self._generated, "pooled": len(self)}

    # -- convenience ---------------------------------------------------------
    def encrypt_int(self, m: int) -> int:
        return self.pub.encrypt_int(m, rn=self.take())
