"""SIMD-style ciphertext packing for Paillier (DESIGN.md §3.2).

A Paillier plaintext is a ~n-bit integer; our fixed-point values need
only ~2*SCALE_BITS + log2(batch) bits, so one plaintext can carry
K = (n_bits - 2) // slot_bits values in disjoint bit-ranges ("slots").
Slots hold *signed* values in balanced-digit representation: the packed
integer is sum_j v_j * 2^(j*slot_bits) computed over Z (borrows between
slots are absorbed by ordinary integer arithmetic), and decoding peels
balanced digits d in (-2^(s-1), 2^(s-1)] from the bottom up. This makes
packed ciphertexts closed under homomorphic addition and plaintext
multiplication as long as every slot stays below its guard-bit budget.

The packed homomorphic matvec computes X^T @ Enc(r) with one
exponentiation per (sample, K-feature chunk) instead of one per matrix
element: Enc(r_i)^{pack(X[i, chunk])} = Enc(pack_j(X[i,j] * r_i)), and
the product over samples accumulates all K dot products at once. A
per-slot offset keeps every exponent positive (no modular inverses) at
the cost of one extra "ones" column whose slot recovers sum_i r_i for
the exact integer correction at decrypt time.

All exponentiations inside one batch share Straus interleaved
multi-exponentiation tables: ~w-bit windows, squarings shared across
all bases — the dominant cost drops from |exp| squarings per sample to
|exp| squarings per *chunk*.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.he.paillier import PublicKey

GUARD_BITS = 4          # headroom on top of the worst-case slot bound


# ---------------------------------------------------------------------------
# balanced-digit packing
# ---------------------------------------------------------------------------


def pack_signed(vals: Sequence[int], slot_bits: int) -> int:
    """Pack signed ints (|v| < 2^(slot_bits-1)) into one integer."""
    acc = 0
    for j, v in enumerate(vals):
        acc += int(v) << (j * slot_bits)
    return acc


def unpack_signed(packed: int, slot_bits: int, count: int) -> List[int]:
    """Inverse of pack_signed — balanced-digit extraction."""
    out = []
    half = 1 << (slot_bits - 1)
    mask = (1 << slot_bits) - 1
    v = int(packed)
    for _ in range(count):
        d = v & mask
        if d >= half:
            d -= 1 << slot_bits
        out.append(d)
        v = (v - d) >> slot_bits
    return out


def max_slots(pub: PublicKey, slot_bits: int) -> int:
    """How many slots fit one plaintext (sign bit + margin reserved)."""
    k = (pub.n.bit_length() - 2) // slot_bits
    if k < 1:
        raise ValueError(
            f"slot of {slot_bits} bits does not fit a "
            f"{pub.n.bit_length()}-bit Paillier plaintext; use a larger "
            f"key or smaller fixed-point values")
    return k


def encrypt_packed(pub: PublicKey, vals: Sequence[int], slot_bits: int,
                   pool=None) -> List[int]:
    """Encrypt ints K-per-ciphertext; one modexp carries K values."""
    k = max_slots(pub, slot_bits)
    take = pool.take if pool is not None else (lambda: None)
    return [pub.encrypt_int(pack_signed(vals[c:c + k], slot_bits),
                            rn=take())
            for c in range(0, len(vals), k)]


def decrypt_packed(priv, cts: Sequence[int], slot_bits: int,
                   count: int) -> List[int]:
    """Decrypt packed ciphertexts back into ``count`` signed ints."""
    k = max_slots(priv.pub, slot_bits)
    out: List[int] = []
    for ct in cts:
        take = min(k, count - len(out))
        out.extend(unpack_signed(priv.decrypt_int(int(ct)), slot_bits,
                                 take))
    return out


# ---------------------------------------------------------------------------
# Straus interleaved multi-exponentiation
# ---------------------------------------------------------------------------


def pow_tables(bases: Sequence[int], mod: int,
               window: int = 4) -> List[List[int]]:
    """Per-base tables of powers 0..2^w-1, shared across multi_pow calls."""
    size = 1 << window
    tabs = []
    for b in bases:
        b = int(b) % mod
        t = [1] * size
        t[1] = b
        for j in range(2, size):
            t[j] = (t[j - 1] * b) % mod
        tabs.append(t)
    return tabs


def multi_pow(exps: Sequence[int], mod: int, tables: List[List[int]],
              window: int = 4) -> int:
    """prod_i base_i^{exps_i} mod ``mod`` with shared squarings.

    Exponents must be non-negative. Cost ~ max_bits squarings total
    (instead of per base) + one table mult per nonzero window digit.
    """
    nbits = max((int(e).bit_length() for e in exps), default=0)
    if nbits == 0:
        return 1
    mask = (1 << window) - 1
    acc = 1
    for wpos in range((nbits + window - 1) // window - 1, -1, -1):
        if acc != 1:
            for _ in range(window):
                acc = (acc * acc) % mod
        shift = wpos * window
        for t, e in zip(tables, exps):
            d = (int(e) >> shift) & mask
            if d:
                acc = (acc * t[d]) % mod
    return acc


# ---------------------------------------------------------------------------
# packed homomorphic matvec
# ---------------------------------------------------------------------------


def matvec_slot_plan(pub: PublicKey, x_int: np.ndarray,
                     r_bound: int) -> Dict[str, int]:
    """Slot geometry for a packed X^T r: width from the exact worst-case
    magnitude of sum_i (x_ij + off) * r_i, K from the key capacity."""
    b, _ = x_int.shape
    r_bound = max(int(r_bound), 1)
    xb = int(np.abs(x_int).max()) if x_int.size else 0
    off = 1 << max(xb.bit_length(), 1)
    colsum = int(np.abs(x_int).astype(object).sum(axis=0).max()) \
        if x_int.size else 0
    bound = max((colsum + b * off) * r_bound,          # feature slots
                (off + 1) * b * r_bound)               # the ones column
    slot_bits = bound.bit_length() + 1 + GUARD_BITS
    return {"slot_bits": slot_bits, "k": max_slots(pub, slot_bits),
            "off_bits": off.bit_length() - 1}


def packed_matvec(pub: PublicKey, x_int: np.ndarray,
                  ciphers: Sequence[int], r_bound: int,
                  pool=None, window: int = 4,
                  ) -> Tuple[List[int], Dict[str, int]]:
    """Homomorphic X^T @ Enc(r) with K dot products per ciphertext.

    x_int: (B, d) int64 fixed-point features; ciphers: B ciphertexts
    Enc(r_i); r_bound: bound on |r_i| (fixed-point int). Returns
    (ciphertexts, info); slots hold [g_0..g_{d-1}, (off+1)*sum_i r_i]
    at product scale. Decode with unpack_matvec.
    """
    b, d = x_int.shape
    assert len(ciphers) == b, "one ciphertext per sample expected"
    info = matvec_slot_plan(pub, x_int, r_bound)
    slot_bits, k, off = info["slot_bits"], info["k"], \
        1 << info["off_bits"]
    info["count"] = d
    tabs = pow_tables(ciphers, pub.n_sq, window)
    rows = x_int.tolist()                       # python ints, fast access
    cts: List[int] = []
    d_tot = d + 1                               # + the ones column
    for c0 in range(0, d_tot, k):
        cols = range(c0, min(d_tot, c0 + k))
        exps = []
        for i in range(b):
            row = rows[i]
            acc = 0
            for t, j in enumerate(cols):
                v = off + (row[j] if j < d else 1)
                acc += v << (t * slot_bits)
            exps.append(acc)
        ct = multi_pow(exps, pub.n_sq, tabs, window)
        if pool is not None:                    # re-randomize
            ct = (ct * pool.take()) % pub.n_sq
        cts.append(ct)
    return cts, info


def unpack_matvec(plains: Sequence[int], slot_bits: int, k: int,
                  off_bits: int, count: int) -> List[int]:
    """Decode decrypted packed-matvec plaintexts into ``count`` gradient
    ints at product scale (2*SCALE_BITS for SCALE_BITS inputs)."""
    off = 1 << off_bits
    slots: List[int] = []
    remaining = count + 1
    for v in plains:
        take = min(k, remaining - len(slots))
        slots.extend(unpack_signed(int(v), slot_bits, take))
    if len(slots) != count + 1:
        raise ValueError("packed matvec: slot count mismatch")
    s_slot = slots[count]
    if s_slot % (off + 1):
        raise ValueError("packed matvec: corrupted ones-column slot")
    s = s_slot // (off + 1)                     # sum_i r_i, exact
    return [slots[j] - off * s for j in range(count)]
