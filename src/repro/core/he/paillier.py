"""Paillier additively-homomorphic encryption (the paper's HE layer).

Pure-python big-int implementation: keygen (Miller-Rabin primes),
encrypt/decrypt, ciphertext addition, plaintext scalar multiplication,
and a vectorized fixed-point codec for float tensors. Used by the
arbitered logistic-regression protocol: the master encrypts residuals,
members compute encrypted gradients (X^T r under HE = scalar-mult +
add), the arbiter (key holder) decrypts.

Decryption is CRT-accelerated (DESIGN.md §3.3): the key holder knows
the factorization n = p*q, so ``c^lam mod n^2`` splits into two
half-width exponentiations mod p^2 and q^2 recombined by the Chinese
remainder theorem — ~3-4x fewer bit operations than the textbook path.

TPU note (DESIGN.md §3.5): 2048-bit modular arithmetic has no MXU/VPU
analogue — this layer is CPU-side by necessity; the device-path privacy
equivalent is mask-based secure aggregation (secure_agg.py).
"""
from __future__ import annotations

import math
import secrets
from dataclasses import dataclass
from typing import Iterable, List, Tuple

import numpy as np

_SMALL_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47]


def _is_probable_prime(n: int, rounds: int = 24) -> bool:
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = secrets.randbelow(n - 3) + 2
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = pow(x, 2, n)
            if x == n - 1:
                break
        else:
            return False
    return True


def _random_prime(bits: int) -> int:
    while True:
        cand = secrets.randbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(cand):
            return cand


@dataclass(frozen=True)
class PublicKey:
    n: int

    @property
    def n_sq(self) -> int:
        return self.n * self.n

    @property
    def n_bytes(self) -> int:
        """Wire width of the modulus."""
        return (self.n.bit_length() + 7) // 8

    @property
    def cipher_bytes(self) -> int:
        """Wire width of one ciphertext (< n^2)."""
        return (2 * self.n.bit_length() + 7) // 8

    def encrypt_int(self, m: int, rn: int = None) -> int:
        """Encrypt; ``rn`` is an optional precomputed blinding r^n mod n^2
        (see pool.RandomnessPool) that turns encryption into two mults."""
        m %= self.n
        if rn is None:
            r = secrets.randbelow(self.n - 2) + 1
            rn = pow(r, self.n, self.n_sq)
        # g = n + 1  =>  g^m = 1 + m*n (mod n^2)
        return ((1 + m * self.n) * rn) % self.n_sq

    def add(self, c1: int, c2: int) -> int:
        return (c1 * c2) % self.n_sq

    def mul_scalar(self, c: int, k: int) -> int:
        return pow(c, k % self.n, self.n_sq)


def _L(x: int, n: int) -> int:
    return (x - 1) // n


@dataclass(frozen=True)
class PrivateKey:
    pub: PublicKey
    lam: int
    mu: int
    # CRT acceleration (optional: p == 0 disables it and decrypt_int
    # falls back to the textbook full-width path)
    p: int = 0
    q: int = 0
    hp: int = 0             # L_p(g^{p-1} mod p^2)^-1 mod p
    hq: int = 0
    p_inv_q: int = 0        # p^-1 mod q

    def decrypt_int(self, c: int) -> int:
        if self.p:
            return self.decrypt_int_crt(c)
        return self.decrypt_int_plain(c)

    def decrypt_int_plain(self, c: int) -> int:
        n = self.pub.n
        x = pow(c, self.lam, self.pub.n_sq)
        m = (_L(x, n) * self.mu) % n
        return m if m <= n // 2 else m - n      # centered representative

    def decrypt_int_crt(self, c: int) -> int:
        """Decrypt mod p^2 and q^2 separately, CRT-recombine."""
        p, q, n = self.p, self.q, self.pub.n
        p_sq, q_sq = p * p, q * q
        mp = _L(pow(c % p_sq, p - 1, p_sq), p) * self.hp % p
        mq = _L(pow(c % q_sq, q - 1, q_sq), q) * self.hq % q
        m = (mp + p * ((mq - mp) * self.p_inv_q % q)) % n
        return m if m <= n // 2 else m - n


def keygen(bits: int = 512) -> Tuple[PublicKey, PrivateKey]:
    while True:
        p = _random_prime(bits // 2)
        q = _random_prime(bits // 2)
        if p != q:
            break
    n = p * q
    lam = math.lcm(p - 1, q - 1)
    pub = PublicKey(n)
    # mu = (L(g^lam mod n^2))^-1 mod n; with g = n+1, L(g^lam) = lam mod n
    mu = pow(lam % n, -1, n)
    g = n + 1
    hp = pow(_L(pow(g, p - 1, p * p), p), -1, p)
    hq = pow(_L(pow(g, q - 1, q * q), q), -1, q)
    return pub, PrivateKey(pub, lam, mu, p, q, hp, hq, pow(p, -1, q))


# ---------------------------------------------------------------------------
# fixed-point float vectors (vectorized numpy encode/decode)
# ---------------------------------------------------------------------------

SCALE_BITS = 32


def encode_fixed(x: np.ndarray, scale_bits: int = SCALE_BITS) -> np.ndarray:
    """float array -> flat int64 fixed-point array (round-to-nearest)."""
    flat = np.asarray(x, np.float64).ravel()
    if flat.size and not np.isfinite(flat).all():
        raise ValueError("fixed-point encode: input has NaN/inf")
    scaled = np.rint(flat * float(1 << scale_bits))
    if scaled.size and np.abs(scaled).max() >= 2.0 ** 62:
        raise OverflowError("fixed-point encode overflows int64; "
                            "reduce magnitude or scale_bits")
    return scaled.astype(np.int64)


def decode_fixed(vals: Iterable[int], shape,
                 scale_bits: int = SCALE_BITS) -> np.ndarray:
    """ints (python or numpy, any magnitude) -> float array / 2^scale."""
    arr = np.fromiter((float(v) for v in vals), np.float64)
    return (arr / float(1 << scale_bits)).reshape(shape)


def encrypt_vector(pub: PublicKey, x: np.ndarray, pool=None) -> np.ndarray:
    take = pool.take if pool is not None else (lambda: None)
    return np.array([pub.encrypt_int(int(m), rn=take())
                     for m in encode_fixed(x)],
                    dtype=object).reshape(np.shape(x))


def decrypt_vector(priv: PrivateKey, c: np.ndarray,
                   scale_bits: int = SCALE_BITS, pool=None,
                   chunk: int = 64) -> np.ndarray:
    """Decrypt a ciphertext array. With ``pool`` (a
    :class:`~repro.core.he.decrypt_pool.DecryptPool`) the ciphertexts
    stream through the worker pool in ``chunk``-sized pieces; without
    one, the serial path binds the CRT dispatch once instead of
    re-resolving it per element."""
    cts = [int(v) for v in np.ravel(c)]
    if pool is not None:
        flat = pool.decrypt_many(cts, chunk=chunk)
    else:
        dec = priv.decrypt_int_crt if priv.p else priv.decrypt_int_plain
        flat = [dec(v) for v in cts]
    return decode_fixed(flat, np.shape(c), scale_bits)


def add_cipher(pub: PublicKey, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.array([pub.add(int(x), int(y))
                     for x, y in zip(np.ravel(a), np.ravel(b))],
                    dtype=object).reshape(np.shape(a))


def matvec_cipher(pub: PublicKey, x_plain: np.ndarray,
                  c_vec: np.ndarray) -> np.ndarray:
    """X^T @ Enc(r) done homomorphically: Enc(sum_i X[i,j] * r[i]).

    x_plain: (n, d) float; c_vec: (n,) ciphertexts (fixed-point encoded).
    Result: (d,) ciphertexts at DOUBLE scale (2*SCALE_BITS).

    This is the scalar reference path — one modexp per matrix element.
    The production path is packing.packed_matvec (K values per
    ciphertext, shared-squaring multi-exponentiation).
    """
    n, d = x_plain.shape
    x_int = encode_fixed(x_plain).reshape(n, d)
    out = []
    for j in range(d):
        acc = pub.encrypt_int(0)
        for i in range(n):
            acc = pub.add(acc, pub.mul_scalar(int(c_vec[i]),
                                              int(x_int[i, j])))
        out.append(acc)
    return np.array(out, dtype=object)
