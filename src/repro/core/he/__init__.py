"""Homomorphic-encryption layer (Paillier), grown from a single module
into packing / CRT / pool submodules (DESIGN.md §3):

- paillier:  keygen, encrypt/decrypt (CRT-accelerated), fixed-point
             codec, scalar homomorphic ops — the reference path.
- packing:   SIMD-style slot packing (K values per ciphertext), the
             packed homomorphic matvec, Straus multi-exponentiation.
- pool:      precomputed r^n blinding pool (fixed-base comb + optional
             background fill) making hot-path encryption two mults.
- decrypt_pool: arbiter-side process pool CRT-decrypting ciphertext
             chunks in parallel with order-preserving reassembly and
             attributed worker-crash propagation (DESIGN.md §10.1).

``from repro.core import he`` keeps working: everything public is
re-exported here.
"""
from repro.core.he.paillier import (SCALE_BITS, PrivateKey, PublicKey,
                                    _is_probable_prime, add_cipher,
                                    decode_fixed, decrypt_vector,
                                    encode_fixed, encrypt_vector, keygen,
                                    matvec_cipher)
from repro.core.he.packing import (GUARD_BITS, decrypt_packed,
                                   encrypt_packed, matvec_slot_plan,
                                   max_slots, multi_pow, pack_signed,
                                   packed_matvec, pow_tables,
                                   unpack_matvec, unpack_signed)
from repro.core.he.decrypt_pool import (DecryptPool, DecryptSession,
                                        DecryptWorkerError)
from repro.core.he.pool import RandomnessPool

__all__ = [
    "SCALE_BITS", "GUARD_BITS", "PublicKey", "PrivateKey", "keygen",
    "encode_fixed", "decode_fixed", "encrypt_vector", "decrypt_vector",
    "add_cipher", "matvec_cipher", "pack_signed", "unpack_signed",
    "max_slots", "encrypt_packed", "decrypt_packed", "multi_pow",
    "pow_tables", "matvec_slot_plan", "packed_matvec", "unpack_matvec",
    "RandomnessPool", "DecryptPool", "DecryptSession",
    "DecryptWorkerError",
]
