"""Arbiter-side decrypt worker pool (DESIGN.md §10.1).

Paillier decryption is pure big-int ``pow``, which holds the GIL — a
thread pool buys nothing, so the pool runs ``workers`` spawned OS
processes, each holding a copy of the private key (a frozen dataclass
of plain ints, cheap to pickle) and CRT-decrypting whole ciphertext
chunks per task. The packed-matvec + CRT path is embarrassingly
parallel across ciphertexts: a chunk is independent of every other
chunk, so chunks stream into the pool as they arrive off the wire
(``TypedChannel.recv_parts``) and plaintexts reassemble in submission
*index* order regardless of completion order.

Failure semantics: a worker that dies mid-round (OOM kill, segfault in
a native big-int op, operator ``kill``) must not hang the arbiter on a
result that will never come. ``gather`` watches worker liveness while
it waits and raises :class:`DecryptWorkerError` naming the worker and
the outstanding chunks; a worker that *reports* an exception (bad
ciphertext bytes) raises the same attributed error without losing the
pool.

``workers=0`` is the inline mode: ``submit``/``gather`` run the exact
serial CRT loop on the caller's thread — the seed decrypt path, used
for bit-identity tests and as the ``decrypt_vector`` fallback.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import time
from typing import Dict, List, Optional, Sequence

from repro.core.he.paillier import PrivateKey


class DecryptWorkerError(RuntimeError):
    """A decrypt worker died or reported a failure; the message names
    the worker (index/pid), the cause, and the chunks outstanding."""


def _worker_main(widx: int, priv: PrivateKey, task_q, res_q) -> None:
    """Worker loop: (session, idx, [ciphertexts]) -> decrypt -> result.
    Module-level for spawn picklability. A ``None`` task shuts down."""
    dec = priv.decrypt_int_crt if priv.p else priv.decrypt_int_plain
    while True:
        task = task_q.get()
        if task is None:
            return
        sess, idx, cts = task
        t0 = time.perf_counter()
        try:
            plains = [dec(c) for c in cts]
        except BaseException as e:      # report, keep the worker alive
            res_q.put((sess, idx, None, f"{type(e).__name__}: {e}",
                       widx, 0.0))
            continue
        res_q.put((sess, idx, plains, None, widx,
                   time.perf_counter() - t0))


class DecryptSession:
    """One decryption round: chunks submitted in any order, plaintexts
    gathered in index order. Obtained from :meth:`DecryptPool.session`;
    sessions are sequential (one open round per pool)."""

    def __init__(self, pool: "DecryptPool", sid: int):
        self._pool = pool
        self._sid = sid
        self._results: Dict[int, List[int]] = {}
        self._submitted = 0

    def submit(self, idx: int, cts: Sequence[int]) -> None:
        """Queue chunk ``idx`` (arrival order is irrelevant — results
        reassemble by ``idx``)."""
        self._pool._submit(self._sid, idx, [int(c) for c in cts])
        self._submitted += 1

    def gather(self, n: Optional[int] = None,
               timeout: Optional[float] = None) -> List[int]:
        """Block until all ``n`` chunks (default: every submitted one)
        are decrypted; return the concatenated plaintexts in chunk-index
        order. Raises :class:`DecryptWorkerError` on a dead or failing
        worker, ``TimeoutError`` when ``timeout`` (default: the pool's)
        elapses first."""
        n = self._submitted if n is None else n
        self._pool._collect(self._sid, self._results, n, timeout)
        out: List[int] = []
        for idx in sorted(self._results):
            out.extend(self._results[idx])
        return out


class DecryptPool:
    """Process pool decrypting ciphertext chunks with ``priv``.

    Stats (``stats()``): chunks/values decrypted, cumulative in-worker
    ``decrypt_s`` vs pool ``idle_s`` (worker-seconds not spent
    decrypting while rounds were open), and the busy high-water mark.
    """

    def __init__(self, priv: PrivateKey, workers: int = 0,
                 timeout_s: float = 60.0):
        self.priv = priv
        self.workers = max(0, int(workers))
        self.timeout_s = timeout_s
        self._sid = 0
        self._inflight = 0
        self._procs: List[mp.process.BaseProcess] = []
        self._task_q = None
        self._res_q = None
        # stats
        self.chunks = 0
        self.values = 0
        self.decrypt_s = 0.0
        self.idle_s = 0.0
        self.max_busy = 0
        self._open_s = 0.0            # wall time with chunks in flight
        self._t_first: Optional[float] = None
        if self.workers:
            ctx = mp.get_context("spawn")
            self._task_q = ctx.Queue()
            self._res_q = ctx.Queue()
            # process-mode VFL agents are themselves daemonic (an
            # abandoned VFLJob must not block interpreter exit), and
            # multiprocessing refuses children of daemons because they
            # would escape atexit joining. Our workers don't: they are
            # daemons too (die with the arbiter) and close() joins
            # them — so lift the flag just for the spawn.
            cfg = mp.current_process()._config
            was_daemon = cfg.get("daemon", False)
            if was_daemon:
                cfg["daemon"] = False
            try:
                for i in range(self.workers):
                    p = ctx.Process(target=_worker_main,
                                    args=(i, priv, self._task_q,
                                          self._res_q), daemon=True)
                    p.start()
                    self._procs.append(p)
            finally:
                if was_daemon:
                    cfg["daemon"] = True
        else:
            self._dec = priv.decrypt_int_crt if priv.p \
                else priv.decrypt_int_plain

    # -- rounds --------------------------------------------------------------
    def session(self) -> DecryptSession:
        self._sid += 1
        return DecryptSession(self, self._sid)

    def decrypt_many(self, cts: Sequence[int],
                     chunk: int = 64) -> List[int]:
        """Decrypt a flat ciphertext list, pool-parallel in ``chunk``-d
        pieces (inline serial at ``workers=0``)."""
        sess = self.session()
        cts = list(cts)
        for i, lo in enumerate(range(0, len(cts), max(1, chunk))):
            sess.submit(i, cts[lo:lo + max(1, chunk)])
        return sess.gather()

    # -- internals -----------------------------------------------------------
    def _submit(self, sid: int, idx: int, cts: List[int]) -> None:
        self.chunks += 1
        self.values += len(cts)
        if not self.workers:
            t0 = time.perf_counter()
            self._serial = getattr(self, "_serial", {})
            self._serial[(sid, idx)] = [self._dec(c) for c in cts]
            self.decrypt_s += time.perf_counter() - t0
            return
        if self._inflight == 0:
            self._t_first = time.perf_counter()
        self._inflight += 1
        self.max_busy = max(self.max_busy,
                            min(self._inflight, self.workers))
        self._task_q.put((sid, idx, cts))

    def _collect(self, sid: int, results: Dict[int, List[int]],
                 n: int, timeout: Optional[float]) -> None:
        if not self.workers:
            serial = getattr(self, "_serial", {})
            for (s, idx) in list(serial):
                if s == sid:
                    results[idx] = serial.pop((s, idx))
            if len(results) < n:
                raise DecryptWorkerError(
                    f"inline decrypt session {sid}: {n - len(results)} "
                    f"of {n} chunks were never submitted")
            return
        deadline = time.monotonic() + (self.timeout_s if timeout is None
                                       else timeout)
        while len(results) < n:
            try:
                rsid, idx, plains, err, widx, dt = \
                    self._res_q.get(timeout=0.05)
            except _queue.Empty:
                self._check_alive(sid, n - len(results))
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"decrypt pool: session {sid} still missing "
                        f"{n - len(results)} of {n} chunks after "
                        f"{self.timeout_s if timeout is None else timeout}s")
                continue
            self._inflight -= 1
            if err is not None:
                raise DecryptWorkerError(
                    f"decrypt worker #{widx} failed on chunk {idx} of "
                    f"session {rsid}: {err}")
            self.decrypt_s += dt
            if rsid == sid:
                results[idx] = plains
            # a stale-session result (caller abandoned a round after an
            # error) is drained and dropped
        if self._inflight == 0 and self._t_first is not None:
            self._open_s += time.perf_counter() - self._t_first
            self._t_first = None

    def _check_alive(self, sid: int, missing: int) -> None:
        for i, p in enumerate(self._procs):
            if not p.is_alive():
                raise DecryptWorkerError(
                    f"decrypt worker #{i} (pid {p.pid}) died with exit "
                    f"code {p.exitcode} while session {sid} had "
                    f"{missing} chunks outstanding")

    # -- lifecycle / stats ---------------------------------------------------
    def stats(self) -> Dict[str, float]:
        idle = max(0.0, self.workers * self._open_s - self.decrypt_s) \
            if self.workers else 0.0
        return {"workers": self.workers, "chunks": self.chunks,
                "values": self.values, "max_busy": self.max_busy,
                "decrypt_s": round(self.decrypt_s + 0.0, 4),
                "idle_s": round(self.idle_s + idle, 4)}

    def close(self) -> None:
        if not self.workers:
            return
        for _ in self._procs:
            try:
                self._task_q.put(None)
            except (ValueError, OSError):
                break
        for p in self._procs:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        self._procs = []
        self.workers = 0
        self._dec = self.priv.decrypt_int_crt if self.priv.p \
            else self.priv.decrypt_int_plain

    def __enter__(self) -> "DecryptPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
