"""Mesh-mode VFL: the paper's exchange schedule lowered onto a TPU mesh.

Beyond-paper execution mode (DESIGN.md §2): parties map to the ``pod``
mesh axis. A member's bottom-forward runs pod-locally on its own feature
shard; the embedding exchange ("send u_p to master") becomes a ``psum``
over the pod axis; pairwise secure-aggregation masks (core/secure_agg)
are added before the psum so no pod ever observes another pod's raw
embedding — the same privacy property the thread/socket modes get from
message isolation, now at ICI/DCN speed.

The top model + loss is computed replicated on every pod (it only sees
the aggregate), and the gradient exchange is the transposed collective,
generated automatically by jax.grad through the psum.

The same function also drives the VFL-LLM integration: members hold the
embedding/feature frontends of the assigned architectures and the master
holds the transformer backbone (examples/vfl_llm.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import secure_agg
from repro.core.protocols.split_nn import _bce, mlp_apply, mlp_init
from repro.sharding.rules import shard_map_compat


def init_party_params(key, n_parties: int, d_in: int, hidden, e: int):
    """Stacked bottom params, one slice per party (pod)."""
    def one(i):
        return mlp_init(jax.random.fold_in(key, i + 2),
                        (d_in,) + tuple(hidden) + (e,))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[
        one(i) for i in range(n_parties)])
    return stacked


def make_mesh_vfl_step(mesh: Mesh, n_parties: int, lr: float = 0.05,
                       use_masks: bool = True):
    """Returns a jit'd step: (bottoms, top, x, y, key) -> (..., loss).

    bottoms: party-stacked pytree with leading dim n_parties, sharded
    over 'pod'; x: (n_parties, batch, d_in) — party feature slices
    (padded to a common width); y: (batch, items) labels (replicated —
    only the aggregate loss needs them).
    """
    def step(bottoms, top, x, y, key):
        def loss_fn(bottoms, top):
            def party_fwd(bottom_p, x_p):
                # runs per pod: bottom_p has a leading party dim of 1
                b = jax.tree.map(lambda a: a[0], bottom_p)
                u = mlp_apply(b, x_p[0], final_act=True)
                if use_masks:
                    idx = jax.lax.axis_index("pod")
                    mask = _mask_for(key, idx, n_parties, u.shape)
                    u = u + mask
                return jax.lax.psum(u, "pod")

            agg = shard_map_compat(
                party_fwd, mesh=mesh,
                in_specs=(P("pod"), P("pod")),
                out_specs=P())(bottoms, x)
            logits = mlp_apply(top, agg)
            return _bce(logits, y)

        loss, (g_b, g_t) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            bottoms, top)
        new_b = jax.tree.map(lambda p, g: p - lr * g, bottoms, g_b)
        new_t = jax.tree.map(lambda p, g: p - lr * g, top, g_t)
        return new_b, new_t, loss

    return jax.jit(step)


def _mask_for(key, party_idx, n_parties: int, shape):
    """Pairwise-canceling mask, branch-free over the traced party index."""
    masks = jnp.stack([
        secure_agg.pairwise_mask(key, i, n_parties, shape)
        for i in range(n_parties)])
    return masks[party_idx]
