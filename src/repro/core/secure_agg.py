"""Mask-based secure aggregation — the TPU-idiomatic HE substitute.

Pairwise PRG masks (Bonawitz et al. style): parties i<j share a seed;
party i adds +PRG(seed_ij), party j adds -PRG(seed_ij). Each individual
contribution is information-theoretically masked from the aggregator,
while the SUM over all parties is exact because masks cancel.

Runs at device speed (jax.random.fold_in / normal) so the mesh-mode VFL
step can mask member embeddings before the psum over the ``pod`` axis —
the property VFL needs ("server sees only the aggregate") with zero
big-int cost. Masks are fp32 and cancellation is exact (same values
added and subtracted).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def _pair_key(base: jax.Array, i: int, j: int) -> jax.Array:
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(base, lo), hi)


def pairwise_mask(base_key: jax.Array, party: int, n_parties: int,
                  shape, dtype=jnp.float32) -> jax.Array:
    """Net mask party ``party`` must ADD to its contribution."""
    mask = jnp.zeros(shape, jnp.float32)
    for other in range(n_parties):
        if other == party:
            continue
        m = jax.random.normal(_pair_key(base_key, party, other), shape,
                              jnp.float32)
        mask = mask + m if party < other else mask - m
    return mask.astype(dtype)


def mask_contribution(base_key: jax.Array, party: int, n_parties: int,
                      x: jax.Array) -> jax.Array:
    return x + pairwise_mask(base_key, party, n_parties, x.shape, x.dtype)


def aggregate(masked: Sequence[jax.Array]) -> jax.Array:
    """Sum of masked contributions == sum of plaintexts (masks cancel)."""
    out = masked[0]
    for m in masked[1:]:
        out = out + m
    return out
