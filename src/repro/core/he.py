"""Paillier additively-homomorphic encryption (the paper's HE layer).

Pure-python big-int implementation: keygen (Miller-Rabin primes),
encrypt/decrypt, ciphertext addition, plaintext scalar multiplication,
and a fixed-point codec for float tensors. Used by the arbitered
logistic-regression protocol: the master encrypts residuals, members
compute encrypted gradients (X^T r under HE = scalar-mult + add), the
arbiter (key holder) decrypts.

TPU note (DESIGN.md): 2048-bit modular arithmetic has no MXU/VPU
analogue — this layer is CPU-side by necessity; the device-path privacy
equivalent is mask-based secure aggregation (secure_agg.py).
"""
from __future__ import annotations

import math
import secrets
from dataclasses import dataclass
from typing import Iterable, List, Tuple

import numpy as np

_SMALL_PRIMES = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47]


def _is_probable_prime(n: int, rounds: int = 24) -> bool:
    if n < 2:
        return False
    for p in _SMALL_PRIMES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = secrets.randbelow(n - 3) + 2
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = pow(x, 2, n)
            if x == n - 1:
                break
        else:
            return False
    return True


def _random_prime(bits: int) -> int:
    while True:
        cand = secrets.randbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(cand):
            return cand


@dataclass(frozen=True)
class PublicKey:
    n: int

    @property
    def n_sq(self) -> int:
        return self.n * self.n

    def encrypt_int(self, m: int) -> int:
        m %= self.n
        r = secrets.randbelow(self.n - 2) + 1
        # g = n + 1  =>  g^m = 1 + m*n (mod n^2)
        return ((1 + m * self.n) * pow(r, self.n, self.n_sq)) % self.n_sq

    def add(self, c1: int, c2: int) -> int:
        return (c1 * c2) % self.n_sq

    def mul_scalar(self, c: int, k: int) -> int:
        return pow(c, k % self.n, self.n_sq)


@dataclass(frozen=True)
class PrivateKey:
    pub: PublicKey
    lam: int
    mu: int

    def decrypt_int(self, c: int) -> int:
        n = self.pub.n
        x = pow(c, self.lam, self.pub.n_sq)
        m = ((x - 1) // n * self.mu) % n
        return m if m <= n // 2 else m - n      # centered representative


def keygen(bits: int = 512) -> Tuple[PublicKey, PrivateKey]:
    while True:
        p = _random_prime(bits // 2)
        q = _random_prime(bits // 2)
        if p != q:
            break
    n = p * q
    lam = math.lcm(p - 1, q - 1)
    pub = PublicKey(n)
    # mu = (L(g^lam mod n^2))^-1 mod n; with g = n+1, L(g^lam) = lam mod n
    mu = pow(lam % n, -1, n)
    return pub, PrivateKey(pub, lam, mu)


# ---------------------------------------------------------------------------
# fixed-point float vectors
# ---------------------------------------------------------------------------

SCALE_BITS = 32


def encode_fixed(x: np.ndarray, scale_bits: int = SCALE_BITS) -> List[int]:
    flat = np.asarray(x, np.float64).ravel()
    s = 1 << scale_bits
    return [int(round(float(v) * s)) for v in flat]


def decode_fixed(vals: Iterable[int], shape,
                 scale_bits: int = SCALE_BITS) -> np.ndarray:
    s = float(1 << scale_bits)
    arr = np.array([v / s for v in vals], np.float64)
    return arr.reshape(shape)


def encrypt_vector(pub: PublicKey, x: np.ndarray) -> np.ndarray:
    return np.array([pub.encrypt_int(m) for m in encode_fixed(x)],
                    dtype=object).reshape(np.shape(x))


def decrypt_vector(priv: PrivateKey, c: np.ndarray,
                   scale_bits: int = SCALE_BITS) -> np.ndarray:
    flat = [priv.decrypt_int(int(v)) for v in np.ravel(c)]
    return decode_fixed(flat, np.shape(c), scale_bits)


def add_cipher(pub: PublicKey, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.array([pub.add(int(x), int(y))
                     for x, y in zip(np.ravel(a), np.ravel(b))],
                    dtype=object).reshape(np.shape(a))


def matvec_cipher(pub: PublicKey, x_plain: np.ndarray,
                  c_vec: np.ndarray) -> np.ndarray:
    """X^T @ Enc(r) done homomorphically: Enc(sum_i X[i,j] * r[i]).

    x_plain: (n, d) float; c_vec: (n,) ciphertexts (fixed-point encoded).
    Result: (d,) ciphertexts at DOUBLE scale (2*SCALE_BITS).
    """
    n, d = x_plain.shape
    x_int = [encode_fixed(x_plain[:, j]) for j in range(d)]
    out = []
    for j in range(d):
        acc = pub.encrypt_int(0)
        for i in range(n):
            acc = pub.add(acc, pub.mul_scalar(int(c_vec[i]), x_int[j][i]))
        out.append(acc)
    return np.array(out, dtype=object)
