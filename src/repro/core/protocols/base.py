"""Protocol layer scaffolding: config, data containers, the matching
phase, deterministic batching, and the protocol registry.

A protocol is a subclass of :class:`~repro.core.protocols.driver.
VFLProtocol` — lifecycle hooks (``match`` / ``setup`` /
``on_batch_master`` / ``on_batch_member`` / ``arbiter_round`` /
``predict_*`` / ``finalize``) driven by the shared training driver.
Hooks speak only through the typed channel — never touching another
party's raw data — and the same class runs unchanged in thread /
process / socket modes (the paper's seamless-switching claim, validated
by tests against recorded seed traces).
"""
from __future__ import annotations

import hashlib
import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.comm import schema
from repro.comm.schema import Field, TypedChannel
from repro.core import psi


@dataclass
class VFLConfig:
    protocol: str = "linreg"
    epochs: int = 3
    batch_size: int = 64
    lr: float = 0.05
    l2: float = 0.0
    seed: int = 0
    he_bits: int = 256            # Paillier key size (tests keep it small)
    # batched-HE path: pack K gradient values per Paillier ciphertext and
    # use the shared-squaring multi-exponentiation matvec (DESIGN.md §3).
    # False falls back to the scalar one-modexp-per-element reference.
    he_packed: bool = True
    embedding_dim: int = 16       # split-nn bottom output width
    hidden: Tuple[int, ...] = (32,)
    use_psi: bool = True          # DH-PSI vs salted-hash matching
    record_every: int = 1
    # async exchange engine (DESIGN.md §7): how many training rounds the
    # master announces ahead of the one it is computing. 1 = strictly
    # synchronous lock-step (bit-identical to the recorded seed traces);
    # D >= 2 = bounded-staleness pipelining — members run their forward
    # stage up to D-1 steps ahead of the last gradient they applied, so
    # compute overlaps in-flight exchanges.
    pipeline_depth: int = 1
    # keep the final short batch of each epoch (True reproduces the old
    # silent tail-drop; every party derives the tail identically either
    # way, so modes always agree on batch boundaries)
    drop_last: bool = False
    # int8-compress split-NN activation/gradient exchanges (4x payload
    # reduction; error feedback keeps training unbiased). Beyond-paper.
    compress: bool = False
    # Bonawitz-style secure aggregation for split-NN: members agree on
    # pairwise DH seeds (exchanged member<->member over the
    # communicator) and mask their embeddings; masks cancel in the
    # master's sum, so the master only ever sees the aggregate.
    secure_agg: bool = False
    # straggler tolerance (elastic clusters): at pipeline_depth >= 2, a
    # member whose per-round contribution misses this deadline (seconds)
    # has its LAST delivered message substituted (bounded staleness) and
    # the straggle recorded in CommStats. 0 = disabled (wait forever,
    # i.e. the transport timeout).
    round_deadline_s: float = 0.0
    # member-side LRU cache of per-row feature-slice embeddings for the
    # predict/serve path (docs/serving.md): recsys query streams repeat
    # hot users, so members answering EVAL rounds skip the bottom-model
    # forward for cached row ids. Capacity in rows; 0 = disabled.
    # Invalidated whenever a fit phase starts (parameters change).
    serve_cache_rows: int = 0
    # key-sharded multi-arbiter decryption (DESIGN.md §10.3): N >= 2
    # runs N arbiter agents ("arbiter", "arbiter1", ...), each with its
    # OWN Paillier keypair decrypting a contiguous slice of every
    # member's gradient columns. The master encrypts the residual once
    # per arbiter key; no single arbiter ever sees a full gradient.
    # (Key-per-shard, not threshold cryptography — documented tradeoff.)
    n_arbiters: int = 1
    # streamed ciphertext rounds (DESIGN.md §10.2): split each
    # Enc(gradient) message into up to this many schema-framed chunks
    # isent back-to-back, so the arbiter starts decrypting chunk 0
    # while later chunks are still on the wire. 0/1 = single message
    # (the seed wire format, bit-identical traces).
    he_stream_chunks: int = 0
    # arbiter-side decrypt worker pool (DESIGN.md §10.1): CRT
    # decryption fans out over this many OS processes (bigint pow holds
    # the GIL). 0 = inline serial decryption (the seed path).
    he_decrypt_workers: int = 0
    # Gaussian noising defense (docs/privacy.md): each party adds
    # N(0, (noise_sigma * rms(signal))^2) noise to the label-bearing
    # exchange it emits — members noise split-NN embeddings before
    # sending, the arbiter noises decrypted logreg gradients before
    # returning them. Deterministic per (seed, round, party); 0.0 is
    # bit-identical to the un-noised path (no rng is ever constructed).
    noise_sigma: float = 0.0
    # adversarial exchange capture (docs/privacy.md): when True every
    # party records the plaintext payloads it sends and receives on the
    # label-bearing message types (split-NN embeddings, decrypted logreg
    # gradients, step announcements) into an in-memory ExchangeCapture
    # exported through ``Driver.result()["capture"]``. Off by default —
    # the tap is a ``None`` check on the hot path and capture-off runs
    # are trace-bit-identical to the seed fixtures (tested).
    capture_exchanges: bool = False
    # composable member tower (DESIGN.md §12, repro.models.tower): a
    # tuple of block configs ("embed:tokens=8,dim=32", "attn_block:
    # heads=4", "mlp:hidden=64") resolved by the tower factory into the
    # member bottom model. Empty = the legacy one-block MLP tower built
    # from ``hidden``/``embedding_dim`` (bit-identical to seed traces).
    tower: Tuple[str, ...] = ()
    # master-side tower: bottom half uses ``tower``/``hidden`` like a
    # member; this configures the top model over the summed embeddings.
    # Empty = the legacy MLP from ``hidden``.
    top_tower: Tuple[str, ...] = ()
    # model-parallel sharding of the member tower over N local devices
    # (launch/mesh.py x sharding/rules.py). 1 = unsharded single-device
    # params (the default; no mesh is ever constructed).
    tower_shard: int = 1


@dataclass
class MasterData:
    ids: List[str]
    y: np.ndarray                  # (n, n_items) targets
    x: Optional[np.ndarray] = None  # master's own feature slice (n, d_m)


@dataclass
class MemberData:
    ids: List[str]
    x: np.ndarray                  # (n, d_p)


def _select(ids: Sequence[str], order: Sequence[str], arr: np.ndarray
            ) -> np.ndarray:
    idx = {v: i for i, v in enumerate(ids)}
    rows = [idx[o] for o in order]
    return arr[rows]


def defense_noise(cfg: "VFLConfig", arr: np.ndarray, step: int,
                  key: str) -> np.ndarray:
    """Gaussian defense noise for one exchanged tensor
    (``cfg.noise_sigma``; docs/privacy.md): zero-mean with standard
    deviation ``noise_sigma * rms(arr)``, so the knob is a
    signal-relative noise floor rather than an absolute scale the
    caller would have to retune per protocol. Deterministic per
    (cfg.seed, step, key) — reruns and restarted agents add the exact
    same noise — and seeded via sha256, so streams for different
    rounds/parties are independent. Callers only invoke this when
    ``noise_sigma > 0``; at 0.0 no rng is ever constructed and the
    exchange stays bit-identical to the un-noised path."""
    rms = float(np.sqrt(np.mean(np.square(np.asarray(arr,
                                                     np.float64)))))
    if rms == 0.0:
        rms = 1.0
    digest = hashlib.sha256(
        f"noise/{cfg.seed}/{step}/{key}".encode()).digest()
    rng = np.random.default_rng(int.from_bytes(digest[:8], "big"))
    return rng.normal(0.0, cfg.noise_sigma * rms,
                      np.shape(arr)).astype(np.asarray(arr).dtype)


# ---------------------------------------------------------------------------
# phase 1: record matching
# ---------------------------------------------------------------------------

schema.message("psi/a_blinded", {"v": Field("uint8", 2)},
               doc="master ids blinded with the master's DH secret")
schema.message("psi/a_double", {"v": Field("uint8", 2)},
               doc="master's blinded ids re-blinded by a member")
schema.message("psi/b_blinded", {"v": Field("uint8", 2)},
               doc="member ids blinded with the member's DH secret")
schema.message("match/salt", {"salt": Field("bytes", 1)},
               doc="shared salt for hash-based matching")
schema.message("match/hashes", {"h": Field("uint8", 2)},
               doc="member's salted id digests")
schema.message("match/order", {"ids": Field("bytes", 1)},
               doc="agreed sample order (sorted common ids)")


def master_match(ch: TypedChannel, data: MasterData,
                 cfg: VFLConfig) -> List[str]:
    """Master drives ID matching; returns the agreed sample order."""
    common = set(data.ids)
    if cfg.use_psi:
        me = psi.DHPsi()
        blinded = me.blind(data.ids)
        for m in ch.members:
            ch.send(m, "psi/a_blinded", {"v": _ints_to_arr(blinded)})
            double_a = ch.recv(m, "psi/a_double").tensor("v")
            b_blinded = ch.recv(m, "psi/b_blinded").tensor("v")
            double_b = {int(x) for x in
                        _arr_to_ints(_ints_to_arr(me.blind_again(
                            _arr_to_ints(b_blinded))))}
            mine = [i for i, v in zip(data.ids, _arr_to_ints(double_a))
                    if int(v) in double_b]
            common &= set(mine)
    else:
        salt = hashlib.sha256(str(cfg.seed).encode()).hexdigest()
        for m in ch.members:
            ch.send(m, "match/salt", {"salt": _str_arr(salt)})
            theirs = ch.recv(m, "match/hashes").tensor("h")
            their_set = {bytes(bytearray(h)) for h in theirs}
            mine = [i for i in data.ids
                    if hashlib.sha256((salt + i).encode()).digest()
                    in their_set]
            common &= set(mine)
    order = sorted(common)
    payload = {"ids": np.array([i.encode() for i in order], dtype="S64")}
    for m in ch.members:
        ch.send(m, "match/order", payload)
    return order


def member_match(ch: TypedChannel, data: MemberData,
                 cfg: VFLConfig) -> List[str]:
    if cfg.use_psi:
        me = psi.DHPsi()
        a_blinded = ch.recv("master", "psi/a_blinded").tensor("v")
        ch.send("master", "psi/a_double",
                {"v": _ints_to_arr(me.blind_again(_arr_to_ints(a_blinded)))})
        ch.send("master", "psi/b_blinded",
                {"v": _ints_to_arr(me.blind(data.ids))})
    else:
        salt = _arr_str(ch.recv("master", "match/salt").tensor("salt"))
        buf = b"".join(hashlib.sha256((salt + i).encode()).digest()
                       for i in data.ids)
        hashes = np.frombuffer(buf, np.uint8).reshape(len(data.ids), 32)
        ch.send("master", "match/hashes", {"h": hashes})
    order = [b.decode() for b in
             ch.recv("master", "match/order").tensor("ids")]
    return order


# big ints <-> uint8 matrices for transport through the tensor codec.
# (NOT numpy "S" dtypes: those strip trailing NUL bytes and corrupt
# binary data — only text ids may use them.)
def _ints_to_arr(vals: Sequence[int], width: int = 96) -> np.ndarray:
    buf = b"".join(v.to_bytes(width, "big") for v in vals)
    return np.frombuffer(buf, np.uint8).reshape(len(vals), width)


def _arr_to_ints(arr: np.ndarray) -> List[int]:
    return [int.from_bytes(bytes(bytearray(row)), "big") for row in arr]


def _str_arr(s: str) -> np.ndarray:
    return np.array([s.encode()], dtype="S128")


def _arr_str(a: np.ndarray) -> str:
    return bytes(a[0]).decode()


# ---------------------------------------------------------------------------
# deterministic batching (every party derives the same boundaries)
# ---------------------------------------------------------------------------


def batch_order(n: int, cfg: VFLConfig, epoch: int) -> np.ndarray:
    """Deterministic permutation every party derives identically."""
    rng = np.random.default_rng(cfg.seed * 1000 + epoch)
    return rng.permutation(n)


def batch_bounds(n: int, cfg: VFLConfig) -> List[Tuple[int, int]]:
    """(lo, hi) slice bounds into the epoch permutation. The tail batch
    (up to batch_size-1 samples) is kept unless ``cfg.drop_last`` — the
    seed code silently dropped it, so those samples were never trained.
    """
    bs = cfg.batch_size
    bounds = [(lo, min(lo + bs, n)) for lo in range(0, n, bs)]
    if cfg.drop_last and bounds and bounds[-1][1] - bounds[-1][0] < bs:
        bounds.pop()
    return bounds


def batches(n: int, cfg: VFLConfig, epoch: int):
    perm = batch_order(n, cfg, epoch)
    for lo, hi in batch_bounds(n, cfg):
        yield perm[lo:hi]


def fit_rows(arr, n: int):
    """Fit ``arr`` to ``n`` rows along axis 0: identity when it already
    matches, else truncate or zero-pad. Stale contributions substituted
    for a down/straggling peer can carry a different (tail-)batch row
    count than the round being computed; this keeps the master's math
    shape-consistent until the peer catches up."""
    if arr.shape[0] == n:
        return arr
    if arr.shape[0] > n:
        return arr[:n]
    pad = [(0, n - arr.shape[0])] + [(0, 0)] * (arr.ndim - 1)
    return np.pad(arr, pad)


# ---------------------------------------------------------------------------
# protocol registry
# ---------------------------------------------------------------------------

PROTOCOLS: Dict[str, Type] = {}      # name -> VFLProtocol subclass


def register(cls) -> type:
    """Register a VFLProtocol subclass under ``cls.name`` (decorator)."""
    PROTOCOLS[cls.name] = cls
    return cls


def resolve_protocol(name: str) -> Type:
    """Look up a protocol class by registry name, or import one given a
    ``"module:ClassName"`` spec (lets spawned worker processes resolve
    user-defined protocols that were never imported in their parent)."""
    if name in PROTOCOLS:
        return PROTOCOLS[name]
    if ":" in name:
        modname, clsname = name.split(":", 1)
        cls = getattr(importlib.import_module(modname), clsname)
        PROTOCOLS.setdefault(name, cls)
        return cls
    raise KeyError(f"unknown protocol {name!r} "
                   f"(registered: {sorted(PROTOCOLS)})")
