"""Protocol layer scaffolding: config, data containers, the matching
phase, and the protocol registry.

A protocol is a triple of role functions (master_fn, member_fn,
arbiter_fn-or-None), each taking (comm, data, cfg) and speaking only
through the PartyCommunicator — never touching another party's raw data.
The same functions run unchanged in thread / process / socket / mesh
modes (the paper's seamless-switching claim, validated by tests).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm.base import PartyCommunicator
from repro.core import psi


@dataclass
class VFLConfig:
    protocol: str = "linreg"
    epochs: int = 3
    batch_size: int = 64
    lr: float = 0.05
    l2: float = 0.0
    seed: int = 0
    he_bits: int = 256            # Paillier key size (tests keep it small)
    # batched-HE path: pack K gradient values per Paillier ciphertext and
    # use the shared-squaring multi-exponentiation matvec (DESIGN.md §3).
    # False falls back to the scalar one-modexp-per-element reference.
    he_packed: bool = True
    embedding_dim: int = 16       # split-nn bottom output width
    hidden: Tuple[int, ...] = (32,)
    use_psi: bool = True          # DH-PSI vs salted-hash matching
    record_every: int = 1
    # int8-compress split-NN activation/gradient exchanges (4x payload
    # reduction; error feedback keeps training unbiased). Beyond-paper.
    compress: bool = False
    # Bonawitz-style secure aggregation for split-NN: members agree on
    # pairwise DH seeds (exchanged member<->member over the
    # communicator) and mask their embeddings; masks cancel in the
    # master's sum, so the master only ever sees the aggregate.
    secure_agg: bool = False


@dataclass
class MasterData:
    ids: List[str]
    y: np.ndarray                  # (n, n_items) targets
    x: Optional[np.ndarray] = None  # master's own feature slice (n, d_m)


@dataclass
class MemberData:
    ids: List[str]
    x: np.ndarray                  # (n, d_p)


def _select(ids: Sequence[str], order: Sequence[str], arr: np.ndarray
            ) -> np.ndarray:
    idx = {v: i for i, v in enumerate(ids)}
    rows = [idx[o] for o in order]
    return arr[rows]


# ---------------------------------------------------------------------------
# phase 1: record matching
# ---------------------------------------------------------------------------


def master_match(comm: PartyCommunicator, data: MasterData,
                 cfg: VFLConfig) -> List[str]:
    """Master drives ID matching; returns the agreed sample order."""
    common = set(data.ids)
    if cfg.use_psi:
        me = psi.DHPsi()
        blinded = me.blind(data.ids)
        for m in comm.members:
            comm.send(m, "psi/a_blinded",
                      {"v": _ints_to_arr(blinded)})
            double_a = comm.recv(m, "psi/a_double").tensor("v")
            b_blinded = comm.recv(m, "psi/b_blinded").tensor("v")
            double_b = {int(x) for x in
                        _arr_to_ints(_ints_to_arr(me.blind_again(
                            _arr_to_ints(b_blinded))))}
            mine = [i for i, v in zip(data.ids, _arr_to_ints(double_a))
                    if int(v) in double_b]
            common &= set(mine)
    else:
        salt = hashlib.sha256(str(cfg.seed).encode()).hexdigest()
        for m in comm.members:
            comm.send(m, "match/salt", {"salt": _str_arr(salt)})
            theirs = comm.recv(m, "match/hashes").tensor("h")
            their_set = {bytes(bytearray(h)) for h in theirs}
            mine = [i for i in data.ids
                    if hashlib.sha256((salt + i).encode()).digest()
                    in their_set]
            common &= set(mine)
    order = sorted(common)
    payload = {"ids": np.array([i.encode() for i in order], dtype="S64")}
    for m in comm.members:
        comm.send(m, "match/order", payload)
    return order


def member_match(comm: PartyCommunicator, data: MemberData,
                 cfg: VFLConfig) -> List[str]:
    if cfg.use_psi:
        me = psi.DHPsi()
        a_blinded = comm.recv("master", "psi/a_blinded").tensor("v")
        comm.send("master", "psi/a_double",
                  {"v": _ints_to_arr(me.blind_again(_arr_to_ints(a_blinded)))})
        comm.send("master", "psi/b_blinded",
                  {"v": _ints_to_arr(me.blind(data.ids))})
    else:
        salt = _arr_str(comm.recv("master", "match/salt").tensor("salt"))
        buf = b"".join(hashlib.sha256((salt + i).encode()).digest()
                       for i in data.ids)
        hashes = np.frombuffer(buf, np.uint8).reshape(len(data.ids), 32)
        comm.send("master", "match/hashes", {"h": hashes})
    order = [b.decode() for b in
             comm.recv("master", "match/order").tensor("ids")]
    return order


# big ints <-> uint8 matrices for transport through the tensor codec.
# (NOT numpy "S" dtypes: those strip trailing NUL bytes and corrupt
# binary data — only text ids may use them.)
def _ints_to_arr(vals: Sequence[int], width: int = 96) -> np.ndarray:
    buf = b"".join(v.to_bytes(width, "big") for v in vals)
    return np.frombuffer(buf, np.uint8).reshape(len(vals), width)


def _arr_to_ints(arr: np.ndarray) -> List[int]:
    return [int.from_bytes(bytes(bytearray(row)), "big") for row in arr]


def _str_arr(s: str) -> np.ndarray:
    return np.array([s.encode()], dtype="S128")


def _arr_str(a: np.ndarray) -> str:
    return bytes(a[0]).decode()


def batch_order(n: int, cfg: VFLConfig, epoch: int) -> np.ndarray:
    """Deterministic permutation every party derives identically."""
    rng = np.random.default_rng(cfg.seed * 1000 + epoch)
    return rng.permutation(n)


def batches(n: int, cfg: VFLConfig, epoch: int):
    perm = batch_order(n, cfg, epoch)
    bs = cfg.batch_size
    for i in range(0, n - bs + 1, bs):
        yield perm[i:i + bs]


PROTOCOLS: Dict[str, Dict[str, object]] = {}


def register(name: str, master, member, arbiter=None, needs_arbiter=False):
    PROTOCOLS[name] = {"master": master, "member": member,
                       "arbiter": arbiter, "needs_arbiter": needs_arbiter}
