"""Arbitered VFL logistic regression with Paillier HE (paper §2: the
Arbiter "performs the distribution of encryption keys and calculation of
the gradients concerning the master and members"), on the lifecycle API.

Flow per batch:
1. parties send partial logits to the master (plaintext — logits are
   aggregates, not raw data),
2. the master computes the residual r = sigma(z) - y, ENCRYPTS it with
   the arbiter's Paillier public key (blinding factors come from a
   precomputed randomness pool, so hot-path encryption is two mults),
   and broadcasts Enc(r) to members,
3. each member computes its encrypted gradient X_p^T Enc(r) using only
   homomorphic scalar-mult/add (it never sees r) — by default via the
   *packed* matvec: K gradient slots per ciphertext, one exponentiation
   per (sample, chunk) with shared Straus tables (DESIGN.md §3),
4. members send Enc(g_p) to the arbiter, who decrypts (CRT-accelerated)
   and returns g_p to the owning member only. Packing means the arbiter
   decrypts ~d/K ciphertexts instead of d.

So: members never see residuals (which leak label information), the
master never sees member gradients, and the arbiter never sees features.
Ciphertext wire widths are derived from the key size, carried in
metadata, and enforced by the message schema at decode (no hardcoded
widths — 2048-bit keys transport unharmed). The master additionally
publishes the fixed-point bound max|r_i| so members can size slots
tightly; that single magnitude is the only extra leakage (DESIGN.md
§3.6).

The decryption round pipelines end to end (DESIGN.md §10):

* ``cfg.he_stream_chunks > 1`` streams each Enc(g_p) as schema-framed
  chunks over ``isend``, so the arbiter starts decrypting chunk 0
  while later chunks are still on the wire;
* ``cfg.he_decrypt_workers > 0`` fans chunk decryption out over an
  arbiter-side process pool (``he.DecryptPool``) with order-preserving
  reassembly and attributed worker-crash propagation;
* at ``cfg.pipeline_depth >= 2`` the member *defers* the gradient
  apply one round: it sends Enc(g) for round t, applies round t-1's
  decrypted gradient, and only consumes round t's reply inside round
  t+1 — the arbiter's decrypt of round t overlaps the master's round
  t+1 logit gather and the member's next matvec instead of serializing
  the whole federation behind it;
* ``cfg.n_arbiters >= 2`` key-shards decryption: each arbiter holds
  its OWN keypair and decrypts a contiguous slice of every member's
  gradient columns, so no single key holder sees a full gradient
  (key-per-shard, not threshold cryptography — DESIGN.md §10.3).

All four knobs default off; the default wire format and depth-1 math
are bit-identical to the serial decrypt path (the recorded seed
traces).

Predict needs no HE at all: partial logits aggregate exactly as in
training, the master applies the sigmoid, and the arbiter sits the
phase out.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.comm import codec, schema
from repro.comm.schema import Field
from repro.core import he
from repro.core.protocols import base
from repro.core.protocols.driver import VFLProtocol

schema.message("he/pubkey",
               {"n": Field("uint8", 1, width_meta="n_bytes")},
               doc="arbiter's Paillier modulus, width self-declared")
schema.message("logreg/setup", {"items": Field("int64", 1)})
schema.message("logreg/z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial logits for the current batch")
schema.message("logreg/enc_resid",
               {"r": Field("uint8", 2, width_meta="width")}, stepped=True,
               doc="Enc(residual), one ciphertext row per sample "
                   "(one message per key shard at n_arbiters >= 2)")
schema.message("logreg/enc_grad",
               {"g": Field("uint8", 2, width_meta="width")}, stepped=True,
               doc="member's encrypted gradient (packed or scalar); "
                   "meta 'parts' marks a streamed chunk sequence")
schema.message("logreg/grad", {"g": Field("float64", 1)}, stepped=True,
               doc="decrypted gradient, returned to the owner only")
schema.message("logreg/pred_z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial logits for a predict query")


def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


@base.register
class LogRegHEProtocol(VFLProtocol):
    name = "logreg_he"
    needs_arbiter = True
    supports_pipeline = True

    def setup(self) -> None:
        cfg, ch = self.cfg, self.ch
        self.arbiters: List[str] = [w for w in ch.world
                                    if w.startswith("arbiter")]
        if self.is_arbiter:
            self.pub, self.priv = he.keygen(cfg.he_bits)
            n_arr = np.frombuffer(
                self.pub.n.to_bytes(self.pub.n_bytes, "big"), np.uint8)
            ch.broadcast("he/pubkey", {"n": n_arr},
                         targets=["master"] + ch.members,
                         meta={"n_bytes": str(self.pub.n_bytes)})
            self.decrypted = 0    # Paillier decryption ops (ciphertexts)
            self.values = 0       # gradient values recovered from them
            self.dpool = he.DecryptPool(self.priv,
                                        workers=cfg.he_decrypt_workers)
            return
        self.pubs = []
        for arb in self.arbiters:
            msg = ch.recv(arb, "he/pubkey")
            self.pubs.append(he.PublicKey(
                int.from_bytes(msg.tensor("n").tobytes(), "big")))
        self.pub = self.pubs[0]
        self.width = self.pub.cipher_bytes
        d = self.data
        if self.is_master:
            # prefetch scales with the announce window: at depth D the
            # master can be encrypting D rounds of residuals before the
            # background filler sees an idle gap — a fixed target would
            # drain and push blinding generation onto the hot path
            target = 2 * cfg.batch_size * max(1, int(cfg.pipeline_depth))
            self.pools = [he.RandomnessPool(p) for p in self.pubs]
            for pool in self.pools:
                pool.start(target=target)
            self.y = base._select(d.ids, self.order, d.y).astype(np.float64)
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64) \
                if d.x is not None else None
            self.items = self.y.shape[1]
            assert self.items == 1, "arbitered logreg: single binary target"
            ch.broadcast("logreg/setup", {"items": np.array([self.items], np.int64)},
                         targets=ch.members)
            self.w = np.zeros((self.x.shape[1], 1)) \
                if self.x is not None else None
        else:
            self.pools = [he.RandomnessPool(p) for p in self.pubs] \
                if cfg.he_packed else [None] * len(self.pubs)
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64)
            ch.recv("master", "logreg/setup")
            self.w = np.zeros((self.x.shape[1], 1))
            # contiguous column shards, one per arbiter key: arbiter s
            # only ever decrypts (and sees) columns self._shards[s]
            self._shards = np.array_split(np.arange(self.x.shape[1]),
                                          len(self.arbiters))
            self._pending = False     # deferred grad apply outstanding

    def on_batch_master(self, rows, step) -> float:
        cfg, ch = self.cfg, self.ch
        zb = np.zeros((len(rows), 1))
        if self.x is not None:
            zb += self.x[rows] @ self.w
        for msg in ch.gather(ch.members, "logreg/z"):
            zb += msg.tensor("z")
        p = _sigmoid(zb)
        r = (p - self.y[rows]) / len(rows)            # (B, 1)
        r_int = he.encode_fixed(r[:, 0])
        rb = str(max(1, int(np.abs(r_int).max())))
        sharded = len(self.pubs) > 1
        for s, (pub, pool) in enumerate(zip(self.pubs, self.pools)):
            enc_r = [pub.encrypt_int(int(v), rn=pool.take())
                     for v in r_int]
            meta = {"width": str(pub.cipher_bytes), "rb": rb}
            if sharded:
                meta["shard"] = str(s)
            # async broadcast: the heavy member-side homomorphic matvec
            # for this round overlaps the master's next-round logit
            # gather and encryption instead of serializing behind the
            # wire write
            ch.broadcast("logreg/enc_resid",
                         {"r": codec.ints_to_u8(enc_r,
                                                pub.cipher_bytes)},
                         targets=ch.members, wait=False, meta=meta)
        if self.x is not None:
            self.w -= cfg.lr * (self.x[rows].T @ r + cfg.l2 * self.w)
        eps = 1e-9
        yb = self.y[rows]
        return float(-np.mean(yb * np.log(p + eps)
                              + (1 - yb) * np.log(1 - p + eps)))

    def member_stage_send(self, rows, step):
        self.ch.isend("master", "logreg/z", {"z": self.x[rows] @ self.w})
        return None

    def member_stage_recv(self, rows, step, ctx) -> None:
        self._send_enc_grads(rows)
        if int(self.cfg.pipeline_depth) >= 2:
            # deferred apply: consume round t-1's decrypted gradient
            # AFTER round t's ciphertexts are on their way, so the
            # arbiter decrypt of round t overlaps the next matvec
            # instead of stalling this member. One extra round of
            # bounded staleness; flushed by on_window_drain.
            if self._pending:
                self._apply_grads()
            self._pending = True
        else:
            self._pending = True
            self._apply_grads()

    def on_window_drain(self) -> None:
        if self.is_member and getattr(self, "_pending", False):
            self._apply_grads()

    def _send_enc_grads(self, rows) -> None:
        """One member round: per key shard, recv Enc(r), compute the
        homomorphic matvec over this shard's columns, ship Enc(g)."""
        cfg, ch = self.cfg, self.ch
        for s, arb in enumerate(self.arbiters):
            pub = self.pubs[s]
            width = pub.cipher_bytes
            cols = self._shards[s] if len(self.arbiters) > 1 else None
            msg = ch.recv("master", "logreg/enc_resid")
            enc_r = codec.u8_to_ints(msg.tensor("r"))
            xb = self.x[rows] if cols is None else self.x[rows][:, cols]
            packed = None
            if cfg.he_packed:
                x_int = he.encode_fixed(xb).reshape(len(rows), -1)
                rb = int(msg.meta.get("rb", 1 << he.SCALE_BITS))
                try:
                    packed = he.packed_matvec(pub, x_int, enc_r, rb,
                                              pool=self.pools[s])
                except ValueError:
                    # slot wider than the key's plaintext (tiny he_bits
                    # / huge values): degrade to the scalar reference
                    packed = None
            if packed is not None:
                cts, info = packed
                meta = {"packed": "1", "width": str(width),
                        **{k: str(v) for k, v in info.items()}}
            else:
                cts = list(he.matvec_cipher(pub, xb,
                                            np.array(enc_r, dtype=object)))
                meta = {"width": str(width)}
            parts = min(max(1, int(cfg.he_stream_chunks)), len(cts))
            if parts <= 1:
                ch.send(arb, "logreg/enc_grad",
                        {"g": codec.ints_to_u8(cts, width)}, meta=meta)
                continue
            # streamed ciphertext round (DESIGN.md §10.2): the first
            # chunk carries the full packing meta plus the stream
            # length; isend lets chunk k+1 encode while chunk k is on
            # the wire, and the arbiter decrypts chunk 0 on arrival
            for i, piece in enumerate(np.array_split(np.arange(len(cts)),
                                                     parts)):
                chunk = [cts[j] for j in piece]
                m = dict(meta, parts=str(parts)) if i == 0 \
                    else {"width": str(width)}
                ch.isend(arb, "logreg/enc_grad",
                         {"g": codec.ints_to_u8(chunk, width)}, meta=m)

    def _apply_grads(self) -> None:
        cfg, ch = self.cfg, self.ch
        if len(self.arbiters) == 1:
            g = ch.recv("arbiter", "logreg/grad").tensor("g")
        else:
            g = np.empty(self.x.shape[1])
            for s, arb in enumerate(self.arbiters):
                g[self._shards[s]] = ch.recv(arb,
                                             "logreg/grad").tensor("g")
        self.w -= cfg.lr * (g[:, None] + cfg.l2 * self.w)
        self._pending = False

    def arbiter_round(self, step) -> None:
        # one decryption round: every member streams an encrypted
        # gradient (possibly chunked); chunks feed the decrypt pool as
        # they arrive and plaintexts reassemble in chunk order
        ch = self.ch
        for m in ch.members:
            sess = self.dpool.session()
            first = None
            n_cts = 0
            for i, part in enumerate(ch.recv_parts(m,
                                                   "logreg/enc_grad")):
                if first is None:
                    first = part
                cts = codec.u8_to_ints(part.tensor("g"))
                n_cts += len(cts)
                sess.submit(i, cts)
            plains = sess.gather()
            if first.meta.get("packed") == "1":
                flat = he.unpack_matvec(plains,
                                        int(first.meta["slot_bits"]),
                                        int(first.meta["k"]),
                                        int(first.meta["off_bits"]),
                                        int(first.meta["count"]))
            else:
                flat = plains
            g = he.decode_fixed(flat, (len(flat),),
                                scale_bits=2 * he.SCALE_BITS)
            if self.cfg.noise_sigma > 0:
                # noising defense (docs/privacy.md): the decrypted
                # gradient is the label-bearing exchange here — the
                # member reconstructs residual signs from it — so the
                # key holder perturbs it before returning ownership
                g = g + base.defense_noise(self.cfg, g, step,
                                           f"{self.role}/{m}")
            ch.send(m, "logreg/grad", {"g": g})
            self.decrypted += n_cts
            self.values += len(flat)

    # -- predict/serve (plaintext logit aggregation; arbiter idle) ----------
    def predict_master(self, rows) -> np.ndarray:
        z = np.zeros((len(rows), 1))
        if self.x is not None:
            z += self.x[rows] @ self.w
        for msg in self.ch.gather(self.ch.members, "logreg/pred_z"):
            z += msg.tensor("z")
        return _sigmoid(z)

    def predict_member(self, rows) -> None:
        self.send_embed(self.predict_embed(rows), rows)

    def predict_embed(self, rows) -> np.ndarray:
        # the member "embedding" is its partial logit slice — row-wise
        # dot products, safely cacheable per row id
        return self.x[rows] @ self.w

    def send_embed(self, z, rows) -> None:
        self.ch.send("master", "logreg/pred_z", {"z": np.asarray(z)})

    def evaluate_master(self, scores, rows) -> Dict[str, float]:
        from repro.train.evals import auc
        y = self.y[rows]
        eps = 1e-9
        logloss = float(-np.mean(y * np.log(scores + eps)
                                 + (1 - y) * np.log(1 - scores + eps)))
        return {"auc": auc(scores, y), "logloss": logloss}

    def finalize(self) -> Dict:
        if self.is_arbiter:
            return {"decrypted_values": self.decrypted,
                    "recovered_values": self.values,
                    "decrypt_pool": self.dpool.stats()}
        pools = [p for p in getattr(self, "pools", []) if p is not None]
        rand = {"hits": sum(p.hits for p in pools),
                "fallbacks": sum(p.fallbacks for p in pools),
                "generated": sum(p._generated for p in pools)}
        if self.is_master:
            return {"w_master": self.w, "rand_pool": rand}
        return {"w": self.w, "rand_pool": rand}

    def close(self) -> None:
        for pool in getattr(self, "pools", []):
            if pool is not None:
                pool.stop()
        dpool = getattr(self, "dpool", None)
        if dpool is not None:
            dpool.close()

    def state_dict(self) -> Dict:
        if self.is_arbiter:
            return {"decrypted": self.decrypted, "values": self.values}
        return {"w": None if self.w is None else self.w.copy()}

    def load_state_dict(self, state) -> None:
        if self.is_arbiter:
            self.decrypted = state["decrypted"]
            self.values = state["values"]
        else:
            self.w = None if state["w"] is None else state["w"].copy()
