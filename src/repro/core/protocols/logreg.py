"""Arbitered VFL logistic regression with Paillier HE (paper §2: the
Arbiter "performs the distribution of encryption keys and calculation of
the gradients concerning the master and members"), on the lifecycle API.

Flow per batch:
1. parties send partial logits to the master (plaintext — logits are
   aggregates, not raw data),
2. the master computes the residual r = sigma(z) - y, ENCRYPTS it with
   the arbiter's Paillier public key (blinding factors come from a
   precomputed randomness pool, so hot-path encryption is two mults),
   and broadcasts Enc(r) to members,
3. each member computes its encrypted gradient X_p^T Enc(r) using only
   homomorphic scalar-mult/add (it never sees r) — by default via the
   *packed* matvec: K gradient slots per ciphertext, one exponentiation
   per (sample, chunk) with shared Straus tables (DESIGN.md §3),
4. members send Enc(g_p) to the arbiter, who decrypts (CRT-accelerated)
   and returns g_p to the owning member only. Packing means the arbiter
   decrypts ~d/K ciphertexts instead of d.

So: members never see residuals (which leak label information), the
master never sees member gradients, and the arbiter never sees features.
Ciphertext wire widths are derived from the key size, carried in
metadata, and enforced by the message schema at decode (no hardcoded
widths — 2048-bit keys transport unharmed). The master additionally
publishes the fixed-point bound max|r_i| so members can size slots
tightly; that single magnitude is the only extra leakage (DESIGN.md
§3.6).

Predict needs no HE at all: partial logits aggregate exactly as in
training, the master applies the sigmoid, and the arbiter sits the
phase out.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.comm import codec, schema
from repro.comm.schema import Field
from repro.core import he
from repro.core.protocols import base
from repro.core.protocols.driver import VFLProtocol

schema.message("he/pubkey",
               {"n": Field("uint8", 1, width_meta="n_bytes")},
               doc="arbiter's Paillier modulus, width self-declared")
schema.message("logreg/setup", {"items": Field("int64", 1)})
schema.message("logreg/z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial logits for the current batch")
schema.message("logreg/enc_resid",
               {"r": Field("uint8", 2, width_meta="width")}, stepped=True,
               doc="Enc(residual), one ciphertext row per sample")
schema.message("logreg/enc_grad",
               {"g": Field("uint8", 2, width_meta="width")}, stepped=True,
               doc="member's encrypted gradient (packed or scalar)")
schema.message("logreg/grad", {"g": Field("float64", 1)}, stepped=True,
               doc="decrypted gradient, returned to the owner only")
schema.message("logreg/pred_z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial logits for a predict query")


def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


@base.register
class LogRegHEProtocol(VFLProtocol):
    name = "logreg_he"
    needs_arbiter = True
    supports_pipeline = True

    def setup(self) -> None:
        cfg, ch = self.cfg, self.ch
        if self.is_arbiter:
            self.pub, self.priv = he.keygen(cfg.he_bits)
            n_arr = np.frombuffer(
                self.pub.n.to_bytes(self.pub.n_bytes, "big"), np.uint8)
            ch.broadcast("he/pubkey", {"n": n_arr},
                         meta={"n_bytes": str(self.pub.n_bytes)})
            self.decrypted = 0    # Paillier decryption ops (ciphertexts)
            self.values = 0       # gradient values recovered from them
            return
        msg = ch.recv("arbiter", "he/pubkey")
        self.pub = he.PublicKey(
            int.from_bytes(msg.tensor("n").tobytes(), "big"))
        self.width = self.pub.cipher_bytes
        d = self.data
        if self.is_master:
            self.pool = he.RandomnessPool(self.pub)
            self.pool.start(target=2 * cfg.batch_size)
            self.y = base._select(d.ids, self.order, d.y).astype(np.float64)
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64) \
                if d.x is not None else None
            self.items = self.y.shape[1]
            assert self.items == 1, "arbitered logreg: single binary target"
            ch.broadcast("logreg/setup", {"items": np.array([self.items], np.int64)},
                         targets=ch.members)
            self.w = np.zeros((self.x.shape[1], 1)) \
                if self.x is not None else None
        else:
            self.pool = he.RandomnessPool(self.pub) if cfg.he_packed \
                else None
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64)
            ch.recv("master", "logreg/setup")
            self.w = np.zeros((self.x.shape[1], 1))

    def on_batch_master(self, rows, step) -> float:
        cfg, ch = self.cfg, self.ch
        zb = np.zeros((len(rows), 1))
        if self.x is not None:
            zb += self.x[rows] @ self.w
        for msg in ch.gather(ch.members, "logreg/z"):
            zb += msg.tensor("z")
        p = _sigmoid(zb)
        r = (p - self.y[rows]) / len(rows)            # (B, 1)
        r_int = he.encode_fixed(r[:, 0])
        enc_r = [self.pub.encrypt_int(int(v), rn=self.pool.take())
                 for v in r_int]
        # async broadcast: the heavy member-side homomorphic matvec for
        # this round overlaps the master's next-round logit gather and
        # encryption instead of serializing behind the wire write
        ch.broadcast("logreg/enc_resid",
                     {"r": codec.ints_to_u8(enc_r, self.width)},
                     targets=ch.members, wait=False,
                     meta={"width": str(self.width),
                           "rb": str(max(1, int(np.abs(r_int).max())))})
        if self.x is not None:
            self.w -= cfg.lr * (self.x[rows].T @ r + cfg.l2 * self.w)
        eps = 1e-9
        yb = self.y[rows]
        return float(-np.mean(yb * np.log(p + eps)
                              + (1 - yb) * np.log(1 - p + eps)))

    def member_stage_send(self, rows, step):
        self.ch.isend("master", "logreg/z", {"z": self.x[rows] @ self.w})
        return None

    def member_stage_recv(self, rows, step, ctx) -> None:
        cfg, ch = self.cfg, self.ch
        msg = ch.recv("master", "logreg/enc_resid")
        enc_r = codec.u8_to_ints(msg.tensor("r"))
        packed = None
        if cfg.he_packed:
            x_int = he.encode_fixed(self.x[rows]).reshape(len(rows), -1)
            rb = int(msg.meta.get("rb", 1 << he.SCALE_BITS))
            try:
                packed = he.packed_matvec(self.pub, x_int, enc_r, rb,
                                          pool=self.pool)
            except ValueError:
                # slot wider than the key's plaintext (tiny he_bits /
                # huge values): degrade to the scalar reference path
                packed = None
        if packed is not None:
            cts, info = packed
            ch.send("arbiter", "logreg/enc_grad",
                    {"g": codec.ints_to_u8(cts, self.width)},
                    meta={"packed": "1", "width": str(self.width),
                          **{k: str(v) for k, v in info.items()}})
        else:
            enc_g = he.matvec_cipher(self.pub, self.x[rows],
                                     np.array(enc_r, dtype=object))
            ch.send("arbiter", "logreg/enc_grad",
                    {"g": codec.ints_to_u8(enc_g, self.width)},
                    meta={"width": str(self.width)})
        g = ch.recv("arbiter", "logreg/grad").tensor("g")
        self.w -= cfg.lr * (g[:, None] + cfg.l2 * self.w)

    def arbiter_round(self, step) -> None:
        # one decryption round: every member sends an encrypted gradient
        ch = self.ch
        for m in ch.members:
            enc = ch.recv(m, "logreg/enc_grad")
            cts = codec.u8_to_ints(enc.tensor("g"))
            if enc.meta.get("packed") == "1":
                plains = [self.priv.decrypt_int(c) for c in cts]
                flat = he.unpack_matvec(plains,
                                        int(enc.meta["slot_bits"]),
                                        int(enc.meta["k"]),
                                        int(enc.meta["off_bits"]),
                                        int(enc.meta["count"]))
            else:
                flat = [self.priv.decrypt_int(c) for c in cts]
            g = he.decode_fixed(flat, (len(flat),),
                                scale_bits=2 * he.SCALE_BITS)
            ch.send(m, "logreg/grad", {"g": g})
            self.decrypted += len(cts)
            self.values += len(flat)

    # -- predict/serve (plaintext logit aggregation; arbiter idle) ----------
    def predict_master(self, rows) -> np.ndarray:
        z = np.zeros((len(rows), 1))
        if self.x is not None:
            z += self.x[rows] @ self.w
        for msg in self.ch.gather(self.ch.members, "logreg/pred_z"):
            z += msg.tensor("z")
        return _sigmoid(z)

    def predict_member(self, rows) -> None:
        self.send_embed(self.predict_embed(rows), rows)

    def predict_embed(self, rows) -> np.ndarray:
        # the member "embedding" is its partial logit slice — row-wise
        # dot products, safely cacheable per row id
        return self.x[rows] @ self.w

    def send_embed(self, z, rows) -> None:
        self.ch.send("master", "logreg/pred_z", {"z": np.asarray(z)})

    def evaluate_master(self, scores, rows) -> Dict[str, float]:
        from repro.train.evals import auc
        y = self.y[rows]
        eps = 1e-9
        logloss = float(-np.mean(y * np.log(scores + eps)
                                 + (1 - y) * np.log(1 - scores + eps)))
        return {"auc": auc(scores, y), "logloss": logloss}

    def finalize(self) -> Dict:
        if self.is_arbiter:
            return {"decrypted_values": self.decrypted,
                    "recovered_values": self.values}
        if self.is_master:
            return {"w_master": self.w}
        return {"w": self.w}

    def close(self) -> None:
        pool = getattr(self, "pool", None)
        if pool is not None:
            pool.stop()

    def state_dict(self) -> Dict:
        if self.is_arbiter:
            return {"decrypted": self.decrypted, "values": self.values}
        return {"w": None if self.w is None else self.w.copy()}

    def load_state_dict(self, state) -> None:
        if self.is_arbiter:
            self.decrypted = state["decrypted"]
            self.values = state["values"]
        else:
            self.w = None if state["w"] is None else state["w"].copy()
