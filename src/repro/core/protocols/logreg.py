"""Arbitered VFL logistic regression with Paillier HE (paper §2: the
Arbiter "performs the distribution of encryption keys and calculation of
the gradients concerning the master and members").

Flow per batch:
1. parties send partial logits to the master (plaintext — logits are
   aggregates, not raw data),
2. the master computes the residual r = sigma(z) - y, ENCRYPTS it with
   the arbiter's Paillier public key (blinding factors come from a
   precomputed randomness pool, so hot-path encryption is two mults),
   and broadcasts Enc(r) to members,
3. each member computes its encrypted gradient X_p^T Enc(r) using only
   homomorphic scalar-mult/add (it never sees r) — by default via the
   *packed* matvec: K gradient slots per ciphertext, one exponentiation
   per (sample, chunk) with shared Straus tables (DESIGN.md §3),
4. members send Enc(g_p) to the arbiter, who decrypts (CRT-accelerated)
   and returns g_p to the owning member only. Packing means the arbiter
   decrypts ~d/K ciphertexts instead of d.

So: members never see residuals (which leak label information), the
master never sees member gradients, and the arbiter never sees features.
Ciphertexts ride as uint8 rows whose width is derived from the key size
and carried in message metadata (no hardcoded wire widths — 2048-bit
keys transport unharmed). The master additionally publishes the
fixed-point bound max|r_i| so members can size slots tightly; that
single magnitude is the only extra leakage (DESIGN.md §3.6).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.comm import codec
from repro.comm.base import PartyCommunicator
from repro.core import he
from repro.core.protocols import base
from repro.core.protocols.base import (MasterData, MemberData, VFLConfig,
                                       batches, master_match, member_match,
                                       register)


def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


def _check_width(msg, name: str, width: int) -> None:
    """Cross-check the metadata-declared big-int width against the
    tensor's trailing dim — catches peers framing ciphertexts with a
    different key size before they decode to garbage."""
    if width and msg.tensor(name).shape[-1] != width:
        raise ValueError(
            f"{msg.tag}: ciphertext width {msg.tensor(name).shape[-1]} "
            f"!= declared {width} (key-size mismatch between parties?)")


def _recv_pubkey(comm: PartyCommunicator) -> he.PublicKey:
    msg = comm.recv("arbiter", "he/pubkey")
    _check_width(msg, "n", int(msg.meta.get("n_bytes", 0)))
    return he.PublicKey(int.from_bytes(msg.tensor("n").tobytes(), "big"))


def arbiter_fn(comm: PartyCommunicator, _data, cfg: VFLConfig) -> Dict:
    pub, priv = he.keygen(cfg.he_bits)
    n_arr = np.frombuffer(pub.n.to_bytes(pub.n_bytes, "big"), np.uint8)
    comm.broadcast("he/pubkey", {"n": n_arr},
                   meta={"n_bytes": str(pub.n_bytes)})
    decrypted = 0           # Paillier decryption ops (ciphertexts)
    values = 0              # gradient values recovered from them
    while True:
        msg = comm.recv("master", "arbiter/ctrl")
        if int(msg.tensor("op")[0]) == 0:       # shutdown
            break
        # one decryption round: every member sends an encrypted gradient
        for m in comm.members:
            enc = comm.recv(m, "logreg/enc_grad")
            _check_width(enc, "g", int(enc.meta.get("width", 0)))
            cts = codec.u8_to_ints(enc.tensor("g"))
            if enc.meta.get("packed") == "1":
                plains = [priv.decrypt_int(c) for c in cts]
                flat = he.unpack_matvec(plains,
                                        int(enc.meta["slot_bits"]),
                                        int(enc.meta["k"]),
                                        int(enc.meta["off_bits"]),
                                        int(enc.meta["count"]))
            else:
                flat = [priv.decrypt_int(c) for c in cts]
            g = he.decode_fixed(flat, (len(flat),),
                                scale_bits=2 * he.SCALE_BITS)
            comm.send(m, "logreg/grad", {"g": g})
            decrypted += len(cts)
            values += len(flat)
    return {"decrypted_values": decrypted, "recovered_values": values,
            "comm": comm.stats.as_dict()}


def master_fn(comm: PartyCommunicator, data: MasterData,
              cfg: VFLConfig) -> Dict:
    pub = _recv_pubkey(comm)
    pool = he.RandomnessPool(pub)
    try:
        pool.start(target=2 * cfg.batch_size)
        order = master_match(comm, data, cfg)
        y = base._select(data.ids, order, data.y).astype(np.float64)
        x = base._select(data.ids, order, data.x).astype(np.float64) \
            if data.x is not None else None
        n, items = y.shape
        assert items == 1, "arbitered logreg: single binary target"
        comm.broadcast("logreg/setup", {"items": np.array([items])},
                       targets=comm.members)
        w = np.zeros((x.shape[1], 1)) if x is not None else None
        history: List[Dict] = []
        step = 0
        width = pub.cipher_bytes
        for epoch in range(cfg.epochs):
            for rows in batches(n, cfg, epoch):
                zb = np.zeros((len(rows), 1))
                if x is not None:
                    zb += x[rows] @ w
                for msg in comm.gather(comm.members, f"logreg/z/{step}"):
                    zb += msg.tensor("z")
                p = _sigmoid(zb)
                r = (p - y[rows]) / len(rows)            # (B, 1)
                r_int = he.encode_fixed(r[:, 0])
                enc_r = [pub.encrypt_int(int(v), rn=pool.take())
                         for v in r_int]
                comm.send("arbiter", "arbiter/ctrl", {"op": np.array([1])})
                comm.broadcast(
                    f"logreg/enc_resid/{step}",
                    {"r": codec.ints_to_u8(enc_r, width)},
                    targets=comm.members,
                    meta={"width": str(width),
                          "rb": str(max(1, int(np.abs(r_int).max())))})
                if x is not None:
                    w -= cfg.lr * (x[rows].T @ r + cfg.l2 * w)
                eps = 1e-9
                loss = float(-np.mean(y[rows] * np.log(p + eps)
                                      + (1 - y[rows]) * np.log(1 - p + eps)))
                if step % cfg.record_every == 0:
                    history.append({"step": step, "epoch": epoch,
                                    "loss": loss})
                step += 1
        comm.send("arbiter", "arbiter/ctrl", {"op": np.array([0])})
        comm.broadcast("logreg/done", {"ok": np.array([1])},
                       targets=comm.members)
    finally:
        pool.stop()
    return {"history": history, "w_master": w, "n_common": n,
            "comm": comm.stats.as_dict()}


def member_fn(comm: PartyCommunicator, data: MemberData,
              cfg: VFLConfig) -> Dict:
    pub = _recv_pubkey(comm)
    pool = he.RandomnessPool(pub) if cfg.he_packed else None
    order = member_match(comm, data, cfg)
    x = base._select(data.ids, order, data.x).astype(np.float64)
    n = len(order)
    comm.recv("master", "logreg/setup")
    w = np.zeros((x.shape[1], 1))
    width = pub.cipher_bytes
    step = 0
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            comm.send("master", f"logreg/z/{step}", {"z": x[rows] @ w})
            msg = comm.recv("master", f"logreg/enc_resid/{step}")
            _check_width(msg, "r", int(msg.meta.get("width", 0)))
            enc_r = codec.u8_to_ints(msg.tensor("r"))
            packed = None
            if cfg.he_packed:
                x_int = he.encode_fixed(x[rows]).reshape(len(rows), -1)
                rb = int(msg.meta.get("rb", 1 << he.SCALE_BITS))
                try:
                    packed = he.packed_matvec(pub, x_int, enc_r, rb,
                                              pool=pool)
                except ValueError:
                    # slot wider than the key's plaintext (tiny he_bits /
                    # huge values): degrade to the scalar reference path
                    packed = None
            if packed is not None:
                cts, info = packed
                comm.send("arbiter", "logreg/enc_grad",
                          {"g": codec.ints_to_u8(cts, width)},
                          meta={"packed": "1", "width": str(width),
                                **{k: str(v) for k, v in info.items()}})
            else:
                enc_g = he.matvec_cipher(pub, x[rows],
                                         np.array(enc_r, dtype=object))
                comm.send("arbiter", "logreg/enc_grad",
                          {"g": codec.ints_to_u8(enc_g, width)},
                          meta={"width": str(width)})
            g = comm.recv("arbiter", "logreg/grad").tensor("g")
            w -= cfg.lr * (g[:, None] + cfg.l2 * w)
            step += 1
    comm.recv("master", "logreg/done")
    return {"w": w, "comm": comm.stats.as_dict()}


register("logreg_he", master_fn, member_fn, arbiter_fn, needs_arbiter=True)
