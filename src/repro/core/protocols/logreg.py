"""Arbitered VFL logistic regression with Paillier HE (paper §2: the
Arbiter "performs the distribution of encryption keys and calculation of
the gradients concerning the master and members").

Flow per batch:
1. parties send partial logits to the master (plaintext — logits are
   aggregates, not raw data),
2. the master computes the residual r = sigma(z) - y, ENCRYPTS it with
   the arbiter's Paillier public key, and broadcasts Enc(r) to members,
3. each member computes its encrypted gradient X_p^T Enc(r) using only
   homomorphic scalar-mult/add (it never sees r),
4. members send Enc(g_p) to the arbiter, who decrypts and returns g_p to
   the owning member only.

So: members never see residuals (which leak label information), the
master never sees member gradients, and the arbiter never sees features.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.comm.base import PartyCommunicator
from repro.core import he
from repro.core.protocols import base
from repro.core.protocols.base import (MasterData, MemberData, VFLConfig,
                                       batches, master_match, member_match,
                                       register)


def _sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


def _cipher_to_arr(c: np.ndarray) -> np.ndarray:
    """Ciphertexts ride as uint8 (n, 256) — S-dtypes strip NUL bytes."""
    flat = [int(v) for v in np.ravel(c)]
    buf = b"".join(v.to_bytes(256, "big") for v in flat)
    return np.frombuffer(buf, np.uint8).reshape(c.shape + (256,))


def _arr_to_cipher(a: np.ndarray) -> np.ndarray:
    shape = a.shape[:-1]
    flat = a.reshape(-1, a.shape[-1])
    vals = [int.from_bytes(bytes(bytearray(row)), "big") for row in flat]
    return np.array(vals, dtype=object).reshape(shape)


def arbiter_fn(comm: PartyCommunicator, _data, cfg: VFLConfig) -> Dict:
    pub, priv = he.keygen(cfg.he_bits)
    n_arr = np.frombuffer(pub.n.to_bytes(256, "big"), np.uint8)
    comm.broadcast("he/pubkey", {"n": n_arr})
    decrypted = 0
    while True:
        msg = comm.recv("master", "arbiter/ctrl")
        if int(msg.tensor("op")[0]) == 0:       # shutdown
            break
        # one decryption round: every member sends an encrypted gradient
        for m in comm.members:
            enc = comm.recv(m, "logreg/enc_grad")
            cipher = _arr_to_cipher(enc.tensor("g"))
            flat = [priv.decrypt_int(int(v)) for v in np.ravel(cipher)]
            g = he.decode_fixed(flat, cipher.shape,
                                scale_bits=2 * he.SCALE_BITS)
            comm.send(m, "logreg/grad", {"g": g})
            decrypted += cipher.size
    return {"decrypted_values": decrypted, "comm": comm.stats.as_dict()}


def master_fn(comm: PartyCommunicator, data: MasterData,
              cfg: VFLConfig) -> Dict:
    pub = he.PublicKey(int.from_bytes(
        bytes(bytearray(comm.recv("arbiter", "he/pubkey").tensor("n"))),
        "big"))
    order = master_match(comm, data, cfg)
    y = base._select(data.ids, order, data.y).astype(np.float64)
    x = base._select(data.ids, order, data.x).astype(np.float64) \
        if data.x is not None else None
    n, items = y.shape
    assert items == 1, "arbitered logreg: single binary target"
    comm.broadcast("logreg/setup", {"items": np.array([items])},
                   targets=comm.members)
    w = np.zeros((x.shape[1], 1)) if x is not None else None
    history: List[Dict] = []
    step = 0
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            zb = np.zeros((len(rows), 1))
            if x is not None:
                zb += x[rows] @ w
            for msg in comm.gather(comm.members, f"logreg/z/{step}"):
                zb += msg.tensor("z")
            p = _sigmoid(zb)
            r = (p - y[rows]) / len(rows)            # (B, 1)
            enc_r = he.encrypt_vector(pub, r[:, 0])
            comm.send("arbiter", "arbiter/ctrl", {"op": np.array([1])})
            comm.broadcast(f"logreg/enc_resid/{step}",
                           {"r": _cipher_to_arr(enc_r)},
                           targets=comm.members)
            if x is not None:
                w -= cfg.lr * (x[rows].T @ r + cfg.l2 * w)
            eps = 1e-9
            loss = float(-np.mean(y[rows] * np.log(p + eps)
                                  + (1 - y[rows]) * np.log(1 - p + eps)))
            if step % cfg.record_every == 0:
                history.append({"step": step, "epoch": epoch, "loss": loss})
            step += 1
    comm.send("arbiter", "arbiter/ctrl", {"op": np.array([0])})
    comm.broadcast("logreg/done", {"ok": np.array([1])},
                   targets=comm.members)
    return {"history": history, "w_master": w, "n_common": n,
            "comm": comm.stats.as_dict()}


def member_fn(comm: PartyCommunicator, data: MemberData,
              cfg: VFLConfig) -> Dict:
    pub = he.PublicKey(int.from_bytes(
        bytes(bytearray(comm.recv("arbiter", "he/pubkey").tensor("n"))),
        "big"))
    order = member_match(comm, data, cfg)
    x = base._select(data.ids, order, data.x).astype(np.float64)
    n = len(order)
    comm.recv("master", "logreg/setup")
    w = np.zeros((x.shape[1], 1))
    step = 0
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            comm.send("master", f"logreg/z/{step}", {"z": x[rows] @ w})
            enc_r = _arr_to_cipher(
                comm.recv("master", f"logreg/enc_resid/{step}").tensor("r"))
            enc_g = he.matvec_cipher(pub, x[rows], enc_r)     # (d,) cipher
            comm.send("arbiter", "logreg/enc_grad",
                      {"g": _cipher_to_arr(enc_g)})
            g = comm.recv("arbiter", "logreg/grad").tensor("g")
            w -= cfg.lr * (g[:, None] + cfg.l2 * w)
            step += 1
    comm.recv("master", "logreg/done")
    return {"w": w, "comm": comm.stats.as_dict()}


register("logreg_he", master_fn, member_fn, arbiter_fn, needs_arbiter=True)
