"""Secure-aggregation split learning as a first-class protocol.

``core/secure_agg.py`` ships the Bonawitz-style pairwise-mask
primitives and ``core/secure_agg_protocol.py`` the over-the-wire
``PairwiseMasker``; until now they only ran as an opt-in flag
(``VFLConfig.secure_agg=True``) on the split-NN protocol. Registering
them as their own protocol name makes the privacy posture a spec-level
choice — ``protocol = "secure_agg"`` in a cluster TOML, or
``VFLConfig(protocol="secure_agg")`` under ``VFLJob``/``run_vfl`` —
with no extra flag to forget.

Semantics are exactly split-NN with masking forced on: members agree on
pairwise DH seeds over the communicator and add cancelling PRG masks to
their embeddings, so the master only ever sees the aggregate sum. The
training math is untouched (masks cancel exactly in fp32), hence the
protocol converges bit-for-bit with plain ``split_nn`` at depth 1 —
a tested claim (tests/test_vfl_protocols.py).
"""
from __future__ import annotations

from repro.core.protocols import base
from repro.core.protocols.split_nn import SplitNNProtocol


@base.register
class SecureAggProtocol(SplitNNProtocol):
    """Split-NN with pairwise-mask secure aggregation always on.

    Example::

        cfg = VFLConfig(protocol="secure_agg", epochs=3)
        res = run_vfl(cfg, master, members, mode="thread")
    """

    name = "secure_agg"

    def setup(self) -> None:
        if self.cfg.compress:
            raise ValueError(
                "secure_agg masks do not survive independent "
                "quantization; disable cfg.compress")
        super().setup()
        if self.is_member and self.masker is None:
            # cfg.secure_agg was off: force the masker on — the whole
            # point of choosing this protocol name
            from repro.core.secure_agg_protocol import PairwiseMasker
            self.masker = PairwiseMasker(self.ch.comm, self.role,
                                         self.ch.members)
