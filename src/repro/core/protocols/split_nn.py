"""Split-learning VFL protocol (paper §2: "neural networks-based
algorithms enabled with a split-learning approach"), on the lifecycle
API.

Members own bottom MLPs over their feature slices; the master owns the
top model and labels. Per batch:

1. members send bottom activations u_p = f_p(X_p),
2. master sums aggregated embedding u = u_master + sum_p u_p, runs the
   top model, computes the multi-label BCE loss,
3. master backprops and returns du_p to each member (the only gradient
   signal that crosses the boundary),
4. members apply their bottom VJP locally.

Predict is the forward half federated end-to-end: members answer
feature-slice queries with bottom activations, the master composes the
top model — nobody ever holds another silo's features or parameters.

Everything is jax (jit'd per party), so the same protocol code is also
what the mesh-mode VFL step shards over pods (core/vfl_step.py).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import schema
from repro.comm.schema import Field
from repro.core.protocols import base
from repro.core.protocols.driver import VFLProtocol

# activations/gradients are free-form (fields flip between {u|du} and
# {q, scale} when int8 exchange compression is on), so only the tag
# sequencing is schema-managed for these two.
schema.message("splitnn/u", None, stepped=True,
               doc="member bottom activations (raw f32 or int8+scale)")
schema.message("splitnn/du", None, stepped=True,
               doc="embedding gradient returned to one member")
schema.message("splitnn/pred_u", {"u": Field("float32", 2)}, stepped=True,
               doc="bottom activations for a predict query")


def mlp_init(key, dims: Tuple[int, ...]) -> List[Dict[str, jax.Array]]:
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a),
            "b": jnp.zeros((b,), jnp.float32),
        })
    return layers


def mlp_apply(params, x, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _bce(logits, y):
    return jnp.mean(jnp.clip(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


@functools.partial(jax.jit, static_argnames=())
def _master_fwd_bwd(top_params, bottom_params, u_members, x_m, y, lr):
    """Returns (loss, new_top, new_bottom, du_members)."""
    def fwd(top, bottom, u_ms):
        u = mlp_apply(bottom, x_m, final_act=True)
        for um in u_ms:
            u = u + um
        logits = mlp_apply(top, u)
        return _bce(logits, y)

    loss, grads = jax.value_and_grad(fwd, argnums=(0, 1, 2))(
        top_params, bottom_params, u_members)
    g_top, g_bottom, g_u = grads
    new_top = jax.tree.map(lambda p, g: p - lr * g, top_params, g_top)
    new_bottom = jax.tree.map(lambda p, g: p - lr * g, bottom_params,
                              g_bottom)
    return loss, new_top, new_bottom, g_u


@jax.jit
def _member_fwd(params, x):
    return mlp_apply(params, x, final_act=True)


@jax.jit
def _member_bwd(params, x, du, lr):
    _, vjp = jax.vjp(lambda p: mlp_apply(p, x, final_act=True), params)
    (g,) = vjp(du)
    return jax.tree.map(lambda p, gg: p - lr * gg, params, g)


@base.register
class SplitNNProtocol(VFLProtocol):
    name = "split_nn"

    def setup(self) -> None:
        from repro.core import compression
        cfg, d = self.cfg, self.data
        self.ef = compression.ErrorFeedback()
        self.lr = jnp.float32(cfg.lr)
        key = jax.random.key(cfg.seed)
        if self.is_master:
            self.y = jnp.asarray(
                base._select(d.ids, self.order, d.y), jnp.float32)
            self.x = jnp.asarray(
                base._select(d.ids, self.order, d.x), jnp.float32)
            e = cfg.embedding_dim
            items = self.y.shape[1]
            self.bottom = mlp_init(jax.random.fold_in(key, 0),
                                   (self.x.shape[1],) + cfg.hidden + (e,))
            self.top = mlp_init(jax.random.fold_in(key, 1),
                                (e,) + cfg.hidden + (items,))
        else:
            self.x = jnp.asarray(
                base._select(d.ids, self.order, d.x), jnp.float32)
            # member index determines its init stream (from its id)
            midx = int(self.role.replace("member", "")) + 2
            self.params = mlp_init(
                jax.random.fold_in(key, midx),
                (self.x.shape[1],) + cfg.hidden + (cfg.embedding_dim,))
            self.masker = None
            # mask-stream namespace for predict queries: every member
            # sees the same EVAL round sequence, so a shared counter
            # keeps pairwise masks aligned without colliding with
            # training-step masks
            self._pred_step = 1 << 20
            if cfg.secure_agg:
                if cfg.compress:
                    raise ValueError("secure_agg masks do not survive "
                                     "independent quantization; choose one")
                from repro.core.secure_agg_protocol import PairwiseMasker
                self.masker = PairwiseMasker(self.ch.comm, self.role,
                                             self.ch.members)

    def on_batch_master(self, rows, step) -> float:
        from repro.core import compression
        cfg, ch = self.cfg, self.ch
        msgs = ch.gather(ch.members, "splitnn/u")
        if cfg.compress:
            u_members = tuple(
                jnp.asarray(compression.unpack(m.payload), jnp.float32)
                for m in msgs)
        else:
            u_members = tuple(jnp.asarray(m.tensor("u"), jnp.float32)
                              for m in msgs)
        loss, self.top, self.bottom, g_u = _master_fwd_bwd(
            self.top, self.bottom, u_members, self.x[rows], self.y[rows],
            self.lr)
        for mname, du in zip(ch.members, g_u):
            if cfg.compress:
                q, scale = self.ef.compress(mname, np.asarray(du))
                ch.send(mname, "splitnn/du", compression.payload(q, scale))
            else:
                ch.send(mname, "splitnn/du", {"du": np.asarray(du)})
        return float(loss)

    def on_batch_member(self, rows, step) -> None:
        from repro.core import compression
        cfg, ch = self.cfg, self.ch
        xb = self.x[rows]
        u = _member_fwd(self.params, xb)
        if self.masker is not None:
            u = jnp.asarray(np.asarray(u)
                            + self.masker.mask(step, np.asarray(u).shape))
        if cfg.compress:
            q, scale = self.ef.compress("u", np.asarray(u))
            ch.send("master", "splitnn/u", compression.payload(q, scale))
            du = jnp.asarray(compression.unpack(
                ch.recv("master", "splitnn/du").payload), jnp.float32)
        else:
            ch.send("master", "splitnn/u", {"u": np.asarray(u)})
            du = jnp.asarray(
                ch.recv("master", "splitnn/du").tensor("du"), jnp.float32)
        self.params = _member_bwd(self.params, xb, du, self.lr)

    # -- predict/serve -------------------------------------------------------
    def predict_master(self, rows) -> np.ndarray:
        u = _member_fwd(self.bottom, self.x[rows])
        for msg in self.ch.gather(self.ch.members, "splitnn/pred_u"):
            u = u + jnp.asarray(msg.tensor("u"), jnp.float32)
        return np.asarray(mlp_apply(self.top, u))

    def predict_member(self, rows) -> None:
        u = np.asarray(_member_fwd(self.params, self.x[rows]))
        if self.masker is not None:
            # predict queries get the same pairwise masking as training
            # rounds — the master only ever sees the aggregate
            u = np.asarray(u + self.masker.mask(self._pred_step, u.shape),
                           np.float32)
            self._pred_step += 1
        self.ch.send("master", "splitnn/pred_u", {"u": u})

    def evaluate_master(self, scores, rows) -> Dict[str, float]:
        from repro.train.evals import recsys_report
        return recsys_report(np.asarray(scores),
                             np.asarray(self.y[rows]), k=5)

    def finalize(self) -> Dict:
        if self.is_master:
            return {"top": jax.tree.map(np.asarray, self.top),
                    "bottom": jax.tree.map(np.asarray, self.bottom),
                    "order": self.order}
        return {"params": jax.tree.map(np.asarray, self.params)}

    def state_dict(self) -> Dict:
        if self.is_master:
            return {"top": jax.tree.map(np.asarray, self.top),
                    "bottom": jax.tree.map(np.asarray, self.bottom),
                    "ef": dict(self.ef.residuals)}
        return {"params": jax.tree.map(np.asarray, self.params),
                "ef": dict(self.ef.residuals)}

    def load_state_dict(self, state) -> None:
        as_jax = functools.partial(jax.tree.map, jnp.asarray)
        if self.is_master:
            self.top = as_jax(state["top"])
            self.bottom = as_jax(state["bottom"])
        else:
            self.params = as_jax(state["params"])
        self.ef.residuals = dict(state["ef"])
