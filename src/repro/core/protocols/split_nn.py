"""Split-learning VFL protocol (paper §2: "neural networks-based
algorithms enabled with a split-learning approach"), on the lifecycle
API.

Members own bottom towers over their feature slices; the master owns
the top model and labels. Per batch:

1. members send bottom activations u_p = f_p(X_p),
2. master sums aggregated embedding u = u_master + sum_p u_p, runs the
   top model, computes the multi-label BCE loss,
3. master backprops and returns du_p to each member (the only gradient
   signal that crosses the boundary),
4. members apply their bottom VJP locally.

Models are built by the composable tower factory
(``repro.models.tower``, DESIGN.md §12): ``cfg.tower`` names the
member/bottom block chain (embedding table + transformer blocks on the
pallas kernels, quantize taps, MLP head) and ``cfg.top_tower`` the
master top model; both default to the legacy one-block MLP derived from
``cfg.hidden``/``cfg.embedding_dim``, which is bit-identical to the
recorded seed traces (same param init stream, same math). Large member
towers shard over local devices via ``cfg.tower_shard``.

Predict is the forward half federated end-to-end: members answer
feature-slice queries with bottom activations, the master composes the
top model — nobody ever holds another silo's features or parameters.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import schema
from repro.comm.schema import Field
from repro.core.protocols import base
from repro.core.protocols.driver import VFLProtocol
from repro.models import tower as twr

# activation/gradient exchanges declare compress=True: when the channel
# is built with compression on (cfg.compress), payloads ride as int8 +
# per-column scale with error feedback — entirely below the protocol,
# which always sees float32 tensors (DESIGN.md §7). Predict queries stay
# exempt so serving fidelity never depends on the training-path knob.
schema.message("splitnn/u", {"u": Field("float32", 2)}, stepped=True,
               compress=True,
               doc="member bottom activations for one training round")
schema.message("splitnn/du", {"du": Field("float32", 2)}, stepped=True,
               compress=True,
               doc="embedding gradient returned to one member")
schema.message("splitnn/pred_u", {"u": Field("float32", 2)}, stepped=True,
               doc="bottom activations for a predict query")


def mlp_init(key, dims: Tuple[int, ...]) -> List[Dict[str, jax.Array]]:
    """Legacy MLP primitive — the tower factory's ``mlp`` block
    reproduces this init stream exactly (kept public: mesh-mode
    ``core/vfl_step.py`` and tests build raw MLPs with it)."""
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a),
            "b": jnp.zeros((b,), jnp.float32),
        })
    return layers


def mlp_apply(params, x, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _bce(logits, y):
    return jnp.mean(jnp.clip(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def bottom_spec(cfg, in_dim: int) -> twr.TowerSpec:
    """Resolve the bottom-model tower for one party's feature width."""
    if cfg.tower:
        return twr.resolve(tuple(cfg.tower), in_dim, cfg.embedding_dim)
    return twr.mlp_tower(in_dim, cfg.hidden, cfg.embedding_dim,
                         final_act=True)


def top_spec(cfg, items: int) -> twr.TowerSpec:
    """Resolve the master's top-model tower (embeddings -> logits)."""
    if cfg.top_tower:
        return twr.resolve(tuple(cfg.top_tower), cfg.embedding_dim,
                           items)
    return twr.mlp_tower(cfg.embedding_dim, cfg.hidden, items,
                         final_act=False)


def _make_master_step(bspec: twr.TowerSpec, tspec: twr.TowerSpec):
    @jax.jit
    def step(top_params, bottom_params, u_members, x_m, y, lr):
        """Returns (loss, new_top, new_bottom, du_members)."""
        def fwd(top, bottom, u_ms):
            u = twr.apply(bspec, bottom, x_m)
            for um in u_ms:
                u = u + um
            logits = twr.apply(tspec, top, u)
            return _bce(logits, y)

        loss, grads = jax.value_and_grad(fwd, argnums=(0, 1, 2))(
            top_params, bottom_params, u_members)
        g_top, g_bottom, g_u = grads
        new_top = jax.tree.map(lambda p, g: p - lr * g, top_params,
                               g_top)
        new_bottom = jax.tree.map(lambda p, g: p - lr * g,
                                  bottom_params, g_bottom)
        return loss, new_top, new_bottom, g_u
    return step


def _make_member_fns(spec: twr.TowerSpec, rules):
    @jax.jit
    def fwd(params, x):
        return twr.apply(spec, params, x, rules=rules)

    @jax.jit
    def bwd(params, x, du, lr):
        _, vjp = jax.vjp(
            lambda p: twr.apply(spec, p, x, rules=rules), params)
        (g,) = vjp(du)
        return jax.tree.map(lambda p, gg: p - lr * gg, params, g)

    return fwd, bwd


@base.register
class SplitNNProtocol(VFLProtocol):
    name = "split_nn"
    supports_pipeline = True

    def setup(self) -> None:
        cfg, d = self.cfg, self.data
        self.lr = jnp.float32(cfg.lr)
        key = jax.random.key(cfg.seed)
        if self.is_master:
            self.y = jnp.asarray(
                base._select(d.ids, self.order, d.y), jnp.float32)
            self.x = jnp.asarray(
                base._select(d.ids, self.order, d.x), jnp.float32)
            items = self.y.shape[1]
            self._bspec = bottom_spec(cfg, self.x.shape[1])
            self._tspec = top_spec(cfg, items)
            self.bottom = twr.init(self._bspec,
                                   jax.random.fold_in(key, 0))
            self.top = twr.init(self._tspec, jax.random.fold_in(key, 1))
            self._step = _make_master_step(self._bspec, self._tspec)
            # the master's own bottom forward for predict (unsharded:
            # the master bottom is the small party-side slice)
            self._fwd, _ = _make_member_fns(self._bspec, None)
            self._top_fwd = jax.jit(functools.partial(twr.apply,
                                                      self._tspec))
        else:
            self.x = jnp.asarray(
                base._select(d.ids, self.order, d.x), jnp.float32)
            # member index determines its init stream (from its id)
            midx = int(self.role.replace("member", "")) + 2
            self._spec = bottom_spec(cfg, self.x.shape[1])
            self.params = twr.init(self._spec,
                                   jax.random.fold_in(key, midx))
            # model-parallel placement of a large member tower over the
            # local mesh; rules=None (the default) never builds a mesh
            self._rules = twr.make_tower_rules(cfg.tower_shard)
            self.params = twr.shard_tower(self.params, self._spec,
                                          self._rules)
            self._fwd, self._bwd = _make_member_fns(self._spec,
                                                    self._rules)
            self.masker = None
            # mask-stream namespace for predict queries: every member
            # sees the same EVAL round sequence, so a shared counter
            # keeps pairwise masks aligned without colliding with
            # training-step masks
            self._pred_step = 1 << 20
            if cfg.secure_agg:
                if cfg.compress:
                    raise ValueError("secure_agg masks do not survive "
                                     "independent quantization; choose one")
                from repro.core.secure_agg_protocol import PairwiseMasker
                self.masker = PairwiseMasker(self.ch.comm, self.role,
                                             self.ch.members)

    def roofline_profile(self) -> Dict[str, float]:
        """Analytic per-step cost for the roofline accounting
        (launch/roofline.py): training FLOPs ~= 3x the forward pass
        (fwd + input/weight VJPs), wire bytes = the float32 u/du
        exchange this role sees each round."""
        cfg = self.cfg
        nb = cfg.batch_size
        ubytes = nb * cfg.embedding_dim * 4
        if self.is_master:
            flops = 3.0 * (twr.tower_flops(self._bspec, nb)
                           + twr.tower_flops(self._tspec, nb))
            wire = 2 * ubytes * max(1, len(self.ch.members))
            pbytes = twr.params_bytes(self.bottom) \
                + twr.params_bytes(self.top)
        else:
            flops = 3.0 * twr.tower_flops(self._spec, nb)
            wire = 2 * ubytes
            pbytes = twr.params_bytes(self.params)
        return {"flops_per_step": flops, "bytes_per_step": float(wire),
                "params_bytes": float(pbytes)}

    def on_batch_master(self, rows, step) -> float:
        ch = self.ch
        msgs = ch.gather(ch.members, "splitnn/u")
        # fit_rows: a stale substitution (down/straggling peer) may
        # carry a different tail-batch row count than this round
        u_members = tuple(
            jnp.asarray(base.fit_rows(m.tensor("u"), len(rows)),
                        jnp.float32) for m in msgs)
        loss, self.top, self.bottom, g_u = self._step(
            self.top, self.bottom, u_members, self.x[rows], self.y[rows],
            self.lr)
        for mname, du in zip(ch.members, g_u):
            # isend: the per-member gradient writes overlap each other
            # and the next round's activation gather
            ch.isend(mname, "splitnn/du", {"du": np.asarray(du)})
        return float(loss)

    def member_stage_send(self, rows, step):
        """Bottom forward + activation isend; the batch slice is the ctx
        the deferred backward stage reuses (its VJP must see the inputs
        this forward actually saw)."""
        xb = self.x[rows]
        u = self._fwd(self.params, xb)
        if self.cfg.noise_sigma > 0:
            # noising defense (docs/privacy.md): the member perturbs
            # its outgoing embedding before any masking, so neither the
            # master nor a wire adversary ever sees the clean
            # activations an embedding-clustering attack feeds on
            u = jnp.asarray(np.asarray(u)
                            + base.defense_noise(self.cfg,
                                                 np.asarray(u), step,
                                                 self.role))
        if self.masker is not None:
            u = jnp.asarray(np.asarray(u)
                            + self.masker.mask(step, np.asarray(u).shape))
        self.ch.isend("master", "splitnn/u", {"u": np.asarray(u)})
        return xb

    def member_stage_recv(self, rows, step, xb) -> None:
        du = jnp.asarray(
            self.ch.recv("master", "splitnn/du").tensor("du"), jnp.float32)
        self.params = self._bwd(self.params, xb, du, self.lr)

    # -- predict/serve -------------------------------------------------------
    def predict_master(self, rows) -> np.ndarray:
        u = self._fwd(self.bottom, self.x[rows])
        for msg in self.ch.gather(self.ch.members, "splitnn/pred_u"):
            u = u + jnp.asarray(msg.tensor("u"), jnp.float32)
        return np.asarray(self._top_fwd(self.top, u))

    def predict_member(self, rows) -> None:
        self.send_embed(self.predict_embed(rows), rows)

    def predict_embed(self, rows) -> np.ndarray:
        # pure bottom-model forward: cacheable per row (no masking —
        # masks are per-query and applied in send_embed)
        return np.asarray(self._fwd(self.params, self.x[rows]))

    def send_embed(self, u, rows) -> None:
        if self.masker is not None:
            # predict queries get the same pairwise masking as training
            # rounds — the master only ever sees the aggregate
            u = np.asarray(u + self.masker.mask(self._pred_step, u.shape),
                           np.float32)
            self._pred_step += 1
        self.ch.send("master", "splitnn/pred_u", {"u": np.asarray(u)})

    def evaluate_master(self, scores, rows) -> Dict[str, float]:
        from repro.train.evals import recsys_report
        return recsys_report(np.asarray(scores),
                             np.asarray(self.y[rows]), k=5)

    def finalize(self) -> Dict:
        if self.is_master:
            return {"top": jax.tree.map(np.asarray, self.top),
                    "bottom": jax.tree.map(np.asarray, self.bottom),
                    "order": self.order}
        return {"params": jax.tree.map(np.asarray, self.params)}

    def _ef_residuals(self) -> Dict:
        # error feedback now lives on the typed channel (schema-level
        # compression); its residuals are part of this role's state
        ef = self.ch.error_feedback
        return dict(ef.residuals) if ef is not None else {}

    def state_dict(self) -> Dict:
        if self.is_master:
            return {"top": jax.tree.map(np.asarray, self.top),
                    "bottom": jax.tree.map(np.asarray, self.bottom),
                    "ef": self._ef_residuals()}
        return {"params": jax.tree.map(np.asarray, self.params),
                "ef": self._ef_residuals()}

    @staticmethod
    def _as_tower(state):
        """Migrate pre-§12 checkpoints: a flat legacy MLP layer list
        becomes the one-block tower param tree. A legacy layer is a
        dict of exactly ``{'w', 'b'}`` — new-format block entries
        never look like that (an mlp block is a *list* of layers;
        embed/attn dicts carry extra keys), so checking the full key
        set keeps embed-first towers out of the legacy path."""
        if (state and isinstance(state[0], dict)
                and set(state[0]) == {"w", "b"}):
            state = [state]
        return jax.tree.map(jnp.asarray, list(state))

    def load_state_dict(self, state) -> None:
        if self.is_master:
            self.top = self._as_tower(state["top"])
            self.bottom = self._as_tower(state["bottom"])
        else:
            self.params = twr.shard_tower(
                self._as_tower(state["params"]), self._spec,
                self._rules)
        if state.get("ef"):
            from repro.core import compression
            # migrate pre-§7 checkpoints: the protocol-owned EF keyed
            # streams as "u" (member) / member name (master); channel
            # EF keys are "{to}/{msg-type}/{field}"
            residuals = {}
            for k, v in state["ef"].items():
                if "/" in k:
                    residuals[k] = v
                elif k == "u":
                    residuals["master/splitnn/u/u"] = v
                else:
                    residuals[f"{k}/splitnn/du/du"] = v
            if self.ch.error_feedback is None:
                self.ch.error_feedback = compression.ErrorFeedback()
            self.ch.error_feedback.residuals = residuals
