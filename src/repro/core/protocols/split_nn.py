"""Split-learning VFL protocol (paper §2: "neural networks-based
algorithms enabled with a split-learning approach").

Members own bottom MLPs over their feature slices; the master owns the
top model and labels. Per batch:

1. members send bottom activations u_p = f_p(X_p),
2. master sums aggregated embedding u = u_master + sum_p u_p, runs the
   top model, computes the multi-label BCE loss,
3. master backprops and returns du_p to each member (the only gradient
   signal that crosses the boundary),
4. members apply their bottom VJP locally.

Everything is jax (jit'd per party), so the same protocol code is also
what the mesh-mode VFL step shards over pods (core/vfl_step.py).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.base import PartyCommunicator
from repro.core.protocols import base
from repro.core.protocols.base import (MasterData, MemberData, VFLConfig,
                                       batches, master_match, member_match,
                                       register)


def mlp_init(key, dims: Tuple[int, ...]) -> List[Dict[str, jax.Array]]:
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        layers.append({
            "w": jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a),
            "b": jnp.zeros((b,), jnp.float32),
        })
    return layers


def mlp_apply(params, x, final_act: bool = False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _bce(logits, y):
    return jnp.mean(jnp.clip(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


@functools.partial(jax.jit, static_argnames=())
def _master_fwd_bwd(top_params, bottom_params, u_members, x_m, y, lr):
    """Returns (loss, new_top, new_bottom, du_members)."""
    def fwd(top, bottom, u_ms):
        u = mlp_apply(bottom, x_m, final_act=True)
        for um in u_ms:
            u = u + um
        logits = mlp_apply(top, u)
        return _bce(logits, y)

    loss, grads = jax.value_and_grad(fwd, argnums=(0, 1, 2))(
        top_params, bottom_params, u_members)
    g_top, g_bottom, g_u = grads
    new_top = jax.tree.map(lambda p, g: p - lr * g, top_params, g_top)
    new_bottom = jax.tree.map(lambda p, g: p - lr * g, bottom_params,
                              g_bottom)
    return loss, new_top, new_bottom, g_u


@jax.jit
def _member_fwd(params, x):
    return mlp_apply(params, x, final_act=True)


@jax.jit
def _member_bwd(params, x, du, lr):
    _, vjp = jax.vjp(lambda p: mlp_apply(p, x, final_act=True), params)
    (g,) = vjp(du)
    return jax.tree.map(lambda p, gg: p - lr * gg, params, g)


def master_fn(comm: PartyCommunicator, data: MasterData,
              cfg: VFLConfig) -> Dict:
    order = master_match(comm, data, cfg)
    y = jnp.asarray(base._select(data.ids, order, data.y), jnp.float32)
    x = jnp.asarray(base._select(data.ids, order, data.x), jnp.float32)
    n, items = y.shape
    e = cfg.embedding_dim
    key = jax.random.key(cfg.seed)
    bottom = mlp_init(jax.random.fold_in(key, 0),
                      (x.shape[1],) + cfg.hidden + (e,))
    top = mlp_init(jax.random.fold_in(key, 1), (e,) + cfg.hidden + (items,))
    history: List[Dict] = []
    step = 0
    lr = jnp.float32(cfg.lr)
    from repro.core import compression
    ef = compression.ErrorFeedback()
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            msgs = comm.gather(comm.members, f"splitnn/u/{step}")
            if cfg.compress:
                u_members = tuple(
                    jnp.asarray(compression.unpack(m.payload), jnp.float32)
                    for m in msgs)
            else:
                u_members = tuple(jnp.asarray(m.tensor("u"), jnp.float32)
                                  for m in msgs)
            loss, top, bottom, g_u = _master_fwd_bwd(
                top, bottom, u_members, x[rows], y[rows], lr)
            for mname, du in zip(comm.members, g_u):
                if cfg.compress:
                    q, scale = ef.compress(mname, np.asarray(du))
                    comm.send(mname, f"splitnn/du/{step}",
                              compression.payload(q, scale))
                else:
                    comm.send(mname, f"splitnn/du/{step}",
                              {"du": np.asarray(du)})
            if step % cfg.record_every == 0:
                history.append({"step": step, "epoch": epoch,
                                "loss": float(loss)})
            step += 1
    comm.broadcast("splitnn/done", {"ok": np.array([1])},
                   targets=comm.members)
    return {"history": history, "n_common": n, "order": order,
            "top": jax.tree.map(np.asarray, top),
            "bottom": jax.tree.map(np.asarray, bottom),
            "comm": comm.stats.as_dict()}


def member_fn(comm: PartyCommunicator, data: MemberData,
              cfg: VFLConfig) -> Dict:
    order = member_match(comm, data, cfg)
    x = jnp.asarray(base._select(data.ids, order, data.x), jnp.float32)
    n = len(order)
    # member index determines its init stream (derived from its id)
    midx = int(comm.me.replace("member", "")) + 2
    params = mlp_init(jax.random.fold_in(jax.random.key(cfg.seed), midx),
                      (x.shape[1],) + cfg.hidden + (cfg.embedding_dim,))
    step = 0
    lr = jnp.float32(cfg.lr)
    from repro.core import compression
    ef = compression.ErrorFeedback()
    masker = None
    if cfg.secure_agg:
        if cfg.compress:
            raise ValueError("secure_agg masks do not survive independent "
                             "quantization; choose one")
        from repro.core.secure_agg_protocol import PairwiseMasker
        masker = PairwiseMasker(comm, comm.me, comm.members)
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            xb = x[rows]
            u = _member_fwd(params, xb)
            if masker is not None:
                u = jnp.asarray(np.asarray(u)
                                + masker.mask(step, np.asarray(u).shape))
            if cfg.compress:
                q, scale = ef.compress("u", np.asarray(u))
                comm.send("master", f"splitnn/u/{step}",
                          compression.payload(q, scale))
                du = jnp.asarray(compression.unpack(
                    comm.recv("master", f"splitnn/du/{step}").payload),
                    jnp.float32)
            else:
                comm.send("master", f"splitnn/u/{step}",
                          {"u": np.asarray(u)})
                du = jnp.asarray(
                    comm.recv("master", f"splitnn/du/{step}").tensor("du"),
                    jnp.float32)
            params = _member_bwd(params, xb, du, lr)
            step += 1
    comm.recv("master", "splitnn/done")
    return {"params": jax.tree.map(np.asarray, params),
            "comm": comm.stats.as_dict()}


register("split_nn", master_fn, member_fn)
