"""Lifecycle protocol API + the shared training driver.

The seed shipped protocols as monolithic ``(master_fn, member_fn,
arbiter_fn)`` triples that each hand-rolled matching, the epoch/batch
loop, history recording, and the shutdown handshake — ~150 lines of
scaffolding per protocol, with no way to run inference, eval
mid-training, or checkpoint. This module splits the two layers the
VFL-survey literature says belong apart:

* **algorithm layer** — :class:`VFLProtocol`: a protocol subclasses it
  and fills in role hooks (``setup``, ``on_batch_master`` /
  ``on_batch_member`` / ``arbiter_round``, ``predict_master`` /
  ``predict_member``, ``finalize``). A new protocol is ~40 lines of
  math, not ~180 of loop plumbing.

* **coordination layer** — :class:`Driver`: ONE copy of the epoch/batch
  loop, deterministic batching, per-round callbacks (eval, checkpoint,
  early-stop, metrics streaming), per-phase wall timings
  (CommStats-style), the predict/serve phase, and the done/shutdown
  handshake. The master's driver announces each round over typed
  ``ctrl/*`` messages; member and arbiter drivers are reactive, so the
  master can stop early, interleave eval rounds, or resume mid-epoch
  without any protocol-level agreement on loop bounds.

Phase machine (one ``ctrl/phase`` per transition, master-announced)::

    match ──> setup ──> [ FIT rounds ]* ──> [ PREDICT rounds ]* ──> shutdown
                          ctrl/step RUN        ctrl/step EVAL
                          (epoch, lo, hi)      + predict/rows

``ctrl/step`` carries (op, epoch, lo, hi); every party reconstructs the
batch rows from the shared deterministic permutation, so the wire never
moves sample indices during training — only during predict, where the
query rows are explicit.
"""
from __future__ import annotations

import os
import pickle
import queue
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.comm import schema
from repro.comm.schema import Field, TypedChannel
from repro.core.protocols.base import (VFLConfig, batch_bounds, batch_order,
                                       master_match, member_match)

# ctrl/phase ops
PHASE_SHUTDOWN = 0
PHASE_FIT = 1
PHASE_PREDICT = 2

# ctrl/step ops
OP_END = 0
OP_RUN = 1
OP_EVAL = 2

schema.message("ctrl/phase", {"op": Field("int64", 1)}, stepped=True,
               doc="master announces the next lifecycle phase")
schema.message("ctrl/step",
               {"op": Field("int64", 1), "epoch": Field("int64", 1),
                "lo": Field("int64", 1), "hi": Field("int64", 1)},
               stepped=True,
               doc="one driver round: train batch / eval chunk / end")
schema.message("predict/rows", {"rows": Field("int64", 1)}, stepped=True,
               doc="explicit query rows (indices into the matched order)")
schema.message("ctrl/rejoin", {"step": Field("int64", 1)}, stepped=True,
               doc="rejoin handshake: restarted member hello (its "
                   "restored step) / master ack (its global step)")


class ExchangeCapture:
    """Driver-level exchange-capture hook (docs/privacy.md).

    When ``cfg.capture_exchanges`` is on, the driver installs one of
    these on its typed channel; the channel then calls :meth:`record`
    for every message whose type is in ``names`` — on the send side
    *before* compression/masking bookkeeping (``_prepare``) and on the
    receive side *after* decompression and schema checks, i.e. exactly
    the plaintext a wire adversary at that party observes. Off by
    default: the tap is a ``capture is None`` check and capture-off
    runs are trace-bit-identical to the seed fixtures (tested in
    tests/test_capture_hook.py).

    The captured rounds are exported through ``Driver.result()
    ["capture"]`` as plain dicts (picklable across every VFLJob mode)
    and consumed offline by :mod:`repro.attacks` — the label-inference
    attacks never touch a live channel.
    """

    #: label-bearing exchanges plus the round announcements needed to
    #: reconstruct batch rows offline (rows never cross the wire during
    #: fit — they are re-derived from ``batch_order`` + (epoch, lo, hi))
    DEFAULT_NAMES = ("ctrl/step", "splitnn/u", "splitnn/du",
                     "logreg/grad")

    def __init__(self, names: Optional[Sequence[str]] = None):
        self.names = frozenset(names if names is not None
                               else self.DEFAULT_NAMES)
        self.records: List[Dict[str, Any]] = []

    def record(self, direction: str, peer: str, name: str,
               payload: Dict[str, np.ndarray]) -> None:
        if name not in self.names:
            return
        self.records.append({
            "dir": direction, "peer": peer, "name": name,
            "payload": {k: np.array(v, copy=True)
                        for k, v in payload.items()}})

    def entries(self, name: Optional[str] = None,
                peer: Optional[str] = None,
                direction: Optional[str] = None) -> List[Dict[str, Any]]:
        """Captured records filtered by message type / peer / direction,
        in arrival order (the order attacks align rounds by)."""
        return [r for r in self.records
                if (name is None or r["name"] == name)
                and (peer is None or r["peer"] == peer)
                and (direction is None or r["dir"] == direction)]

    def as_dict(self) -> Dict[str, Any]:
        return {"names": sorted(self.names),
                "records": list(self.records)}


@dataclass
class ElasticCfg:
    """Master-side elastic policy: which peers may crash and rejoin
    mid-fit (the launcher derives this from the spec's ``[restart]``
    section), and how long the master waits for a restarted peer's
    ``ctrl/rejoin`` hello before giving up and failing the run."""
    roles: frozenset = frozenset()
    wait_s: float = 60.0


class VFLProtocol:
    """Base class for VFL protocols: algorithm hooks only.

    One instance exists per agent; ``self.role`` says which hooks the
    driver will call. State set up in ``setup`` (weight slices, selected
    feature matrices) lives on ``self`` and is what ``state_dict`` /
    ``load_state_dict`` checkpoint. The hook lifecycle diagram lives in
    docs/protocols.md.

    Example (a minimal pipeline-capable protocol)::

        @register
        class MyProto(VFLProtocol):
            name = "my_proto"
            supports_pipeline = True

            def setup(self):
                self.w = np.zeros(...)            # role-local state

            def on_batch_master(self, rows, step):
                z = self.ch.recv("member0", "my/z").tensor("z")
                self.ch.isend("member0", "my/r", {"r": z - y})
                return float(loss)

            def member_stage_send(self, rows, step):
                self.ch.isend("master", "my/z", {"z": fwd(rows)})
                return rows                       # ctx for recv stage

            def member_stage_recv(self, rows, step, ctx):
                r = self.ch.recv("master", "my/r").tensor("r")
                self.apply(ctx, r)
    """

    name: str = "?"
    needs_arbiter: bool = False
    # protocols that split the member round into a send stage (compute
    # outbound from current — possibly stale — state) and a recv stage
    # (consume the master's reply, apply the update) can run pipelined
    # at cfg.pipeline_depth >= 2; see member_stage_send/_recv below.
    supports_pipeline: bool = False

    def __init__(self, cfg: VFLConfig, ch: TypedChannel, role: str):
        self.cfg = cfg
        self.ch = ch
        self.role = role
        self.data: Any = None          # MasterData / MemberData / None
        self.order: Optional[List[str]] = None
        # True while running under a checkpoint restore: setup() hooks
        # must skip comm-based exchanges whose counterpart ran (or is
        # mid-fit) in another epoch of the federation — e.g. a rejoining
        # member recovers setup-time scalars from the checkpoint instead
        self.resuming: bool = False

    @property
    def is_master(self) -> bool:
        return self.role == "master"

    @property
    def is_member(self) -> bool:
        return self.role.startswith("member")

    @property
    def is_arbiter(self) -> bool:
        # key-sharded decryption (cfg.n_arbiters >= 2) names its agents
        # "arbiter", "arbiter1", ... — all of them are arbiter-role
        return self.role.startswith("arbiter")

    # -- lifecycle hooks (override what the protocol needs) ------------------
    def match(self) -> Optional[List[str]]:
        """ID matching; default is the shared PSI / salted-hash phase."""
        if self.is_master:
            return master_match(self.ch, self.data, self.cfg)
        if self.is_member:
            return member_match(self.ch, self.data, self.cfg)
        return None

    def setup(self) -> None:
        """Post-match initialization (select rows, init weights, exchange
        dimensions / keys). Runs again on resume — training state that
        must survive belongs in ``state_dict``."""

    def on_batch_master(self, rows: np.ndarray, step: int) -> float:
        """One training round on the master; returns the batch loss."""
        raise NotImplementedError

    def on_batch_member(self, rows: np.ndarray, step: int) -> None:
        """One synchronous member round. Pipeline-capable protocols get
        this for free as stage_send immediately followed by stage_recv —
        which is exactly what guarantees ``pipeline_depth=1`` stays
        bit-identical to the pipelined hooks."""
        if not self.supports_pipeline:
            raise NotImplementedError
        ctx = self.member_stage_send(rows, step)
        self.member_stage_recv(rows, step, ctx)

    # -- pipelined member stages (supports_pipeline protocols) ---------------
    def member_stage_send(self, rows: np.ndarray, step: int) -> Any:
        """Compute this step's outbound tensors from the member's
        *current* state and isend them. Returns an opaque ctx handed
        back to :meth:`member_stage_recv` (e.g. the cached batch
        slice). With ``pipeline_depth=D`` the driver runs this up to
        D-1 steps ahead of the matching recv stage."""
        raise NotImplementedError

    def member_stage_recv(self, rows: np.ndarray, step: int,
                          ctx: Any) -> None:
        """Consume the master's reply for ``step`` and apply the local
        update."""
        raise NotImplementedError

    def arbiter_round(self, step: int) -> None:
        """One arbiter service round (e.g. decrypt-and-return)."""

    def on_window_drain(self) -> None:
        """Called on members when the driver drains its pipeline window
        (phase end): protocols that defer part of a round past its recv
        stage — e.g. the HE gradient apply at ``pipeline_depth >= 2``
        (DESIGN.md §10.2) — flush the remainder here so the next phase
        (predict/eval) sees fully applied state."""

    def predict_master(self, rows: np.ndarray) -> np.ndarray:
        """Assemble joint scores for ``rows`` of the matched order."""
        raise NotImplementedError

    def predict_member(self, rows: np.ndarray) -> None:
        """Answer one feature-slice query during predict/eval."""
        raise NotImplementedError

    # -- serving cache hooks (optional; docs/serving.md) ---------------------
    def predict_embed(self, rows: np.ndarray) -> Optional[np.ndarray]:
        """Pure per-row embedding compute for ``rows`` — no comm, no
        per-query masking — or ``None`` when the protocol cannot split
        its predict path (the driver then bypasses the embedding cache
        and calls :meth:`predict_member` directly). Row ``i`` of the
        result must depend only on row ``i`` of the input, so cached
        and freshly computed rows can be mixed within one query."""
        return None

    def send_embed(self, u: np.ndarray, rows: np.ndarray) -> None:
        """Ship precomputed embeddings ``u`` for ``rows`` to the master,
        applying any per-query transform (e.g. pairwise secure-agg
        masks) that must NOT be cached. Protocols overriding
        :meth:`predict_embed` must override this too."""
        raise NotImplementedError

    def evaluate_master(self, scores: np.ndarray,
                        rows: np.ndarray) -> Dict[str, float]:
        """Metrics for predicted ``scores`` vs the master's labels."""
        return {}

    def finalize(self) -> Dict[str, Any]:
        """Role-specific result payload (weights, counters)."""
        return {}

    def close(self) -> None:
        """Release protocol resources (threads, pools). Always called."""

    # -- checkpoint hooks ----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        pass

    # -- roofline hook -------------------------------------------------------
    def roofline_profile(self) -> Optional[Dict[str, float]]:
        """Analytic per-step cost of this role's model, or ``None``
        when the protocol doesn't account itself. Keys (all optional):
        ``flops_per_step`` (training FLOPs for one round),
        ``bytes_per_step`` (wire bytes this role exchanges per round),
        ``params_bytes``. Merged into ``Driver.result()["roofline"]``
        next to the measured compute/wire split (launch/roofline.py)."""
        return None


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------


class Callback:
    """Per-round hooks invoked by the driver (all roles). Master-side
    callbacks may call ``driver.request_stop()`` / ``driver.predict_now``
    / ``driver.save_checkpoint()``; member/arbiter drivers invoke the
    same hooks so e.g. checkpoints stay role-consistent."""

    def on_fit_start(self, driver: "Driver") -> None: ...
    def on_epoch_start(self, driver: "Driver", epoch: int) -> None: ...
    def on_batch_end(self, driver: "Driver", step: int, epoch: int,
                     loss: Optional[float]) -> None: ...
    def on_epoch_end(self, driver: "Driver", epoch: int) -> None: ...
    def on_fit_end(self, driver: "Driver") -> None: ...


class MetricsStream(Callback):
    """Streams per-round rows into ``self.rows`` (CommStats-style: step,
    epoch, loss, cumulative sent bytes, wall time since fit start)."""

    def __init__(self):
        self.rows: List[Dict[str, Any]] = []
        self._t0 = 0.0

    def on_fit_start(self, driver):
        self._t0 = time.perf_counter()

    def on_batch_end(self, driver, step, epoch, loss):
        if driver.role != "master":
            return
        self.rows.append({
            "step": step, "epoch": epoch, "loss": loss,
            "sent_bytes": driver.ch.stats.sent_bytes,
            "wall_s": round(time.perf_counter() - self._t0, 4),
        })


class EarlyStopping(Callback):
    """Stop when the master's batch loss hasn't improved by
    ``min_delta`` for ``patience`` consecutive rounds."""

    def __init__(self, patience: int = 10, min_delta: float = 0.0):
        self.patience = patience
        self.min_delta = min_delta
        self.best = float("inf")
        self.bad = 0

    def on_batch_end(self, driver, step, epoch, loss):
        if driver.role != "master" or loss is None:
            return
        if loss < self.best - self.min_delta:
            self.best, self.bad = loss, 0
        else:
            self.bad += 1
            if self.bad >= self.patience:
                driver.request_stop(f"early-stop at step {step} "
                                    f"(best loss {self.best:.6f})")


class StopAtStep(Callback):
    """Deterministically end fit after ``n`` global steps (testing /
    budgeted runs)."""

    def __init__(self, n: int):
        self.n = n

    def on_batch_end(self, driver, step, epoch, loss):
        if driver.role == "master" and step + 1 >= self.n:
            driver.request_stop(f"step budget {self.n} reached")


class Checkpointer(Callback):
    """Writes ``<dir>/<role>.pkl`` every ``every_steps`` rounds; every
    role checkpoints at the same global step, so a directory is a
    consistent cut of the whole federation. Resume via
    ``VFLJob(..., resume_dir=...)``."""

    def __init__(self, directory, every_steps: int = 1,
                 save_on_start: bool = False):
        self.directory = str(directory)
        self.every_steps = every_steps
        # elastic clusters set this so a checkpoint exists from step 0:
        # a member crashing before its first on_batch_end still has
        # state (and the matched order) to rejoin from
        self.save_on_start = save_on_start

    def on_fit_start(self, driver):
        if self.save_on_start:
            driver.save_checkpoint(self.directory)

    def on_batch_end(self, driver, step, epoch, loss):
        if (step + 1) % self.every_steps == 0:
            driver.save_checkpoint(self.directory)


class EvalEveryEpoch(Callback):
    """Master-side mid-training evaluation: runs a federated predict
    pass over the matched set at each epoch end (members answer inside
    their fit loop via EVAL rounds) and appends the protocol's metrics
    to ``driver.eval_history``."""

    def __init__(self, every: int = 1, max_rows: Optional[int] = None):
        self.every = every
        self.max_rows = max_rows

    def on_epoch_end(self, driver, epoch):
        if driver.role != "master" or (epoch + 1) % self.every:
            return
        n = driver.n if self.max_rows is None else min(driver.n,
                                                       self.max_rows)
        rows = np.arange(n)
        scores = driver.predict_now(rows)
        metrics = driver.proto.evaluate_master(scores, rows)
        driver.eval_history.append({"epoch": epoch, **metrics})


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


class EmbedCache:
    """Bounded LRU of per-row member embeddings for the serve path
    (``cfg.serve_cache_rows``; docs/serving.md). Keys are matched-order
    row ids (int), values the member's *unmasked* embedding row —
    per-query transforms (secure-agg masks) are applied after lookup by
    :meth:`VFLProtocol.send_embed`. Cleared whenever a fit phase starts."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._d)

    def lookup(self, rows: np.ndarray
               ) -> Tuple[Dict[int, np.ndarray], np.ndarray]:
        """Split ``rows`` into (found, missing). ``found`` maps row id ->
        cached embedding; ``missing`` keeps query order, deduplicated."""
        found: Dict[int, np.ndarray] = {}
        missing: List[int] = []
        seen_missing = set()
        for r in rows:
            r = int(r)
            if r in found or r in seen_missing:
                continue
            v = self._d.get(r)
            if v is not None:
                self._d.move_to_end(r)
                found[r] = v
                self.hits += 1
            else:
                seen_missing.add(r)
                missing.append(r)
                self.misses += 1
        return found, np.asarray(missing, dtype=rows.dtype)

    def insert(self, rows: np.ndarray, u: np.ndarray) -> None:
        for i, r in enumerate(rows):
            self._d[int(r)] = u[i]
            self._d.move_to_end(int(r))
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def invalidate(self) -> None:
        if self._d:
            self.invalidations += 1
        self._d.clear()

    def as_dict(self) -> Dict[str, int]:
        return {"rows": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations}


def _step_payload(op: int, epoch: int, lo: int, hi: int):
    # explicit dtype: bare np.array([int]) is int32 on some platforms,
    # which would fail the declared-int64 schema check
    return {"op": np.array([op], np.int64),
            "epoch": np.array([epoch], np.int64),
            "lo": np.array([lo], np.int64),
            "hi": np.array([hi], np.int64)}


class Driver:
    """Shared coordination layer: owns the loop, the protocol owns the
    math. One driver per agent; the master's is command-driven (via
    :class:`~repro.core.party.VFLJob`), member/arbiter drivers follow
    the master's ``ctrl/*`` announcements."""

    def __init__(self, proto: VFLProtocol,
                 callbacks: Sequence[Callback] = (),
                 resume_state: Optional[Dict[str, Any]] = None,
                 elastic: Optional[ElasticCfg] = None):
        self.proto = proto
        self.cfg = proto.cfg
        self.ch = proto.ch
        self.role = proto.role
        self.callbacks = list(callbacks)
        self.history: List[Dict[str, Any]] = []
        self.eval_history: List[Dict[str, Any]] = []
        self.phase_s: Dict[str, float] = {}
        self.global_step = 0
        self.n: int = 0
        self.stopped: Optional[str] = None
        self._stop: Optional[str] = None
        self._resume = resume_state
        self._pos = (0, 0)            # (epoch, next batch index)
        self.elastic = elastic        # master-side; None = fail-fast
        # one dict per recovered peer: role, master step at rejoin, the
        # peer's restored step, and how long the rejoin handshake took
        self.recoveries: List[Dict[str, Any]] = []
        # member-side serve cache (cfg.serve_cache_rows); lazily built on
        # the first EVAL round a cache-capable protocol answers
        self._embed_cache: Optional[EmbedCache] = None
        # per-step roofline accounting (launch/roofline.py): fit phases
        # accumulate wall/steps plus CommStats counter deltas here, and
        # result() resolves them into the compute-vs-wire split
        self._fit_acc: Dict[str, float] = {"wall_s": 0.0, "steps": 0}
        # adversarial exchange capture (docs/privacy.md): installed on
        # the channel only when asked for — every other run keeps the
        # channel's ``capture`` at None and pays one is-None check
        if self.cfg.capture_exchanges:
            self.ch.capture = ExchangeCapture()

    _ROOF_COUNTERS = ("recv_wait_s", "send_s", "queued_s", "wire_s",
                      "sent_bytes")

    def _roof_snap(self) -> Dict[str, float]:
        s = self.ch.stats
        return {k: float(getattr(s, k)) for k in self._ROOF_COUNTERS}

    def _roof_record(self, t0: float, snap: Dict[str, float],
                     step0: int) -> None:
        """Fold one fit phase's wall/steps/comm deltas into the
        roofline accumulator (phases add up across refits)."""
        acc = self._fit_acc
        acc["wall_s"] += time.perf_counter() - t0
        acc["steps"] += self.global_step - step0
        now = self._roof_snap()
        for k in self._ROOF_COUNTERS:
            acc[k] = acc.get(k, 0.0) + now[k] - snap[k]

    # -- helpers -------------------------------------------------------------
    @property
    def _others(self) -> List[str]:
        return self.ch.members + self._arbiters

    @property
    def _arbiters(self) -> List[str]:
        return [w for w in self.ch.world if w.startswith("arbiter")]

    def _invoke(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    def _timed(self, phase: str, t0: float) -> None:
        self.phase_s[phase] = round(
            self.phase_s.get(phase, 0.0) + time.perf_counter() - t0, 4)

    def request_stop(self, reason: str = "requested") -> None:
        self._stop = reason

    def save_checkpoint(self, directory) -> None:
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        state = {"global_step": self.global_step, "pos": self._pos,
                 "history": list(self.history),
                 # the agreed sample order: lets a restarted agent skip
                 # the comm-driven match phase entirely on resume
                 "order": list(self.proto.order)
                 if self.proto.order is not None else None,
                 "proto": self.proto.state_dict()}
        # atomic tmp+rename: a SIGKILL mid-write must never leave a
        # truncated pickle for the restarted process to trip over
        tmp = d / f".{self.role}.pkl.tmp"
        tmp.write_bytes(pickle.dumps(state))
        os.replace(tmp, d / f"{self.role}.pkl")

    # -- lifecycle entry -----------------------------------------------------
    def prepare(self, data) -> None:
        """match + setup (+ checkpoint restore). Runs once per agent."""
        self.proto.data = data
        self.proto.resuming = self._resume is not None
        t0 = time.perf_counter()
        self.ch.stats.phase = "match"
        if self._resume is not None and \
                self._resume.get("order") is not None:
            # the checkpoint carries the agreed order — a restarted
            # agent must NOT rerun the comm-based match phase (its
            # peers are mid-fit, not waiting in match)
            self.proto.order = list(self._resume["order"])
        else:
            self.proto.order = self.proto.match()
        self._timed("match", t0)
        self.n = len(self.proto.order) if self.proto.order is not None \
            else 0
        t0 = time.perf_counter()
        self.ch.stats.phase = "setup"
        self.proto.setup()          # keygen etc. — timed on its own
        self._timed("setup", t0)
        if self._resume is not None:
            self.proto.load_state_dict(self._resume["proto"])
            self.global_step = self._resume["global_step"]
            self._pos = tuple(self._resume["pos"])
            self.history = list(self._resume["history"])

    def result(self) -> Dict[str, Any]:
        out = {**self.proto.finalize(), "comm": self.ch.stats.as_dict(),
               "phase_s": dict(self.phase_s)}
        if self._embed_cache is not None:
            out["embed_cache"] = self._embed_cache.as_dict()
        if getattr(self.ch, "capture", None) is not None:
            out["capture"] = self.ch.capture.as_dict()
        if self._fit_acc["steps"] > 0:
            from repro.launch.roofline import step_account
            out["roofline"] = step_account(
                self._fit_acc["wall_s"], int(self._fit_acc["steps"]),
                self._fit_acc, self.proto.roofline_profile())
        if self.role == "master":
            out["history"] = list(self.history)
            out["n_common"] = self.n
            if self.stopped:
                out["stopped"] = self.stopped
            if self.eval_history:
                out["eval_history"] = list(self.eval_history)
            if self.recoveries:
                out["recoveries"] = list(self.recoveries)
        return out

    # -- master side ---------------------------------------------------------
    def fit(self, epochs: Optional[int] = None) -> Dict[str, Any]:
        """Run the training phase (master only): announce FIT, drive the
        epoch/batch loop, broadcast RUN rounds, handle callbacks /
        early stop, then close the phase with END.

        The master keeps a sliding window of up to ``cfg.pipeline_depth``
        announced-but-not-yet-computed rounds. At depth 1 (default) the
        announce/compute interleaving is exactly the synchronous
        lock-step loop. At depth D >= 2 members see future rounds early
        and run their send stage ahead (bounded staleness); every
        announced round IS computed — a stop request only stops new
        announcements, so stops take effect within D-1 rounds and no
        follower is ever left waiting on a round that never happens.
        """
        assert self.role == "master"
        t0 = time.perf_counter()
        roof_snap, roof_step0 = self._roof_snap(), self.global_step
        cfg = self.cfg
        epochs = cfg.epochs if epochs is None else epochs
        # protocols without stage hooks run their members synchronously;
        # announcing ahead of them would deadlock a mid-fit eval (the
        # member sits inside on_batch_member for an announced round the
        # master hasn't computed), so the window collapses to 1
        depth = max(1, int(cfg.pipeline_depth)) \
            if self.proto.supports_pipeline else 1
        self.ch.stats.phase = "fit"
        # arm the channel's elastic / straggler machinery for the fit
        # phase only: crashes outside fit (match, predict) stay
        # fail-fast, and the per-round deadline is meaningful only when
        # the pipeline gives members slack to be stale in
        if self.elastic is not None:
            self.ch.elastic_roles = set(self.elastic.roles)
        if depth > 1 and cfg.round_deadline_s > 0:
            self.ch.round_deadline = float(cfg.round_deadline_s)
        self.ch.broadcast("ctrl/phase", {"op": np.array([PHASE_FIT], np.int64)},
                          targets=self._others)
        self._stop = None
        self._invoke("on_fit_start")
        start_epoch, start_batch = self._pos
        bounds = batch_bounds(self.n, cfg)
        last_b = len(bounds) - 1

        def _schedule():
            for epoch in range(start_epoch, epochs):
                first = start_batch if epoch == start_epoch else 0
                for b in range(first, len(bounds)):
                    yield epoch, b, bounds[b]

        sched = _schedule()
        announced: "deque" = deque()
        exhausted = False
        cached_epoch, perm = None, None
        while True:
            # a down peer pauses NEW announcements; the already-announced
            # window still completes below (stale substitution keeps the
            # survivors' streams in lock-step), then the rejoin handshake
            # runs with no round in flight
            while not self._stop and not exhausted and not self.ch.down \
                    and len(announced) < depth:
                try:
                    epoch, b, (lo, hi) = next(sched)
                except StopIteration:
                    exhausted = True
                    break
                # epoch-start callbacks run BEFORE the epoch's first
                # round is announced (so a callback may run comm rounds,
                # e.g. an eval pass, with no member mid-round). At
                # depth 1 this is the legacy ordering exactly; at
                # depth >= 2 on_epoch_start(e) can fire while the tail
                # of epoch e-1 is still computing.
                if b == 0:
                    self._invoke("on_epoch_start", epoch)
                self.ch.broadcast("ctrl/step",
                                  _step_payload(OP_RUN, epoch, lo, hi),
                                  targets=self._others,
                                  wait=(depth == 1))
                announced.append((epoch, b, lo, hi))
            if not announced:
                if self.ch.down and self.elastic is not None:
                    self._elastic_rejoin()
                    continue
                break
            epoch, b, lo, hi = announced.popleft()
            if epoch != cached_epoch:
                perm = batch_order(self.n, cfg, epoch)
                cached_epoch = epoch
            loss = self.proto.on_batch_master(perm[lo:hi],
                                              self.global_step)
            if self.global_step % cfg.record_every == 0:
                # wall_s (since fit start) lets offline analysis split
                # steady-state step time from jit/pipeline warmup
                self.history.append({"step": self.global_step,
                                     "epoch": epoch, "loss": loss,
                                     "wall_s": round(
                                         time.perf_counter() - t0, 6)})
            self.global_step += 1
            self._pos = (epoch, b + 1)
            self._invoke("on_batch_end", self.global_step - 1, epoch,
                         loss)
            if b == last_b and not self._stop:
                self._pos = (epoch + 1, 0)
                self._invoke("on_epoch_end", epoch)
        self.ch.round_deadline = None     # disarm: predict waits fully
        self.ch._drain_stale()            # consume late straggler msgs
        self.ch.broadcast("ctrl/step", _step_payload(OP_END, -1, 0, 0),
                          targets=self._others)
        self.stopped = self._stop
        self._invoke("on_fit_end")
        self._roof_record(t0, roof_snap, roof_step0)
        self._timed("fit", t0)
        out = {"history": list(self.history), "n_common": self.n,
               "stopped": self.stopped,
               "eval_history": list(self.eval_history)}
        if self.recoveries:
            out["recoveries"] = list(self.recoveries)
        return out

    def _elastic_rejoin(self) -> None:
        """The in-flight window is drained and at least one elastic peer
        is down: for each, reset every per-peer comm/channel counter
        (the restarted process counts from zero on both planes), wait
        for its ``ctrl/rejoin`` hello, ack with the master's global
        step, and resume announcing. Survivors never notice — their
        streams were kept in lock-step by stale substitution, so no
        counter of theirs is touched."""
        assert self.role == "master" and self.elastic is not None
        for dead in sorted(self.ch.down):
            t0 = time.perf_counter()
            # full reset BEFORE listening: sequence numbers, reorder
            # buffers, EF residuals, the cached connection and the
            # sticky send error all return to zero so both ends of the
            # new connection agree on a fresh stream. The hello may
            # already be pending — keep control-plane tags.
            self.ch.reset_peer(dead)
            self.ch.comm.reset_peer(dead, keep_tags=("ctrl/",))
            try:
                hello = self.ch.recv(dead, "ctrl/rejoin",
                                     timeout=self.elastic.wait_s)
            except (TimeoutError, ConnectionError) as e:
                raise ConnectionError(
                    f"master: peer {dead!r} dropped mid-fit and sent "
                    f"no rejoin hello within {self.elastic.wait_s}s"
                ) from e
            peer_step = int(hello.tensor("step")[0])
            self.ch.down.discard(dead)
            self.ch.send(dead, "ctrl/rejoin",
                         {"step": np.array([self.global_step],
                                           np.int64)})
            self.recoveries.append({
                "role": dead, "step": self.global_step,
                "peer_step": peer_step,
                "wait_s": round(time.perf_counter() - t0, 4)})

    def predict(self, rows: Optional[np.ndarray] = None,
                batch_size: Optional[int] = None) -> np.ndarray:
        """Joint inference phase (master only): members answer
        feature-slice queries, the master assembles scores. No training
        state changes."""
        assert self.role == "master"
        t0 = time.perf_counter()
        self.ch.stats.phase = "predict"
        self.ch.broadcast("ctrl/phase", {"op": np.array([PHASE_PREDICT], np.int64)},
                          targets=self._others)
        out = self.predict_now(rows, batch_size)
        self.ch.broadcast("ctrl/step", _step_payload(OP_END, -1, 0, 0),
                          targets=self._others)
        self._timed("predict", t0)
        return out

    def predict_now(self, rows: Optional[np.ndarray] = None,
                    batch_size: Optional[int] = None) -> np.ndarray:
        """Run EVAL rounds inside the *current* phase (used by the
        standalone predict phase and by mid-fit eval callbacks alike —
        members handle EVAL steps from within their fit loop)."""
        rows = np.arange(self.n) if rows is None else \
            np.asarray(rows, dtype=np.int64)
        bs = batch_size or self.cfg.batch_size
        parts = []
        for lo in range(0, len(rows), bs):
            sub = rows[lo:lo + bs]
            # duplicate row ids inside one batch (coalesced serving
            # queries hit the same hot users) are computed and shipped
            # once and re-expanded on return; already-unique batches
            # take the original path untouched, so training-time traces
            # stay bit-identical
            uniq, inv = np.unique(sub, return_inverse=True)
            wire = uniq if len(uniq) < len(sub) else sub
            step = _step_payload(OP_EVAL, -1, lo, lo + len(wire))
            # one coalesced frame per member: the EVAL announcement and
            # its query rows ride a single wire message (DESIGN.md §7)
            for m in self.ch.members:
                with self.ch.frame(m):
                    self.ch.send(m, "ctrl/step", step)
                    self.ch.send(m, "predict/rows", {"rows": wire})
            for arb in self._arbiters:
                self.ch.send(arb, "ctrl/step", step)
            scores = np.asarray(self.proto.predict_master(wire))
            if wire is uniq:
                scores = scores[inv]
            parts.append(scores)
        return np.concatenate(parts, axis=0) if parts else \
            np.zeros((0, 1))

    # -- persistent serving session (docs/serving.md) ------------------------
    def serve_open(self) -> None:
        """Open a long-lived predict phase: one ``ctrl/phase`` broadcast
        parks every member in its EVAL round loop, after which
        :meth:`serve_query` answers each coalesced query batch with a
        single round — no per-query phase handshake. Close with
        :meth:`serve_close` before fitting or shutting down."""
        assert self.role == "master"
        self.ch.stats.phase = "serve"
        self.ch.broadcast("ctrl/phase",
                          {"op": np.array([PHASE_PREDICT], np.int64)},
                          targets=self._others)

    def serve_query(self, rows: np.ndarray,
                    batch_size: Optional[int] = None) -> np.ndarray:
        """One federated inference round inside an open serve session.
        Scores come back in ``rows`` order; duplicates within the batch
        cross the wire once (see :meth:`predict_now`)."""
        assert self.role == "master"
        return self.predict_now(rows, batch_size or len(rows) or None)

    def serve_close(self) -> None:
        """End the serve session: members drain back to their phase
        wait loop."""
        assert self.role == "master"
        self.ch.broadcast("ctrl/step", _step_payload(OP_END, -1, 0, 0),
                          targets=self._others)

    def evaluate(self, rows: Optional[np.ndarray] = None) -> Dict[str, Any]:
        assert self.role == "master"
        rows = np.arange(self.n) if rows is None else \
            np.asarray(rows, dtype=np.int64)
        scores = self.predict(rows)
        return self.proto.evaluate_master(scores, rows)

    def shutdown_world(self) -> None:
        assert self.role == "master"
        self.ch.broadcast("ctrl/phase", {"op": np.array([PHASE_SHUTDOWN], np.int64)},
                          targets=self._others)

    # -- member / arbiter side ----------------------------------------------
    def follow(self, idle_timeout: float = 3600.0) -> Dict[str, Any]:
        """Reactive phase loop for members and the arbiter: wait for the
        master's phase announcements until shutdown. The wait between
        phases is patient (a live job may sit idle between fit and
        predict far longer than the transports' per-message timeouts);
        within a phase, round timeouts stay strict."""
        while True:
            deadline = time.monotonic() + idle_timeout
            while True:
                try:
                    op = int(self.ch.recv("master",
                                          "ctrl/phase").tensor("op")[0])
                    break
                except (queue.Empty, TimeoutError):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"{self.role}: no phase announcement within "
                            f"{idle_timeout}s")
            if op == PHASE_SHUTDOWN:
                break
            t0 = time.perf_counter()
            if op == PHASE_FIT:
                self.ch.stats.phase = "fit"
                if self._embed_cache is not None:
                    # refit invalidates every cached embedding — the
                    # bottom model is about to change
                    self._embed_cache.invalidate()
                self._invoke("on_fit_start")
                roof_snap, roof_step0 = self._roof_snap(), \
                    self.global_step
                self._follow_steps()
                self._roof_record(t0, roof_snap, roof_step0)
                self._invoke("on_fit_end")
                self._timed("fit", t0)
            elif op == PHASE_PREDICT:
                self.ch.stats.phase = "predict"
                # a predict phase may be a long-lived serving session
                # with idle gaps between queries far beyond the
                # transport timeout — wait for rounds as patiently as
                # for phase announcements
                self._follow_steps(idle_timeout=idle_timeout)
                self._timed("predict", t0)
            else:
                raise ValueError(f"{self.role}: unknown phase op {op}")
        return self.result()

    def rejoin_follow(self, idle_timeout: float = 3600.0
                      ) -> Dict[str, Any]:
        """Member entry point after a restart: state is already restored
        from the checkpoint (``prepare`` skipped match via the stored
        order), the master is paused mid-fit waiting for us. Send the
        rejoin hello, take the master's global step from the ack, and
        drop straight into the fit round loop — there is no pending
        ``ctrl/phase`` announcement to wait for. After fit ends, hand
        over to the normal :meth:`follow` loop for predict/shutdown."""
        assert self.role != "master"
        hello = {"step": np.array([self.global_step], np.int64)}
        self.ch.send("master", "ctrl/rejoin", hello)
        ack = self.ch.recv("master", "ctrl/rejoin",
                           timeout=self.ch.comm._timeout)
        self.global_step = max(self.global_step,
                               int(ack.tensor("step")[0]))
        t0 = time.perf_counter()
        self.ch.stats.phase = "fit"
        self._invoke("on_fit_start")
        roof_snap, roof_step0 = self._roof_snap(), self.global_step
        self._follow_steps()
        self._roof_record(t0, roof_snap, roof_step0)
        self._invoke("on_fit_end")
        self._timed("fit", t0)
        return self.follow(idle_timeout)

    def _follow_steps(self, idle_timeout: Optional[float] = None) -> None:
        """Reactive round loop. Synchronous members execute each RUN
        round in place; with ``pipeline_depth=D >= 2`` a
        pipeline-capable member keeps up to D rounds in flight — the
        send stage runs as soon as a round is announced, the recv stage
        (gradient apply) is deferred until the window is full or the
        phase ends. The master computes every round it announced, so
        draining the window at END never blocks on a missing reply.
        EVAL rounds are answered immediately with the current (possibly
        bounded-stale) parameters. ``idle_timeout`` (serving sessions)
        makes the wait for the *next* round patient — transport
        timeouts between queries are retried until the budget runs
        out; within a round, timeouts stay strict."""
        cfg = self.cfg
        depth = max(1, int(cfg.pipeline_depth))
        arbiter = self.role.startswith("arbiter")
        pipelined = (depth > 1 and not arbiter
                     and self.proto.supports_pipeline)
        inflight: "deque" = deque()       # (rows, step, epoch, ctx)
        cached_epoch, perm = None, None

        def _complete_one() -> None:
            rows0, step0, epoch0, ctx0 = inflight.popleft()
            self.proto.member_stage_recv(rows0, step0, ctx0)
            self._invoke("on_batch_end", step0, epoch0, None)

        def _next_step():
            if idle_timeout is None:
                return self.ch.recv("master", "ctrl/step")
            deadline = time.monotonic() + idle_timeout
            while True:
                try:
                    return self.ch.recv("master", "ctrl/step")
                except (queue.Empty, TimeoutError):
                    if time.monotonic() > deadline:
                        raise TimeoutError(
                            f"{self.role}: no serve round within "
                            f"{idle_timeout}s")

        while True:
            msg = _next_step()
            op = int(msg.tensor("op")[0])
            if op == OP_END:
                while inflight:
                    _complete_one()
                if not arbiter:
                    self.proto.on_window_drain()
                return
            epoch = int(msg.tensor("epoch")[0])
            lo, hi = int(msg.tensor("lo")[0]), int(msg.tensor("hi")[0])
            if op == OP_RUN:
                if epoch != cached_epoch:
                    perm = batch_order(self.n, self.cfg, epoch)
                    cached_epoch = epoch
                rows = perm[lo:hi]
                if arbiter:
                    self.proto.arbiter_round(self.global_step)
                    self.global_step += 1
                    self._pos = (epoch, -1)
                    self._invoke("on_batch_end", self.global_step - 1,
                                 epoch, None)
                elif not pipelined:
                    self.proto.on_batch_member(rows, self.global_step)
                    self.global_step += 1
                    self._pos = (epoch, -1)   # members don't track batch
                    self._invoke("on_batch_end", self.global_step - 1,
                                 epoch, None)
                else:
                    while len(inflight) >= depth:
                        _complete_one()
                    ctx = self.proto.member_stage_send(rows,
                                                       self.global_step)
                    inflight.append((rows, self.global_step, epoch, ctx))
                    self.global_step += 1
                    self._pos = (epoch, -1)
            elif op == OP_EVAL:
                if not arbiter:
                    rows = self.ch.recv("master",
                                        "predict/rows").tensor("rows")
                    self._answer_eval(np.asarray(rows))
            else:
                raise ValueError(f"{self.role}: unknown step op {op}")

    def _answer_eval(self, rows: np.ndarray) -> None:
        """Answer one EVAL query, through the embedding cache when the
        protocol supports the split predict path and
        ``cfg.serve_cache_rows > 0``."""
        if self.cfg.serve_cache_rows <= 0:
            self.proto.predict_member(rows)
            return
        if self._embed_cache is None:
            self._embed_cache = EmbedCache(self.cfg.serve_cache_rows)
        cache = self._embed_cache
        found, missing = cache.lookup(rows)
        if len(missing):
            fresh = self.proto.predict_embed(missing)
            if fresh is None:
                # protocol can't split compute from comm — fall back
                # (undo the speculative stat counts for this query)
                cache.misses -= len(missing)
                cache.hits -= len(found)
                self.proto.predict_member(rows)
                return
            fresh = np.asarray(fresh)
            cache.insert(missing, fresh)
            found.update(
                {int(r): fresh[i] for i, r in enumerate(missing)})
        u = np.stack([found[int(r)] for r in rows], axis=0)
        self.proto.send_embed(u, rows)


def load_checkpoint(directory, role: str) -> Optional[Dict[str, Any]]:
    p = Path(directory) / f"{role}.pkl"
    if not p.exists():
        return None
    return pickle.loads(p.read_bytes())
