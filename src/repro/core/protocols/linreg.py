"""Arbiterless VFL linear regression (paper §2 protocol layer).

Per batch: every party computes its partial prediction z_p = X_p w_p and
sends it to the master; the master (who holds labels and its own feature
slice) sums partials, computes the residual, and broadcasts it; each
party updates its own weight slice locally from X_p^T r. No raw features
ever leave a party.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.comm.base import PartyCommunicator
from repro.core.protocols import base
from repro.core.protocols.base import (MasterData, MemberData, VFLConfig,
                                       batches, master_match, member_match,
                                       register)


def master_fn(comm: PartyCommunicator, data: MasterData,
              cfg: VFLConfig) -> Dict:
    order = master_match(comm, data, cfg)
    y = base._select(data.ids, order, data.y).astype(np.float64)
    x = base._select(data.ids, order, data.x).astype(np.float64) \
        if data.x is not None else None
    n, items = y.shape
    comm.broadcast("linreg/setup", {"items": np.array([items])},
                   targets=comm.members)
    w = np.zeros((x.shape[1], items)) if x is not None else None
    history: List[Dict] = []
    step = 0
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            zb = np.zeros((len(rows), items))
            if x is not None:
                zb += x[rows] @ w
            for msg in comm.gather(comm.members, f"linreg/z/{step}"):
                zb += msg.tensor("z")
            r = (zb - y[rows]) / len(rows)
            comm.broadcast(f"linreg/resid/{step}", {"r": r},
                           targets=comm.members)
            if x is not None:
                w -= cfg.lr * (x[rows].T @ r + cfg.l2 * w)
            loss = float(0.5 * np.mean((zb - y[rows]) ** 2))
            if step % cfg.record_every == 0:
                history.append({"step": step, "epoch": epoch, "loss": loss})
            step += 1
    comm.broadcast("linreg/done", {"ok": np.array([1])},
                   targets=comm.members)
    return {"history": history, "w_master": w, "n_common": n,
            "comm": comm.stats.as_dict()}


def member_fn(comm: PartyCommunicator, data: MemberData,
              cfg: VFLConfig) -> Dict:
    order = member_match(comm, data, cfg)
    x = base._select(data.ids, order, data.x).astype(np.float64)
    n = len(order)
    items = int(comm.recv("master", "linreg/setup").tensor("items")[0])
    w = np.zeros((x.shape[1], items))
    step = 0
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            comm.send("master", f"linreg/z/{step}", {"z": x[rows] @ w})
            r = comm.recv("master", f"linreg/resid/{step}").tensor("r")
            w -= cfg.lr * (x[rows].T @ r + cfg.l2 * w)
            step += 1
    comm.recv("master", "linreg/done")
    return {"w": w, "comm": comm.stats.as_dict()}


register("linreg", master_fn, member_fn)
