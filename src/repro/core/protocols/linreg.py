"""Arbiterless VFL linear regression (paper §2 protocol layer), on the
lifecycle API.

Per batch: every party computes its partial prediction z_p = X_p w_p and
sends it to the master; the master (who holds labels and its own feature
slice) sums partials, computes the residual, and broadcasts it; each
party updates its own weight slice locally from X_p^T r. No raw features
ever leave a party. Predict is the forward half alone: members answer
feature-slice queries with partial scores, the master sums.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.comm import schema
from repro.comm.schema import Field
from repro.core.protocols import base
from repro.core.protocols.driver import VFLProtocol

schema.message("linreg/setup", {"items": Field("int64", 1)},
               doc="target width broadcast after matching")
schema.message("linreg/z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial predictions for the current batch")
schema.message("linreg/resid", {"r": Field("float64", 2)}, stepped=True,
               doc="shared residual (the only training signal members see)")
schema.message("linreg/pred_z", {"z": Field("float64", 2)}, stepped=True,
               doc="partial scores for a predict query")


@base.register
class LinRegProtocol(VFLProtocol):
    name = "linreg"
    supports_pipeline = True

    def setup(self) -> None:
        ch, d = self.ch, self.data
        # the width exchange only runs on a fresh federation: a resumed
        # (e.g. rejoining) agent restores items/w from its checkpoint —
        # its counterpart is mid-fit, not waiting in setup
        if self.is_master:
            self.y = base._select(d.ids, self.order, d.y).astype(np.float64)
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64) \
                if d.x is not None else None
            self.items = self.y.shape[1]
            if not self.resuming:
                ch.broadcast("linreg/setup",
                             {"items": np.array([self.items], np.int64)},
                             targets=ch.members)
            self.w = np.zeros((self.x.shape[1], self.items)) \
                if self.x is not None else None
        else:
            self.x = base._select(d.ids, self.order, d.x).astype(np.float64)
            if self.resuming:
                return          # items/w arrive via load_state_dict
            self.items = int(ch.recv("master",
                                     "linreg/setup").tensor("items")[0])
            self.w = np.zeros((self.x.shape[1], self.items))

    def on_batch_master(self, rows, step) -> float:
        cfg, ch = self.cfg, self.ch
        zb = np.zeros((len(rows), self.items))
        if self.x is not None:
            zb += self.x[rows] @ self.w
        for msg in ch.gather(ch.members, "linreg/z"):
            # stale substitutions (down/straggling peer) may carry a
            # different tail-batch row count than this round
            zb += base.fit_rows(msg.tensor("z"), len(rows))
        r = (zb - self.y[rows]) / len(rows)
        # async broadcast: the residual is snapshotted at encode time,
        # so the in-place weight update below can't race the wire write
        ch.broadcast("linreg/resid", {"r": r}, targets=ch.members,
                     wait=False)
        if self.x is not None:
            self.w -= cfg.lr * (self.x[rows].T @ r + cfg.l2 * self.w)
        return float(0.5 * np.mean((zb - self.y[rows]) ** 2))

    def member_stage_send(self, rows, step):
        self.ch.isend("master", "linreg/z", {"z": self.x[rows] @ self.w})
        return None

    def member_stage_recv(self, rows, step, ctx) -> None:
        cfg = self.cfg
        r = self.ch.recv("master", "linreg/resid").tensor("r")
        self.w -= cfg.lr * (self.x[rows].T @ r + cfg.l2 * self.w)

    # -- predict/serve -------------------------------------------------------
    def predict_master(self, rows) -> np.ndarray:
        z = np.zeros((len(rows), self.items))
        if self.x is not None:
            z += self.x[rows] @ self.w
        for msg in self.ch.gather(self.ch.members, "linreg/pred_z"):
            z += msg.tensor("z")
        return z

    def predict_member(self, rows) -> None:
        self.send_embed(self.predict_embed(rows), rows)

    def predict_embed(self, rows) -> np.ndarray:
        return self.x[rows] @ self.w

    def send_embed(self, z, rows) -> None:
        self.ch.send("master", "linreg/pred_z", {"z": np.asarray(z)})

    def evaluate_master(self, scores, rows) -> Dict[str, float]:
        return {"mse": float(np.mean((scores - self.y[rows]) ** 2))}

    def finalize(self) -> Dict:
        return {"w_master": self.w} if self.is_master else {"w": self.w}

    def state_dict(self) -> Dict:
        return {"w": None if self.w is None else self.w.copy()}

    def load_state_dict(self, state) -> None:
        self.w = None if state["w"] is None else state["w"].copy()
        if self.w is not None:
            self.items = self.w.shape[1]
