from repro.core.protocols.base import VFLConfig, PROTOCOLS  # noqa: F401
