from repro.core.protocols.base import (PROTOCOLS, VFLConfig,    # noqa: F401
                                       register, resolve_protocol)
from repro.core.protocols.driver import (Callback, Checkpointer,  # noqa: F401
                                         Driver, EarlyStopping,
                                         EvalEveryEpoch, MetricsStream,
                                         StopAtStep, VFLProtocol)
