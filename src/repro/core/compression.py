"""Compressed VFL exchanges: int8 quantization with error feedback.

Beyond-paper lever on the paper's own axis (compact serialization for
WAN silos, §2): bottom-model activations and the returned gradients are
sent as per-column-scaled int8 (4x smaller payloads than f32). Error
feedback keeps the quantization residual locally and adds it to the next
round's tensor, so the *accumulated* transmitted signal is unbiased —
split-NN training converges to the same region (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np


def quantize_int8(x: np.ndarray, axis: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-slice int8. Returns (q int8, scale f32)."""
    absmax = np.maximum(np.abs(x).max(axis=axis, keepdims=True), 1e-12)
    scale = (absmax / 127.0).astype(np.float32)
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


@dataclass
class ErrorFeedback:
    """Per-tag residual accumulator (one per sending party)."""

    residuals: Dict[str, np.ndarray] = field(default_factory=dict)

    def compress(self, tag: str, x: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        r = self.residuals.get(tag)
        xc = x + r if r is not None and r.shape == x.shape else x.copy()
        q, scale = quantize_int8(xc)
        self.residuals[tag] = xc - dequantize_int8(q, scale)
        return q, scale


def payload(q: np.ndarray, scale: np.ndarray) -> Dict[str, np.ndarray]:
    return {"q": q, "scale": scale}


def unpack(msg_payload: Dict[str, np.ndarray]) -> np.ndarray:
    return dequantize_int8(msg_payload["q"], msg_payload["scale"])
