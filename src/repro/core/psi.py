"""Private set intersection — the VFL record-matching phase (paper §1:
"the first phase aims to identify common samples across all
participants").

Two constructions:

- ``salted_hash_intersection`` — both parties hash IDs with a shared
  salt and compare digests (fast; hides IDs from eavesdroppers but not
  from each other — the paper's baseline matcher).
- ``DHPsi`` — Diffie-Hellman commutative-exponentiation PSI: each party
  blinds hashed IDs with a private exponent; double-blinded values are
  compared so neither party learns non-intersecting IDs.
"""
from __future__ import annotations

import hashlib
import secrets
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

# 512-bit safe prime (p = 2q+1), RFC 3526-style generation, fixed for
# reproducibility of the protocol transcript sizes. P_HEX is q itself
# (the search result is pinned: the previous seed value sat ~74k odd
# candidates before the first safe prime, costing ~30s of Miller-Rabin
# per process at import of the PSI group).
P_HEX = (
    "d6fce03bb15d1e6fbd4ac31f1e90bd6c05e08974ab7a1a23fcf25cb51e63ffff"
    "f8c4e3a9cbf0b2788d24d330b06cd7d1e1a1c339d8e9e19b219e8e834bb10cef"
)


def _safe_prime() -> int:
    # deterministic search from a fixed seed value for reproducibility
    q = int(P_HEX, 16) | 1
    from repro.core.he import _is_probable_prime
    while True:
        if _is_probable_prime(q) and _is_probable_prime(2 * q + 1):
            return 2 * q + 1
        q += 2


_P_CACHE: List[int] = []


def group_prime() -> int:
    if not _P_CACHE:
        _P_CACHE.append(_safe_prime())
    return _P_CACHE[0]


def _hash_to_group(item: str, p: int) -> int:
    h = int.from_bytes(hashlib.sha256(item.encode()).digest(), "big")
    return pow(h % p, 2, p)       # square -> quadratic residue subgroup


def salted_hash_intersection(ids_a: Sequence[str], ids_b: Sequence[str],
                             salt: str) -> List[str]:
    ha = {hashlib.sha256((salt + i).encode()).hexdigest(): i for i in ids_a}
    hb = {hashlib.sha256((salt + i).encode()).hexdigest() for i in ids_b}
    return sorted(i for h, i in ha.items() if h in hb)


@dataclass
class DHPsi:
    """One side of the DH-PSI protocol."""

    secret: int = field(default_factory=lambda: secrets.randbits(256) | 1)

    def blind(self, ids: Sequence[str]) -> List[int]:
        p = group_prime()
        return [pow(_hash_to_group(i, p), self.secret, p) for i in ids]

    def blind_again(self, blinded: Sequence[int]) -> List[int]:
        p = group_prime()
        return [pow(int(b), self.secret, p) for b in blinded]


def dh_psi(ids_a: Sequence[str], ids_b: Sequence[str]
           ) -> Tuple[List[str], int]:
    """Run both sides in-process (tests / local mode). Returns
    (intersection as A's ids, transcript elements exchanged)."""
    a, b = DHPsi(), DHPsi()
    ya = a.blind(ids_a)                 # A -> B
    yb = b.blind(ids_b)                 # B -> A
    yab = b.blind_again(ya)             # B -> A (double-blinded A ids)
    yba = a.blind_again(yb)             # A keeps
    common = set(yba) & set(yab)
    inter = [i for i, v in zip(ids_a, yab) if v in common]
    return sorted(inter), len(ya) + len(yb) + len(yab)
