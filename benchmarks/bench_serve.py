"""Load generator for the federated serving engine
(``repro.serve.federated``): closed- and open-loop traffic against a
split-NN federation served in-process, reporting sustained QPS and
p50/p99 latency on loopback and under LinkSpec WAN shaping.

Methodology (docs/serving.md):

* **Closed loop** — W worker threads each keep exactly one query in
  flight; QPS measures the engine's sustainable round rate under full
  coalescing pressure, latencies are honest end-to-end (admission ->
  demux) times.
* **Open loop** — a Poisson arrival process submits without waiting,
  so queue buildup (not worker count) shapes the tail; used for the
  WAN row where the round RTT dominates.
* **A/B cache discipline** — the Zipf-stream comparison interleaves
  cache-on/cache-off reps (2-core host, throughput drifts
  minute-to-minute) and reports the best rep of each arm, mirroring
  bench_vfl_async's min-over-reps protocol.
* Every (wire-)batch shape up to ``max_batch`` is warmed through the
  XLA jit cache before measurement — serving batches vary per round
  and a compile storm would otherwise land in the tail.

Gated rows (benchmarks/check_regression.py, ``vfl_serve_`` prefix):
``vfl_serve_qps`` (us_per_call = 1e6/QPS so lower stays better) and
``vfl_serve_p99_ms`` (us). The Zipf/WAN rows are informational.

Standalone: PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, List, Optional

import numpy as np

MAX_BATCH = 64
N_ROWS = 4096
CACHE_ROWS = 2048
ZIPF_A = 1.5


def _percentile(lat: List[float], q: float) -> float:
    if not lat:
        return 0.0
    s = sorted(lat)
    return s[min(len(s) - 1, int(q * len(s)))]


def _closed_loop(server, n_rows: int, duration_s: float, workers: int,
                 qrows: int, sampler: Callable) -> dict:
    """W threads, one in-flight query each; returns qps/p50/p99."""
    lat: List[float] = []
    lock = threading.Lock()
    stop = time.perf_counter() + duration_s

    def worker(widx: int) -> None:
        rng = np.random.default_rng(1000 + widx)
        mine = []
        while time.perf_counter() < stop:
            rows = sampler(rng, qrows, n_rows)
            t0 = time.perf_counter()
            server.query(rows)
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    t0 = time.perf_counter()
    ts = [threading.Thread(target=worker, args=(i,))
          for i in range(workers)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(duration_s + 60)
    wall = time.perf_counter() - t0
    return {"qps": len(lat) / wall, "p50": _percentile(lat, 0.50),
            "p99": _percentile(lat, 0.99), "queries": len(lat)}


def _open_loop(server, n_rows: int, duration_s: float, rate_qps: float,
               qrows: int, sampler: Callable) -> dict:
    """Poisson arrivals at ``rate_qps``; queue depth, not worker count,
    shapes the tail."""
    rng = np.random.default_rng(7)
    pendings = []
    stop = time.perf_counter() + duration_s
    while time.perf_counter() < stop:
        rows = sampler(rng, qrows, n_rows)
        try:
            pendings.append(server.submit(rows))
        except Exception:
            pass                      # shed (admission) — counted below
        time.sleep(rng.exponential(1.0 / rate_qps))
    lat = []
    for p in pendings:
        if p.done.wait(60) and p.err is None:
            lat.append(p.t_done - p.t_admit)
    return {"qps": len(lat) / duration_s,
            "p50": _percentile(lat, 0.50),
            "p99": _percentile(lat, 0.99), "queries": len(lat)}


def _uniform(rng, qrows: int, n: int) -> np.ndarray:
    return rng.integers(0, n, size=qrows)


def _zipf(rng, qrows: int, n: int) -> np.ndarray:
    return (rng.zipf(ZIPF_A, size=qrows) - 1) % n


def bench_serve(emit, quick: bool = False) -> None:
    caps = {"XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                         "intra_op_parallelism_threads=1",
            "OPENBLAS_NUM_THREADS": "1", "OMP_NUM_THREADS": "1"}
    saved = {k: os.environ.get(k) for k in caps}
    os.environ.update(caps)
    try:
        _bench_serve(emit, quick)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _make_job(cfg, widths, mode: str = "thread", comm_cfg=None):
    from repro.core.party import VFLJob
    from repro.data.vertical import vertical_partition

    rng = np.random.default_rng(0)
    d = sum(widths) + 6                 # master keeps a thin slice
    x = rng.normal(size=(N_ROWS, d))
    y = (x @ rng.normal(size=(d, 1)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(N_ROWS)]
    master, members = vertical_partition(ids, x, y, widths=widths,
                                         overlap=1.0, seed=1)
    kw = {"comm_cfg": comm_cfg} if comm_cfg is not None else {}
    job = VFLJob(cfg, master, members, mode=mode, **kw)
    job.fit()
    return job


def _warm_shapes(job, up_to: int = MAX_BATCH) -> None:
    """Compile every wire-batch shape once before measuring: dynamic
    coalescing + dedupe produce arbitrary row counts <= max_batch, and
    XLA compiles per shape."""
    job.serve_open()
    for k in range(1, up_to + 1):
        job.serve_query(rows=np.arange(k, dtype=np.int64))
    job.serve_close()


def _bench_serve(emit, quick: bool) -> None:
    from repro.comm.base import CommCfg, LinkSpec
    from repro.core.protocols.base import VFLConfig
    from repro.serve.federated import FederatedServer, ServeCfg

    scfg = ServeCfg(max_batch=MAX_BATCH, max_wait_ms=1.0,
                    admission_limit=8192)
    duration = 1.5 if quick else 3.0
    reps = 2 if quick else 3
    workers, qrows = 8, 8

    # -- loopback closed loop, uniform stream: engine throughput ------------
    # Thin bottom models so orchestration (admission -> coalesce ->
    # round -> demux), not matmul time, is what the row gates.
    cfg = VFLConfig(protocol="split_nn", epochs=1, batch_size=256,
                    lr=0.1, use_psi=False, embedding_dim=8,
                    hidden=(16,), seed=0, serve_cache_rows=0)
    job = _make_job(cfg, widths=[4, 3])
    _warm_shapes(job)
    base = None
    for _ in range(reps):
        with FederatedServer(job, scfg) as server:
            _closed_loop(server, N_ROWS, duration * 0.2, workers,
                         qrows, _uniform)           # settle the batcher
            r = _closed_loop(server, N_ROWS, duration, workers,
                             qrows, _uniform)
        if base is None or r["qps"] > base["qps"]:
            base = r
    emit("vfl_serve_qps", 1e6 / max(base["qps"], 1e-9),
         f"qps={base['qps']:.0f} workers={workers} qrows={qrows} "
         f"max_batch={MAX_BATCH} p50_ms={base['p50'] * 1e3:.2f}")
    emit("vfl_serve_p99_ms", base["p99"] * 1e6,
         f"p99_ms={base['p99'] * 1e3:.2f} "
         f"p50_ms={base['p50'] * 1e3:.2f} "
         f"tail_x{base['p99'] / max(base['p50'], 1e-9):.2f}")
    job.shutdown()

    # -- Zipf stream, heavy member towers: the cache's home turf ------------
    # Wide member slices + a thin master slice put the member bottom
    # forward on the round's critical path; the LRU then lifts hot rows
    # off it. Thread mode shares one VFLConfig across agents, so the
    # member cache toggles live between serve sessions.
    hcfg = VFLConfig(protocol="split_nn", epochs=1, batch_size=512,
                     lr=0.1, use_psi=False, embedding_dim=32,
                     hidden=(256,), seed=0, serve_cache_rows=0)
    hjob = _make_job(hcfg, widths=[512, 512])
    _warm_shapes(hjob)
    zbest = {"off": None, "on": None}
    for _ in range(reps):
        for arm in zbest:
            hcfg.serve_cache_rows = CACHE_ROWS if arm == "on" else 0
            with FederatedServer(hjob, scfg) as server:
                _closed_loop(server, N_ROWS, duration * 0.2, workers,
                             qrows, _zipf)
                r = _closed_loop(server, N_ROWS, duration, workers,
                                 qrows, _zipf)
            if zbest[arm] is None or r["qps"] > zbest[arm]["qps"]:
                zbest[arm] = r
    cache_x = zbest["on"]["qps"] / max(zbest["off"]["qps"], 1e-9)
    emit("vfl_serve_zipf_cache_qps",
         1e6 / max(zbest["on"]["qps"], 1e-9),
         f"qps={zbest['on']['qps']:.0f} "
         f"cache_off_qps={zbest['off']['qps']:.0f} "
         f"cache_x{cache_x:.2f} zipf_a={ZIPF_A} "
         f"cache_rows={CACHE_ROWS}")
    hcfg.serve_cache_rows = 0
    hjob.shutdown()

    # -- WAN shaping: open-loop Poisson at a fixed offered rate -------------
    wan_cfg = VFLConfig(protocol="split_nn", epochs=1, batch_size=256,
                        lr=0.1, use_psi=False, embedding_dim=8,
                        hidden=(16,), seed=0)
    wan_job = _make_job(wan_cfg, widths=[4, 3], mode="grpc",
                        comm_cfg=CommCfg(link=LinkSpec(latency_ms=10.0)))
    _warm_shapes(wan_job)
    with FederatedServer(wan_job, scfg) as server:
        r = _open_loop(server, N_ROWS, duration, rate_qps=200.0,
                       qrows=qrows, sampler=_uniform)
    wan_job.shutdown()
    emit("vfl_serve_wan_p99_ms", r["p99"] * 1e6,
         f"qps={r['qps']:.0f} offered=200 rtt_ms=20 "
         f"p50_ms={r['p50'] * 1e3:.2f} p99_ms={r['p99'] * 1e3:.2f} "
         f"open_loop=poisson")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.2f},{derived}", flush=True)

    bench_serve(emit, args.quick)


if __name__ == "__main__":
    main()
