"""Transformer-tower split-NN under pipelining (DESIGN.md §12): the
workload the tower factory exists for — member compute AND exchange
both non-trivial, measured with the driver's per-step roofline
accounting.

Workload: one member with an embed + attn_block + mlp tower
(`TowerSpec`, ~0.4 GFLOP forward per 512-row step) and a 128 KiB
float32 activation exchange per step, over real TCP sockets with one
OS process per agent (``socket_proc``), the link shaped to a
10 Mbit/s, 10 ms WAN profile — sized on the 2-core CI host so
per-step compute and wire time are the same order (each ≥ 25% of the
step in the committed baseline). Depth 1 is lock-step; depth 2
overlaps the member's forward with the in-flight exchange — the
pipeline win the roofline split explains.

Methodology (the bench-discipline note in ROADMAP.md):

* each agent process capped at 1 compute thread (per-silo hardware
  emulation; uncapped XLA pools thrash the 2-core host),
* depths interleaved, per-depth MIN over reps (host throughput
  drifts minute-to-minute; interleaving samples both arms under the
  same conditions),
* steady-state per-step time from the master's wall stamps, first
  steps skipped (per-process jit compile + pipeline fill).

Gated rows (benchmarks/check_regression.py, ``vfl_tower_`` prefix):
``vfl_tower_splitnn_d1`` and ``vfl_tower_splitnn_d2``; the d2 row's
``derived`` carries the member's roofline split (compute_frac /
wire_frac) and the d2-vs-d1 speedup. The ``vfl_tower_roofline_*``
rows are informational (per-step compute seconds per role).

Standalone: PYTHONPATH=src python -m benchmarks.bench_tower [--quick]
"""
from __future__ import annotations

import os

import numpy as np

N_ROWS = 4096
BATCH = 512
WIDTHS = [48]
EMBED_DIM = 64
TOWER = ("embed:tokens=8,dim=64", "attn_block:heads=4",
         "mlp:hidden=64")
TOP_TOWER = ("mlp:hidden=64,final_act=0",)
# WAN shape: 131 KiB activations take ~105 ms at 10 Mbit/s — the same
# order as the ~145 ms member forward+backward on the CI host
LATENCY_MS = 10.0
BANDWIDTH_MBPS = 10.0


def bench_tower(emit, quick: bool = False) -> None:
    caps = {"XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                         "intra_op_parallelism_threads=1",
            "OPENBLAS_NUM_THREADS": "1", "OMP_NUM_THREADS": "1"}
    saved = {k: os.environ.get(k) for k in caps}
    os.environ.update(caps)        # spawned agents inherit
    try:
        _bench_tower(emit, quick)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _steady_us(history, skip: int) -> float:
    h = history
    skip = min(skip, len(h) - 2)
    return (h[-1]["wall_s"] - h[skip]["wall_s"]) / \
        (len(h) - 1 - skip) * 1e6


def _bench_tower(emit, quick: bool) -> None:
    from repro.comm.base import CommCfg, LinkSpec
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition

    rng = np.random.default_rng(0)
    items = 8
    d = sum(WIDTHS) + 16
    x = rng.normal(size=(N_ROWS, d))
    y = (x @ rng.normal(size=(d, items)) > 0).astype(np.float64)
    ids = [f"u{i:06d}" for i in range(N_ROWS)]
    master, members = vertical_partition(ids, x, y, widths=WIDTHS,
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="split_nn", epochs=1 if quick else 2,
                    batch_size=BATCH, lr=0.05, use_psi=False,
                    embedding_dim=EMBED_DIM, tower=TOWER,
                    top_tower=TOP_TOWER)
    link = CommCfg(link=LinkSpec(latency_ms=LATENCY_MS,
                                 bandwidth_mbps=BANDWIDTH_MBPS))

    per_step = {1: float("inf"), 2: float("inf")}
    info: dict = {}
    roof: dict = {}
    for _ in range(2 if quick else 3):
        for depth in per_step:
            res = run_vfl(cfg, master, members, mode="socket_proc",
                          pipeline_depth=depth, comm_cfg=link)
            h = res["master"]["history"]
            us = _steady_us(h, skip=4)
            if us < per_step[depth]:
                per_step[depth] = us
                info[depth] = f"steps={len(h)} loss={h[-1]['loss']:.4f}"
                roof[depth] = {r: res[r]["roofline"]
                               for r in ("master", "member0")}
    for depth, us in per_step.items():
        m0 = roof[depth]["member0"]
        extra = "" if depth == 1 else \
            f" speedup_x{per_step[1] / max(us, 1e-9):.2f}"
        emit(f"vfl_tower_splitnn_d{depth}", us,
             f"{info[depth]} mode=socket_proc "
             f"wan={LATENCY_MS:.0f}ms/{BANDWIDTH_MBPS:.0f}Mbps "
             f"member_compute_frac={m0['compute_frac']:.2f} "
             f"member_wire_frac={m0['wire_frac']:.2f}{extra}")
    # informational: the per-role roofline split behind the d2 win
    for role in ("master", "member0"):
        r = roof[2][role]
        emit(f"vfl_tower_roofline_{role}",
             r["compute_s_per_step"] * 1e6,
             f"d2 wall_us={r['wall_s_per_step'] * 1e6:.0f} "
             f"compute_frac={r['compute_frac']:.2f} "
             f"wire_frac={r['wire_frac']:.2f} "
             f"stall_frac={r['stall_frac']:.2f} "
             f"flops_per_step={r['model_flops_per_step']:.3g} "
             f"exch_intensity={r.get('exchange_intensity', 0):.0f}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    def emit(name, us, derived):
        print(f"{name},{us:.2f},{derived}")

    print("name,us_per_call,derived")
    bench_tower(emit, args.quick)
