"""Benchmark harness — one function per paper table/figure + the
roofline aggregation. Prints ``name,us_per_call,derived`` CSV.

Paper artifacts covered:
  Table 1  -> bench_table1_demo (SBOL-statistics demo workload: losses +
              communication volume per protocol)
  Fig. 1   -> bench_comm_modes (communication layer: per-mode exchange
              latency), bench_codec (the Protobuf+Safetensors choice),
              bench_he / bench_psi (protocol-layer crypto costs)
  (ours)   -> bench_kernels (Pallas kernels vs oracles),
              bench_roofline (dry-run roofline terms per arch x shape)

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import pickle
import threading
import time
from typing import Callable, List, Tuple

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}")


# build-once synthetic dataset cache: every bench that needs a dataset
# pulls it from here, so repeated rows (and repeated reps of the
# interleaved A/B protocol) never pay generation again, and the build
# cost is visible as its own ``dataset_build_*`` row instead of
# polluting a workload row (WAN rows measure exchange, not data gen)
_FIXTURES: dict = {}


def dataset_fixture(name: str, builder: Callable):
    if name not in _FIXTURES:
        t0 = time.perf_counter()
        _FIXTURES[name] = builder()
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"dataset_build_{name}", dt, "shared fixture, built once")
    return _FIXTURES[name]


def _timeit(fn: Callable, n: int = 5) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------


def bench_codec():
    from repro.comm import codec
    x = {"t": np.random.default_rng(0).normal(size=(512, 512))
         .astype(np.float32)}
    blob = codec.encode(x)
    us_enc = _timeit(lambda: codec.encode(x), 20)
    us_dec = _timeit(lambda: codec.decode(blob), 20)
    us_pkl = _timeit(lambda: pickle.dumps(x), 20)
    emit("codec_encode_1MB", us_enc, f"bytes={len(blob)}")
    emit("codec_decode_1MB", us_dec, f"vs_pickle_x{us_pkl/max(us_enc,1):.2f}")


def roundtrip(ca, cb, payload, n=10):
    def echo():
        for i in range(n):
            m = cb.recv("a", f"m{i}")
            cb.send("a", f"r{i}", m.payload)
    t = threading.Thread(target=echo)
    t.start()
    t0 = time.perf_counter()
    for i in range(n):
        ca.send("b", f"m{i}", payload)
        ca.recv("b", f"r{i}")
    dt = (time.perf_counter() - t0) / n * 1e6
    t.join()
    return dt


def bench_comm_modes():
    from repro.comm.grpc import GrpcCommunicator
    from repro.comm.local import ThreadBus
    from repro.comm.sock import SocketCommunicator, local_addresses
    payload = {"x": np.zeros((256, 256), np.float32)}   # 256 KiB
    # the Nagle satellite rows use small control-sized messages
    # (delayed-ACK interaction dominated the seed's small-message
    # latency); the others compare framings at exchange size
    small = {"x": np.zeros((32,), np.float32)}

    bus = ThreadBus(["a", "b"])
    pairs = {"thread": (bus.communicator("a"), bus.communicator("b"))}
    for name, cls in (("socket", SocketCommunicator),
                      ("grpc", GrpcCommunicator)):
        addrs = local_addresses(["a", "b"])
        pairs[name] = (cls("a", addrs), cls("b", addrs))
    for name, nodelay in (("nagle", False), ("nodelay", True)):
        addrs = local_addresses(["a", "b"])
        pairs[name] = (SocketCommunicator("a", addrs, nodelay=nodelay),
                       SocketCommunicator("b", addrs, nodelay=nodelay))
    best = {k: float("inf") for k in pairs}
    try:
        # interleaved min-over-reps (the 2-core-host protocol, same as
        # bench_vfl_async): one rep of every config per round, so
        # capacity drift hits all configs alike and the reported min is
        # comparable across runs — these rows feed the CI
        # bench-regression gate (benchmarks/check_regression.py)
        for _ in range(3):
            for name, (ca, cb) in pairs.items():
                p = small if name in ("nagle", "nodelay") else payload
                n = 20 if name in ("nagle", "nodelay") else 10
                best[name] = min(best[name], roundtrip(ca, cb, p, n=n))
    finally:
        for name, (ca, cb) in pairs.items():
            if name != "thread":
                ca.close(); cb.close()
    emit("comm_roundtrip_thread_256KiB", best["thread"], "mode=thread")
    emit("comm_roundtrip_socket_256KiB", best["socket"], "mode=socket")
    emit("comm_socket_small_nagle", best["nagle"], "nodelay=off")
    # loopback ACKs immediately, so Nagle rarely stalls here — the row
    # records the before/after so real-link runs (where delayed ACK
    # costs up to 40ms per small exchange) have a baseline
    emit("comm_socket_small_nodelay", best["nodelay"],
         f"speedup_x{best['nagle'] / max(best['nodelay'], 1e-9):.2f}"
         f" (loopback; guards WAN delayed-ACK stalls)")
    # gRPC-framed transport vs length-prefix framing: same safetensors
    # payloads, HTTP/2-like frames (DESIGN.md §8.1)
    emit("comm_roundtrip_grpc_256KiB", best["grpc"], "mode=grpc")


def bench_encode_offload():
    """Caller-visible isend cost: inline encode vs sender-thread encode
    offload (DESIGN.md §8.3). The offload row measures what the
    master's critical path actually pays per isend — the snapshot copy
    — instead of the full safetensors serialization. Interleaved,
    min-over-reps (2-core host, noisy)."""
    from repro.comm.base import CommCfg
    from repro.comm.local import ThreadBus

    payload = {"x": np.random.default_rng(0).normal(size=(1024, 512))}
    pairs = {}
    for offload in (False, True):
        bus = ThreadBus(["a", "b"])
        ca = bus.communicator(
            "a", comm_cfg=CommCfg(encode_offload=offload))
        cb = bus.communicator("b")
        ca.isend("b", "w", payload).result(30)     # warm the sender
        cb.recv("a", "w")
        pairs[offload] = (ca, cb)
    best = {False: float("inf"), True: float("inf")}
    for _ in range(3):
        for offload, (ca, cb) in pairs.items():
            t0 = time.perf_counter()
            fut = ca.isend("b", "t", payload)
            dt = (time.perf_counter() - t0) * 1e6
            fut.result(30)
            cb.recv("a", "t")
            best[offload] = min(best[offload], dt)
    emit("comm_isend_encode_inline", best[False],
         "payload=4MiB caller-blocked-us")
    emit("comm_isend_encode_offload", best[True],
         f"payload=4MiB caller-blocked-us "
         f"speedup_x{best[False] / max(best[True], 1e-9):.2f}")


def _recsys_demo_data():
    from repro.configs.vfl_recsys import VFLRecsysConfig
    from repro.core.protocols.base import MasterData, MemberData
    from repro.data.synthetic import make_recsys_silos
    data = make_recsys_silos(VFLRecsysConfig().reduced(), seed=0)
    master = MasterData(data.ids, data.labels.astype(np.float64),
                        data.features)
    members = [MemberData(i, x) for i, x in
               zip(data.member_ids, data.member_features)]
    return master, members


def bench_table1_demo(quick: bool):
    from repro.core.party import run_vfl
    from repro.core.protocols.base import MasterData, VFLConfig
    master, members = dataset_fixture("recsys_demo", _recsys_demo_data)
    for proto, epochs, lr in (("linreg", 3, 0.05), ("split_nn", 3, 0.3)):
        cfg = VFLConfig(protocol=proto, epochs=epochs, batch_size=64,
                        lr=lr, use_psi=False, embedding_dim=16)
        t0 = time.perf_counter()
        res = run_vfl(cfg, master, members, mode="thread")
        dt = (time.perf_counter() - t0) * 1e6
        h = res["master"]["history"]
        emit(f"demo_{proto}", dt / max(len(h), 1),
             f"loss {h[0]['loss']:.4f}->{h[-1]['loss']:.4f} "
             f"bytes={res['master']['comm']['sent_bytes']}")
    if not quick:
        import dataclasses
        yb = master.y[:, :1]
        cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32,
                        lr=0.5, use_psi=False, he_bits=256)
        rows = {}
        for packed in (False, True):
            c = dataclasses.replace(cfg, he_packed=packed)
            t0 = time.perf_counter()
            res = run_vfl(c, MasterData(master.ids, yb, master.x),
                          members, mode="thread")
            dt = (time.perf_counter() - t0) * 1e6
            h = res["master"]["history"]
            rows[packed] = (dt / max(len(h), 1), h,
                            res["arbiter"]["decrypted_values"])
        us_s, h, dec_s = rows[False]
        emit("demo_logreg_he_scalar", us_s,
             f"loss {h[0]['loss']:.4f}->{h[-1]['loss']:.4f} "
             f"decrypted={dec_s}")
        us_p, h, dec_p = rows[True]
        emit("demo_logreg_he", us_p,
             f"loss {h[0]['loss']:.4f}->{h[-1]['loss']:.4f} "
             f"decrypted={dec_p} speedup_x{us_s / max(us_p, 1):.2f} "
             f"decrypt_drop_x{dec_s / max(dec_p, 1):.2f}")


def bench_he():
    from repro.core import he
    pub, priv = he.keygen(256)
    us = _timeit(lambda: pub.encrypt_int(12345), 20)
    emit("paillier_encrypt_256b", us, "key=256bit")
    pool = he.RandomnessPool(pub)
    us_pool = _timeit(lambda: pool.encrypt_int(12345), 20)
    emit("paillier_encrypt_pooled_256b", us_pool,
         f"speedup_x{us / max(us_pool, 1e-9):.2f}")
    c = pub.encrypt_int(12345)
    us_plain = _timeit(lambda: priv.decrypt_int_plain(c), 20)
    emit("paillier_decrypt_256b", us_plain, "")
    us_crt = _timeit(lambda: priv.decrypt_int_crt(c), 20)
    emit("paillier_decrypt_crt_256b", us_crt,
         f"speedup_x{us_plain / max(us_crt, 1e-9):.2f}")
    emit("paillier_add", _timeit(lambda: pub.add(c, c), 50), "")


def bench_he_packed(quick: bool = False):
    """Packed-vs-scalar homomorphic matvec + packing-factor sweep."""
    from repro.core import he
    rng = np.random.default_rng(0)
    b, d = 32, 32
    x = rng.normal(size=(b, d))
    r = rng.normal(size=b) / b
    x_int = he.encode_fixed(x).reshape(b, d)
    r_int = he.encode_fixed(r)
    rb = int(np.abs(r_int).max())
    for bits in ((256,) if quick else (256, 512)):
        pub, priv = he.keygen(bits)
        ciphers = [pub.encrypt_int(int(v)) for v in r_int]
        c_arr = np.array(ciphers, dtype=object)

        def scalar():
            cts = he.matvec_cipher(pub, x, c_arr)
            return [priv.decrypt_int_plain(int(v)) for v in cts]

        def packed():
            cts, info = he.packed_matvec(pub, x_int, ciphers, rb)
            return he.unpack_matvec([priv.decrypt_int(v) for v in cts],
                                    info["slot_bits"], info["k"],
                                    info["off_bits"], d)

        assert packed() == scalar(), "paths must agree exactly"
        us_s = _timeit(scalar, 2)
        us_p = _timeit(packed, 2)
        info = he.matvec_slot_plan(pub, x_int, rb)
        emit(f"he_matvec_scalar_{bits}b", us_s, f"B={b} d={d}")
        emit(f"he_matvec_packed_{bits}b", us_p,
             f"K={info['k']} slot_bits={info['slot_bits']} "
             f"speedup_x{us_s / max(us_p, 1e-9):.2f}")


def bench_psi():
    from repro.core import psi
    ids_a = [f"u{i}" for i in range(300)]
    ids_b = [f"u{i}" for i in range(150, 450)]
    us = _timeit(lambda: psi.salted_hash_intersection(ids_a, ids_b, "s"), 5)
    emit("psi_salted_300ids", us, "inter=150")
    us = _timeit(lambda: psi.dh_psi(ids_a[:60], ids_b[:60]), 2)
    emit("psi_dh_60ids", us, "")


def bench_kernels(quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.key(0), 5)
    b, h, s, dh = 1, 4, 256, 64
    q = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, 2, s, dh))
    v = jax.random.normal(ks[2], (b, 2, s, dh))

    def run():
        return jax.block_until_ready(
            ops.flash_attention(q, k, v, interpret=True))
    err = float(jnp.abs(run() - ref.attention_ref(q, k, v)).max())
    emit("kernel_flash_attention_256", _timeit(run, 3 if quick else 5),
         f"max_err={err:.2e}")

    dt = jax.nn.softplus(jax.random.normal(ks[0], (1, 128, 64))) * 0.1
    bm = jax.random.normal(ks[1], (1, 128, 8))
    cm = jax.random.normal(ks[2], (1, 128, 8))
    u = jax.random.normal(ks[3], (1, 128, 64))
    a = -jnp.exp(jax.random.normal(ks[4], (64, 8)) * 0.5)

    def run2():
        return jax.block_until_ready(
            ops.selective_scan(dt, bm, cm, u, a, interpret=True)[0])
    y2, _ = ref.selective_scan_ref(dt, bm, cm, u, a)
    err = float(jnp.abs(run2() - y2).max())
    emit("kernel_selective_scan_128", _timeit(run2, 3), f"max_err={err:.2e}")

    r_ = jax.random.normal(ks[0], (1, 2, 128, 32))
    w_ = jax.nn.sigmoid(jax.random.normal(ks[3], (1, 2, 128, 32))) * 0.5 + 0.4
    u_ = jax.random.normal(ks[4], (2, 32)) * 0.3

    def run3():
        return jax.block_until_ready(
            ops.rwkv6_wkv(r_, r_, r_, w_, u_, interpret=True)[0])
    y3, _ = ref.rwkv6_ref(r_, r_, r_, w_, u_)
    err = float(jnp.abs(run3() - y3).max())
    emit("kernel_rwkv6_wkv_128", _timeit(run3, 3), f"max_err={err:.2e}")

    x = jax.random.normal(ks[0], (4, 128, 64))
    wm = jax.random.normal(ks[1], (4, 64, 128))

    def run4():
        return jax.block_until_ready(
            ops.moe_gmm(x, wm, block_d=64, interpret=True))
    err = float(jnp.abs(run4() - ref.gmm_ref(x, wm)).max())
    emit("kernel_moe_gmm_4x128", _timeit(run4, 3), f"max_err={err:.2e}")

    xq = jax.random.normal(ks[2], (512, 128)) * 2

    def run5():
        return jax.block_until_ready(ops.quantize_int8(xq,
                                                       interpret=True)[0])
    qk = run5()
    qr, _ = ref.quantize_int8_ref(xq)
    emit("kernel_quantize_int8_512", _timeit(run5, 3),
         f"exact={bool((qk == qr).all())}")


def _seed_linreg_roles(master, members, cfg):
    """The pre-lifecycle seed loop, reconstructed: hand-rolled role
    functions over raw communicators with stringly step tags and no
    driver ctrl rounds. Kept here as the baseline the driver-overhead
    row is measured against."""
    import threading

    from repro.comm.local import ThreadBus
    from repro.comm.schema import TypedChannel
    from repro.core.protocols import base

    def master_fn(comm, data):
        ch = TypedChannel(comm)          # match phase needs typed tags
        order = base.master_match(ch, data, cfg)
        y = base._select(data.ids, order, data.y)
        x = base._select(data.ids, order, data.x)
        n, items = y.shape
        comm.send("member0", "setup", {"items": np.array([items])})
        w = np.zeros((x.shape[1], items))
        history = []
        step = 0
        # time the training loop alone (the lifecycle row compares
        # against the driver's fit-phase timer, so the windows match),
        # and do the same loss/history work the seed master did
        t0 = time.perf_counter()
        for epoch in range(cfg.epochs):
            for rows in base.batches(n, cfg, epoch):
                zb = x[rows] @ w
                zb += comm.recv("member0", f"z/{step}").tensor("z")
                r = (zb - y[rows]) / len(rows)
                comm.send("member0", f"resid/{step}", {"r": r})
                w -= cfg.lr * (x[rows].T @ r)
                loss = float(0.5 * np.mean((zb - y[rows]) ** 2))
                history.append({"step": step, "epoch": epoch,
                                "loss": loss})
                step += 1
        loop_s = time.perf_counter() - t0
        comm.send("member0", "done", {"ok": np.array([1])})
        return step, loop_s

    def member_fn(comm, data):
        ch = TypedChannel(comm)
        order = base.member_match(ch, data, cfg)
        x = base._select(data.ids, order, data.x)
        n = len(order)
        items = int(comm.recv("master", "setup").tensor("items")[0])
        w = np.zeros((x.shape[1], items))
        step = 0
        for epoch in range(cfg.epochs):
            for rows in base.batches(n, cfg, epoch):
                comm.send("master", f"z/{step}", {"z": x[rows] @ w})
                r = comm.recv("master", f"resid/{step}").tensor("r")
                w -= cfg.lr * (x[rows].T @ r)
                step += 1
        comm.recv("master", "done")

    bus = ThreadBus(["master", "member0"])
    out = {}

    def run_master():
        out["steps"], out["loop_s"] = master_fn(
            bus.communicator("master"), master)
    t = threading.Thread(target=run_master)
    t.start()
    member_fn(bus.communicator("member0"), members[0])
    t.join()
    return out["steps"], out["loop_s"]


def bench_driver_overhead():
    """Lifecycle-API cost vs the seed loop: the shared driver adds one
    small ctrl broadcast per batch + callback dispatch; this row tracks
    that overhead (steps/sec both ways) from day one."""
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition

    def _build():
        rng = np.random.default_rng(0)
        n, d = 512, 16
        x = rng.normal(size=(n, d))
        y = x @ rng.normal(size=(d, 2)) * 0.3
        ids = [f"u{i:05d}" for i in range(n)]
        return vertical_partition(ids, x, y, widths=[6],
                                  overlap=1.0, seed=1)
    master, members = dataset_fixture("linreg_512x16", _build)
    cfg = VFLConfig(protocol="linreg", epochs=4, batch_size=32, lr=0.05,
                    use_psi=False)

    steps, dt_seed = _seed_linreg_roles(master, members, cfg)
    t0 = time.perf_counter()
    res = run_vfl(cfg, master, members, mode="thread")
    dt_total = time.perf_counter() - t0
    dt_fit = res["master"]["phase_s"]["fit"]
    new_steps = len(res["master"]["history"])
    assert new_steps == steps, (new_steps, steps)
    emit("vfl_driver_seed_loop", dt_seed / steps * 1e6,
         f"steps_per_s={steps / dt_seed:.0f}")
    emit("vfl_driver_lifecycle", dt_fit / new_steps * 1e6,
         f"steps_per_s={new_steps / dt_fit:.0f} "
         f"fit_overhead_x{dt_fit / max(dt_seed, 1e-9):.2f} "
         f"job_total_s={dt_total:.2f}")


def bench_vfl_scaling():
    """Comm volume vs number of member silos (paper: multi-member VFL)."""
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition
    n, items = 192, 2

    def _build():
        rng = np.random.default_rng(0)
        out = {}
        for m in (1, 2, 4):
            d = 6 + 4 * m
            x = rng.normal(size=(n, d))
            y = x @ rng.normal(size=(d, items)) * 0.3
            ids = [f"u{i:05d}" for i in range(n)]
            out[m] = vertical_partition(ids, x, y, widths=[4] * m,
                                        seed=1)
        return out
    silos = dataset_fixture("scaling_192", _build)
    for n_members in (1, 2, 4):
        master, members = silos[n_members]
        cfg = VFLConfig(protocol="split_nn", epochs=1, batch_size=48,
                        lr=0.1, use_psi=False, embedding_dim=8,
                        hidden=(16,))
        t0 = time.perf_counter()
        res = run_vfl(cfg, master, members, mode="thread")
        dt = (time.perf_counter() - t0) * 1e6
        emit(f"vfl_scaling_{n_members}members", dt,
             f"master_bytes={res['master']['comm']['sent_bytes']}")


def bench_compression():
    """int8 exchange compression: payload + quality deltas."""
    import dataclasses

    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition
    def _build():
        rng = np.random.default_rng(0)
        n, d = 192, 12
        x = rng.normal(size=(n, d))
        y = (x @ rng.normal(size=(d, 3)) > 0).astype(np.float64)
        ids = [f"u{i:05d}" for i in range(n)]
        return vertical_partition(ids, x, y, widths=[5], seed=1)
    master, members = dataset_fixture("compress_192x12", _build)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=48, lr=0.1,
                    use_psi=False, embedding_dim=8, hidden=(16,))
    for compress in (False, True):
        c = dataclasses.replace(cfg, compress=compress)
        t0 = time.perf_counter()
        res = run_vfl(c, master, members, mode="thread")
        dt = (time.perf_counter() - t0) * 1e6
        h = res["master"]["history"]
        emit(f"vfl_exchange_compress={compress}", dt,
             f"loss={h[-1]['loss']:.4f} "
             f"member_bytes={res['member0']['comm']['sent_bytes']}")


def _steady_us(history, skip: int) -> float:
    """Per-step µs from the master's wall_s stamps, skipping the first
    ``skip`` steps (jit compile + pipeline fill)."""
    h = history
    skip = min(skip, len(h) - 2)
    return (h[-1]["wall_s"] - h[skip]["wall_s"]) / \
        (len(h) - 1 - skip) * 1e6


def bench_vfl_async(quick: bool):
    """Async exchange engine (DESIGN.md §7): demo-scale split_nn over
    real TCP sockets with one OS process per agent (``socket_proc`` —
    the paper's distributed deployment) at pipeline depth 1/2/4. Depth
    1 is the synchronous lock-step baseline; depth >= 2 lets the member
    run its forward stage ahead so each party's (de)serialization, wire
    writes and compute overlap the peer's round. The workload is
    exchange-dominated (1 MiB activations per step, compact bottom
    models) — the cross-silo regime the async engine targets. Each
    agent process is capped to one compute thread (per-silo hardware
    emulation: a real deployment doesn't share cores between silos;
    uncapped, 4 XLA thread pools thrash this host's 2 cores and the
    measurement is noise). Steady-state per-step time, first steps
    skipped (per-process jit compile + pipeline fill). Plus the
    ``vfl_async_splitnn_wan_d*`` rows — the same workload under a
    LinkSpec-shaped 40 ms-RTT link (DESIGN.md §8.2), where the
    pipeline-depth win is measurable beyond loopback — and the
    logreg_he rows (DESIGN.md §10): the HE decrypt round against a
    remote arbiter on the same shaped link, serial (d1) vs the full
    pipeline stack (d2: announce window + deferred gradient apply +
    streamed ciphertext chunks + decrypt worker pool), over raw
    process sockets (``_overlap_``) and gRPC framing (``_wan_``)."""
    import os

    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition

    caps = {"XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                         "intra_op_parallelism_threads=1",
            "OPENBLAS_NUM_THREADS": "1", "OMP_NUM_THREADS": "1"}
    saved = {k: os.environ.get(k) for k in caps}
    os.environ.update(caps)        # spawned agents inherit
    try:
        def _build():
            rng = np.random.default_rng(0)
            n, items = 8192, 8
            widths = [32]
            d = sum(widths) + 32
            x = rng.normal(size=(n, d))
            y = (x @ rng.normal(size=(d, items)) > 0) \
                .astype(np.float64)
            ids = [f"u{i:06d}" for i in range(n)]
            silos = vertical_partition(ids, x, y, widths=widths,
                                       overlap=1.0, seed=1)
            # raw arrays kept alongside the partition: the HE-overlap
            # fixture below slices them instead of re-drawing
            return {"ids": ids, "x": x, "y": y, "silos": silos}
        master, members = dataset_fixture("async_8192x64",
                                          _build)["silos"]
        cfg = VFLConfig(protocol="split_nn", epochs=2, batch_size=1024,
                        lr=0.05, use_psi=False, embedding_dim=256,
                        hidden=(32,))
        # depths are interleaved and the per-depth MIN over reps is
        # reported: the host's throughput drifts minute-to-minute, and
        # interleaving samples every depth under the same conditions
        per_step = {1: float("inf"), 2: float("inf"), 4: float("inf")}
        info = {}
        for _ in range(2 if quick else 4):
            for depth in per_step:
                res = run_vfl(cfg, master, members, mode="socket_proc",
                              pipeline_depth=depth)
                h = res["master"]["history"]
                per_step[depth] = min(per_step[depth],
                                      _steady_us(h, skip=4))
                info[depth] = f"steps={len(h)} loss={h[-1]['loss']:.4f}"
        for depth, us in per_step.items():
            extra = "" if depth == 1 else \
                f" speedup_x{per_step[1] / max(us, 1e-9):.2f}"
            emit(f"vfl_async_splitnn_socket_d{depth}", us,
                 f"{info[depth]} mode=socket_proc{extra}")

        # WAN emulation (DESIGN.md §8.2): the same exchange-dominated
        # split-NN over the gRPC-framed transport with LinkSpec 20 ms
        # one-way latency (40 ms RTT) on every link. Depth 1 pays
        # RTT + compute per step, serialized; depth >= 2 overlaps the
        # in-flight exchange with the master's round, which is where
        # the pipeline win becomes visible beyond loopback.
        # Threads-in-one-process (mode="grpc") keeps process-spawn cost
        # out of the short runs; the RTT dwarfs the GIL.
        from repro.comm.base import CommCfg, LinkSpec
        wan = CommCfg(link=LinkSpec(latency_ms=20.0))
        wan_step = {1: float("inf"), 2: float("inf"), 4: float("inf")}
        wan_info = {}
        for _ in range(1 if quick else 2):
            for depth in wan_step:
                res = run_vfl(cfg, master, members, mode="grpc",
                              pipeline_depth=depth, comm_cfg=wan)
                h = res["master"]["history"]
                wan_step[depth] = min(wan_step[depth],
                                      _steady_us(h, skip=4))
                wan_info[depth] = f"steps={len(h)} " \
                                  f"loss={h[-1]['loss']:.4f}"
        for depth, us in wan_step.items():
            extra = "" if depth == 1 else \
                f" speedup_x{wan_step[1] / max(us, 1e-9):.2f}"
            emit(f"vfl_async_splitnn_wan_d{depth}", us,
                 f"{wan_info[depth]} rtt_ms=40 mode=grpc{extra}")

        # HE decryption pipeline (DESIGN.md §10): logreg_he with the
        # arbiter on the far side of a LinkSpec-shaped 40 ms-RTT link —
        # the deployment the pipeline targets (a trusted third party is
        # rarely co-located with the silos). The d1 row is the serial
        # seed stack: every step pays z-gather + Enc(r) broadcast +
        # enc-grad upload + decrypt + grad return, four shaped wire
        # legs strictly serialized with the compute. The d2 row turns
        # the whole stack on — depth-2 announce window, deferred
        # gradient apply (the member ships round t's ciphertexts before
        # consuming round t-1's gradient), streamed enc-grad chunks and
        # a 1-process arbiter decrypt pool — so the wire legs and the
        # arbiter's decrypt ride under member/master compute. On this
        # single-core host the overlap_x factor measures exactly that
        # latency hiding (compute cannot parallelize with itself);
        # depth-1 results stay bit-identical to the serial decrypt
        # path (tests/test_he_pipeline.py). One OS process per agent,
        # 1 compute thread each (caps above).
        from repro.comm.base import CommCfg as _CommCfg
        from repro.comm.base import LinkSpec as _LinkSpec

        def _build_he():
            d = dataset_fixture("async_8192x64", _build)  # cache hit
            yb = d["y"][:, :1]
            return vertical_partition(d["ids"][:1024], d["x"][:1024],
                                      yb[:1024], widths=[32], seed=2)
        m1, mem1 = dataset_fixture("async_he_1024x64", _build_he)
        hcfg = VFLConfig(protocol="logreg_he", epochs=1,
                         batch_size=64 if quick else 128, lr=0.5,
                         use_psi=False, he_bits=256)
        he_link = _CommCfg(link=_LinkSpec(latency_ms=20.0))
        he_piped = dataclasses.replace(hcfg, pipeline_depth=2,
                                       he_stream_chunks=4,
                                       he_decrypt_workers=1)
        he_step = {1: float("inf"), 2: float("inf")}
        he_info = {}
        for _ in range(1 if quick else 2):
            for depth, c in ((1, hcfg), (2, he_piped)):
                res = run_vfl(c, m1, mem1, mode="process",
                              pipeline_depth=depth, comm_cfg=he_link)
                h = res["master"]["history"]
                he_step[depth] = min(he_step[depth],
                                     _steady_us(h, skip=1))
                he_info[depth] = f"steps={len(h)} rtt_ms=40 mode=process"
        for depth, us in he_step.items():
            extra = "" if depth == 1 else \
                f" overlap_x{he_step[1] / max(us, 1e-9):.2f}"
            emit(f"vfl_async_logreg_he_overlap_d{depth}", us,
                 f"{he_info[depth]}{extra}")

        # the same HE stack over the gRPC-framed transport at the same
        # 40 ms RTT (threads-in-one-process, like the splitnn WAN rows:
        # spawn cost out, the RTT dwarfs the GIL) — the cross-silo WAN
        # number comparable against vfl_async_splitnn_wan_d*
        hw_step = {1: float("inf"), 2: float("inf")}
        hw_info = {}
        for _ in range(1 if quick else 2):
            for depth, c in ((1, hcfg), (2, he_piped)):
                res = run_vfl(c, m1, mem1, mode="grpc",
                              pipeline_depth=depth, comm_cfg=he_link)
                h = res["master"]["history"]
                hw_step[depth] = min(hw_step[depth],
                                     _steady_us(h, skip=1))
                hw_info[depth] = f"steps={len(h)} rtt_ms=40 mode=grpc"
        for depth, us in hw_step.items():
            extra = "" if depth == 1 else \
                f" speedup_x{hw_step[1] / max(us, 1e-9):.2f}"
            emit(f"vfl_async_logreg_he_wan_d{depth}", us,
                 f"{hw_info[depth]}{extra}")
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def bench_rejoin():
    """Elastic recovery cost (docs/deploy.md `[restart]`): member0
    crashes mid-fit over real sockets, a fresh communicator restores
    from its checkpoint and rejoins via the ctrl/rejoin handshake; the
    row records the master's recovery wait (pause -> rejoin ack),
    which the vfl_rejoin_ CI prefix gates against the baseline."""
    import tempfile

    from repro.comm.base import CommCfg
    from repro.comm.sock import SocketCommunicator, local_addresses
    from repro.core.party import PartyMaster, PartyMember
    from repro.core.protocols.base import VFLConfig
    from repro.core.protocols.driver import (Callback, Checkpointer,
                                             ElasticCfg)
    from repro.data.vertical import vertical_partition

    class CrashAt(Callback):
        def on_batch_end(self, driver, step, epoch, loss):
            if step == 3:
                raise RuntimeError("bench: injected crash")

    def _build():
        rng = np.random.default_rng(0)
        n, d = 192, 12
        x = rng.normal(size=(n, d))
        y = x @ rng.normal(size=(d, 2)) * 0.4
        ids = [f"u{i:05d}" for i in range(n)]
        return vertical_partition(ids, x, y, widths=[4, 3],
                                  overlap=1.0, seed=1)
    master_data, member_datas = dataset_fixture("rejoin_192x12",
                                                _build)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48,
                    lr=0.1, seed=0, use_psi=False)
    world = ["master", "member0", "member1"]
    addrs = local_addresses(world)
    ccfg = CommCfg(strict_eof=True, timeout=30.0)
    comms = {w: SocketCommunicator(w, addrs, comm_cfg=ccfg)
             for w in world}
    ckpt = tempfile.mkdtemp(prefix="bench_rejoin_")

    def survivor():
        PartyMember(comms["member1"], cfg).serve(member_datas[1])

    def victim():
        try:
            PartyMember(comms["member0"], cfg,
                        callbacks=[Checkpointer(ckpt,
                                                save_on_start=True),
                                   CrashAt()]).serve(member_datas[0])
        except RuntimeError:
            pass
        finally:
            comms["member0"].close()          # the dead process's FIN

    t_victim = threading.Thread(target=victim, daemon=True)

    def rejoiner():
        t_victim.join(60)
        c = SocketCommunicator("member0", addrs, comm_cfg=ccfg)
        PartyMember(c, cfg, resume_dir=ckpt).serve(member_datas[0],
                                                   rejoin=True)

    ts = [threading.Thread(target=survivor, daemon=True), t_victim,
          threading.Thread(target=rejoiner, daemon=True)]
    for t in ts:
        t.start()
    pm = PartyMaster(comms["master"], cfg,
                     elastic=ElasticCfg(roles=frozenset({"member0"}),
                                        wait_s=60.0))
    t0 = time.perf_counter()
    fit = pm.fit(master_data)
    fit_s = time.perf_counter() - t0
    pm.shutdown()
    for t in ts:
        t.join(60)
    rec = fit["recoveries"][0]
    emit("vfl_rejoin_recovery_s", rec["wait_s"] * 1e6,
         f"wait_s={rec['wait_s']:.2f} at_step={rec['step']} "
         f"fit_s={fit_s:.2f} steps={len(fit['history'])}")


def bench_serving():
    """Decode throughput per family (reduced archs, CPU)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import params as PRM, transformer as T
    from repro.serve.engine import ServeEngine
    for arch in ("h2o-danube-1.8b", "rwkv6-7b", "minicpm3-4b",
                 "granite-moe-3b-a800m"):
        cfg = get_config(arch).reduced()
        params = PRM.init_tree(T.model_spec(cfg), jax.random.key(0),
                               jnp.float32)
        eng = ServeEngine(cfg, params, max_seq=64)
        prompts = np.ones((4, 8), np.int32)
        eng.generate(prompts, 4)          # warm the jit
        t0 = time.perf_counter()
        out = eng.generate(prompts, 32)
        dt = time.perf_counter() - t0
        emit(f"serve_decode_{arch}", dt / 32 * 1e6,
             f"tok_s={4 * 32 / dt:.1f}")


def bench_roofline():
    d = RESULTS / "dryrun"
    if not d.exists():
        print("# no dry-run results; run repro.launch.dryrun --all first")
        return
    from repro.launch.mesh import PEAK_FLOPS_BF16
    for f in sorted(d.glob("*__single.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        step_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        mfu = rf["model_flops"] / (step_s * r["chips"] * PEAK_FLOPS_BF16) \
            if step_s else 0.0
        emit(f"roofline_{r['arch']}_{r['shape']}", step_s * 1e6,
             f"dominant={rf['dominant'].replace('_s','')} "
             f"roofline_mfu={mfu:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_codec()
    bench_comm_modes()
    bench_encode_offload()
    bench_table1_demo(args.quick)
    bench_he()
    bench_he_packed(args.quick)
    bench_psi()
    bench_kernels(args.quick)
    bench_driver_overhead()
    bench_vfl_async(args.quick)
    bench_rejoin()
    bench_vfl_scaling()
    bench_compression()
    bench_serving()
    # federated serving engine (persistent sessions, dynamic batching,
    # member embed cache) — rows vfl_serve_*; lives in its own module
    from benchmarks.bench_serve import bench_serve
    bench_serve(emit, args.quick)
    # transformer-tower split-NN + per-step roofline split — rows
    # vfl_tower_*; lives in its own module
    from benchmarks.bench_tower import bench_tower
    bench_tower(emit, args.quick)
    bench_roofline()
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "bench.csv").write_text(
        "name,us_per_call,derived\n" + "\n".join(
            f"{n},{u:.2f},{d}" for n, u, d in ROWS))
    # machine-readable mirror so the perf trajectory is trackable in CI
    (RESULTS / "bench.json").write_text(json.dumps(
        [{"name": n, "us_per_call": round(u, 2), "derived": d}
         for n, u, d in ROWS], indent=1))


if __name__ == "__main__":
    main()
