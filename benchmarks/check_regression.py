"""Bench-regression gate: compare a fresh ``bench.json`` against the
committed ``benchmarks/results/baseline.json``.

Two checks, either failing exits non-zero:

1. **Presence** — every row in the required set exists in the fresh
   results (the old CI row-presence check, kept).
2. **Regression** — for every gated row (prefix-matched, present in
   both files), ``new_us <= baseline_us * threshold``. The default
   threshold of 1.5x absorbs host-speed variance between the 2-core
   dev box that recorded the baseline and CI runners; both sides are
   min-over-reps from the interleaved A/B protocol (see
   ``bench_vfl_async``/``bench_comm_modes``), which is what makes the
   comparison meaningful on noisy shared hosts in the first place.

Baseline values are deliberately an **envelope** (per-row max across
several recorded runs, including runs under adversarial parallel
load — each row's ``derived`` field records the spread): the gate is
tuned to never fail on host noise at the cost of only catching
regressions that exceed the noisiest recorded run by the threshold.
Tighten a row by re-recording its baseline on a quiet host once CI
variance for it is known.

Rows in the baseline but missing from the fresh run fail the gate too
(a silently dropped bench is how perf coverage rots).

  python benchmarks/check_regression.py \\
      benchmarks/results/bench.json benchmarks/results/baseline.json \\
      --threshold 1.5 --prefix vfl_async_ --prefix comm_

``--privacy privacy.json`` additionally (or instead, when the bench
positionals are omitted) gates the adversarial-harness rows written by
``repro.attacks.runner`` (docs/privacy.md): every required
(protocol, attack, defense) cell must be present, the undefended
attacks must demonstrably work (leakage AUC floor — a broken attack
would silently vacate every defense claim), and the gated defenses
must hold leakage under 0.6 within their utility budget.

  python benchmarks/check_regression.py \\
      --privacy benchmarks/results/privacy.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

# Rows whose absolute magnitude is small enough (sub-ms loopback
# latencies) that OS scheduling dominates: cross-run dispersion on an
# otherwise idle 2-core host measures ~4x even with the interleaved
# min-over-reps protocol, so a flat 1.5x gate would flake. These keep a
# wider per-row threshold scaled to that measured dispersion — still a
# hard gate, tuned to catch real regressions (e.g. a lost TCP_NODELAY
# on the wire path) rather than scheduler noise.
PER_ROW_THRESHOLD = {
    "comm_socket_small_nagle": 4.0,
    "comm_socket_small_nodelay": 4.0,
    "comm_roundtrip_thread_256KiB": 4.0,
    # in-process loopback rejoin: the recovery wait is ~1-2ms, so the
    # row guards against the backoff/reset path regressing by orders
    # of magnitude, not against sub-ms scheduling jitter
    "vfl_rejoin_recovery_s": 4.0,
    # serving rows are thread-scheduler-bound (admission queue +
    # coalescing wakeups across 10+ threads on 2 cores): the tail row
    # especially disperses with CPU contention, so gate on magnitude
    "vfl_serve_qps": 3.0,
    "vfl_serve_p99_ms": 4.0,
}

REQUIRED = {
    "vfl_driver_seed_loop", "vfl_driver_lifecycle",
    "vfl_async_splitnn_socket_d1", "vfl_async_splitnn_socket_d2",
    "vfl_async_splitnn_socket_d4",
    "vfl_async_splitnn_wan_d1", "vfl_async_splitnn_wan_d2",
    "vfl_async_splitnn_wan_d4",
    "vfl_async_logreg_he_overlap_d1", "vfl_async_logreg_he_overlap_d2",
    "vfl_async_logreg_he_wan_d1", "vfl_async_logreg_he_wan_d2",
    "comm_socket_small_nagle", "comm_socket_small_nodelay",
    "comm_roundtrip_grpc_256KiB",
    "comm_isend_encode_inline", "comm_isend_encode_offload",
    "vfl_rejoin_recovery_s",
    "vfl_serve_qps", "vfl_serve_p99_ms",
    "vfl_tower_splitnn_d1", "vfl_tower_splitnn_d2",
}


# privacy gate (repro.attacks.runner rows). Keys are (protocol,
# attack, defense); every listed cell must exist. min_leak asserts the
# attack itself works (an undefended exchange that stopped leaking
# means the harness broke, not that privacy improved); max_leak
# asserts the defense works; max_delta bounds the utility cost vs the
# undefended run of the same protocol. int8 has no max_leak on
# purpose: quantization error is far below label structure and the
# row exists to document that compression is NOT a privacy mechanism.
PRIVACY_GATES = {
    ("logreg_he", "grad_direction", "none"): {"min_leak": 0.75},
    ("logreg_he", "grad_direction", "noise"): {"max_leak": 0.6,
                                               "max_delta": 0.02},
    ("split_nn", "embed_probe", "none"): {"min_leak": 0.65},
    ("split_nn", "embed_cluster", "none"): {"min_leak": 0.6},
    ("split_nn", "embed_probe", "noise"): {"max_leak": 0.6},
    ("split_nn", "embed_cluster", "noise"): {"max_leak": 0.6},
    ("split_nn", "embed_probe", "int8"): {"max_delta": 0.02},
    ("split_nn", "embed_cluster", "int8"): {},
    ("split_nn", "embed_probe", "secure_agg"): {"max_leak": 0.6,
                                                "max_delta": 0.02},
    ("split_nn", "embed_cluster", "secure_agg"): {"max_leak": 0.6},
}


def check_privacy(path: str) -> list:
    """Gate the privacy.json rows; returns failure strings (empty =
    pass). Split out so tests drive it without argparse."""
    failures = []
    rows = {(r["protocol"], r["attack"], r["defense"]): r
            for r in json.load(open(path))}
    for key, gate in PRIVACY_GATES.items():
        name = "/".join(key)
        row = rows.get(key)
        if row is None:
            failures.append(f"privacy row missing: {name}")
            continue
        leak = float(row["leakage_auc"])
        delta = abs(float(row["utility_delta"]))
        checks = []
        if "min_leak" in gate:
            checks.append((leak >= gate["min_leak"],
                           f"leakage {leak:.3f} >= {gate['min_leak']}"
                           f" (attack must work)"))
        if "max_leak" in gate:
            checks.append((leak < gate["max_leak"],
                           f"leakage {leak:.3f} < {gate['max_leak']}"))
        if "max_delta" in gate:
            checks.append((delta <= gate["max_delta"],
                           f"|utility_delta| {delta:.3f} <= "
                           f"{gate['max_delta']}"))
        for ok, what in checks:
            print(f"{'OK ' if ok else 'PRIVACY-FAIL'} {name}: {what}")
            if not ok:
                failures.append(f"{name}: {what} violated")
    return failures


def _rows(path: str) -> Dict[str, float]:
    return {r["name"]: float(r["us_per_call"])
            for r in json.load(open(path))}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", nargs="?", help="fresh bench.json")
    ap.add_argument("baseline", nargs="?",
                    help="committed baseline.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when new > baseline * threshold "
                         "(default 1.5)")
    ap.add_argument("--prefix", action="append", default=None,
                    help="row-name prefixes to gate (repeatable; "
                         "default: vfl_async_ and comm_)")
    ap.add_argument("--privacy", default=None,
                    help="also gate adversarial-harness rows "
                         "(privacy.json from repro.attacks.runner)")
    args = ap.parse_args()
    prefixes = tuple(args.prefix or ("vfl_async_", "comm_"))

    failures = []
    if args.privacy:
        failures += check_privacy(args.privacy)
    if args.bench is None:
        if not args.privacy:
            ap.error("need bench+baseline positionals, --privacy, "
                     "or both")
        if failures:
            print("\n".join(f"FAIL: {f}" for f in failures),
                  file=sys.stderr)
            return 1
        print(f"privacy gate: {len(PRIVACY_GATES)} cells OK")
        return 0
    if args.baseline is None:
        ap.error("bench given without baseline")

    new = _rows(args.bench)
    base = _rows(args.baseline)

    missing = REQUIRED - set(new)
    if missing:
        failures.append(f"missing required bench rows: "
                        f"{sorted(missing)}")

    gated = sorted(n for n in base if n.startswith(prefixes))
    if not gated:
        failures.append(f"baseline has no rows matching {prefixes} — "
                        f"regenerate baseline.json")
    for name in gated:
        if name not in new:
            failures.append(f"{name}: in baseline but not in fresh "
                            f"results (bench silently dropped?)")
            continue
        limit = PER_ROW_THRESHOLD.get(name, args.threshold)
        ratio = new[name] / max(base[name], 1e-9)
        status = "OK " if ratio <= limit else "REGRESSION"
        print(f"{status} {name}: {new[name]:.1f}us vs baseline "
              f"{base[name]:.1f}us (x{ratio:.2f}, limit x{limit})")
        if ratio > limit:
            failures.append(f"{name} regressed x{ratio:.2f} "
                            f"(> x{limit})")

    if failures:
        print("\n".join(f"FAIL: {f}" for f in failures),
              file=sys.stderr)
        return 1
    print(f"bench-regression gate: {len(gated)} rows OK, "
          f"{len(REQUIRED)} required rows present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
