"""Causality property test: for every decoder family, logits at position
i must be invariant to tokens at positions > i — this catches masking,
token-shift, conv-padding and scan-direction bugs in one invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models import params as PRM, transformer as T

# one representative per sequence-mixing mechanism
ARCHS = ["glm4-9b", "h2o-danube-1.8b", "minicpm3-4b", "rwkv6-7b",
         "jamba-1.5-large-398b"]

_CACHE = {}


def _setup(arch):
    if arch not in _CACHE:
        cfg = get_config(arch).reduced()
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = PRM.init_tree(T.model_spec(cfg), jax.random.key(0),
                               jnp.float32)
        fwd = jax.jit(lambda p, t: T.forward(
            cfg, p, {"tokens": t}, jnp.float32)[0])
        _CACHE[arch] = (cfg, params, fwd)
    return _CACHE[arch]


@pytest.mark.parametrize("arch", ARCHS)
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 14))
def test_future_tokens_do_not_leak(arch, seed, cut):
    cfg, params, fwd = _setup(arch)
    rng = np.random.default_rng(seed)
    s = 16
    a = rng.integers(0, cfg.vocab, (1, s))
    b = a.copy()
    b[:, cut:] = rng.integers(0, cfg.vocab, (1, s - cut))
    la = np.asarray(fwd(params, jnp.asarray(a, jnp.int32)))
    lb = np.asarray(fwd(params, jnp.asarray(b, jnp.int32)))
    # positions < cut see identical histories -> identical logits
    np.testing.assert_allclose(la[:, :cut], lb[:, :cut],
                               rtol=2e-4, atol=2e-4)
    # and the change is actually visible afterwards (sanity)
    if not np.array_equal(a[:, cut:], b[:, cut:]):
        assert np.abs(la[:, -1] - lb[:, -1]).max() > 1e-6
