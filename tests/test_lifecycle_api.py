"""Lifecycle API (driver + typed schema + VFLJob): ported protocols must
reproduce the recorded seed traces bit-for-bit across execution modes,
callbacks fire in order, checkpoint/resume is deterministic mid-epoch,
predict round-trips without retraining, agent failures propagate with
real tracebacks, and tail batches are no longer silently dropped."""
import dataclasses
import json
import pathlib
import time

import numpy as np
import pytest

from repro.comm import schema
from repro.comm.local import ThreadBus
from repro.comm.schema import Field, SchemaError, TypedChannel
from repro.core.party import VFLJob, run_vfl
from repro.core.protocols.base import (VFLConfig, batch_bounds, batches)
from repro.core.protocols.driver import (Callback, Checkpointer,
                                         EarlyStopping, EvalEveryEpoch,
                                         MetricsStream, StopAtStep)
from repro.core.protocols.linreg import LinRegProtocol
from repro.data.vertical import vertical_partition

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


def _dataset(n=192, d=12, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, y


def _linreg_case():
    ids, x, y = _dataset()
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    return cfg, master, members


def _logreg_case():
    ids, x, y = _dataset(n=64, d=8, items=1)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[3], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256)
    return cfg, master, members


def _splitnn_case():
    ids, x, y = _dataset(n=128, d=12, items=3)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    return cfg, master, members


# ---------------------------------------------------------------------------
# ported protocols == recorded seed traces, across modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["thread", "socket", "process"])
def test_linreg_matches_seed_trace(mode):
    """The lifecycle port must change ZERO arithmetic: losses and every
    weight slice equal the monolithic role functions' recorded trace."""
    cfg, master, members = _linreg_case()
    res = run_vfl(cfg, master, members, mode=mode)
    got = [h["loss"] for h in res["master"]["history"]]
    np.testing.assert_allclose(got, TRACES["linreg"]["losses"],
                               rtol=0, atol=0)
    np.testing.assert_allclose(res["master"]["w_master"],
                               TRACES["linreg"]["w_master"], rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


@pytest.mark.parametrize("mode", ["thread", "socket"])
def test_logreg_he_matches_seed_trace(mode):
    cfg, master, members = _logreg_case()
    res = run_vfl(cfg, master, members, mode=mode)
    got = [h["loss"] for h in res["master"]["history"]]
    np.testing.assert_allclose(got, TRACES["logreg_he"]["losses"],
                               rtol=0, atol=0)
    np.testing.assert_allclose(res["member0"]["w"],
                               TRACES["logreg_he"]["w_members"][0],
                               rtol=0, atol=0)


@pytest.mark.parametrize("mode", ["thread", "socket"])
def test_split_nn_matches_seed_trace(mode):
    cfg, master, members = _splitnn_case()
    res = run_vfl(cfg, master, members, mode=mode)
    got = [h["loss"] for h in res["master"]["history"]]
    np.testing.assert_allclose(got, TRACES["split_nn"]["losses"],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------


class _Recorder(Callback):
    def __init__(self):
        self.events = []

    def _rec(self, driver, kind, *detail):
        if driver.role == "master":
            self.events.append((kind,) + detail)

    def on_fit_start(self, driver):
        self._rec(driver, "fit_start")

    def on_epoch_start(self, driver, epoch):
        self._rec(driver, "epoch_start", epoch)

    def on_batch_end(self, driver, step, epoch, loss):
        self._rec(driver, "batch_end", step)

    def on_epoch_end(self, driver, epoch):
        self._rec(driver, "epoch_end", epoch)

    def on_fit_end(self, driver):
        self._rec(driver, "fit_end")


def test_callback_invocation_order():
    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(cfg, epochs=2)
    rec = _Recorder()
    run_vfl(cfg, master, members, callbacks=[rec])
    want = [("fit_start",)]
    step = 0
    for epoch in range(2):
        want.append(("epoch_start", epoch))
        for _ in range(4):          # 192 / 48
            want.append(("batch_end", step))
            step += 1
        want.append(("epoch_end", epoch))
    want.append(("fit_end",))
    assert rec.events == want


def test_metrics_stream_and_early_stop():
    cfg, master, members = _linreg_case()
    ms = MetricsStream()
    res = run_vfl(cfg, master, members,
                  callbacks=[ms, EarlyStopping(patience=2,
                                               min_delta=10.0)])
    # min_delta=10 means nothing beats the first round's loss: stop
    # after `patience` further rounds
    assert len(res["master"]["history"]) == 3
    assert "early-stop" in res["master"]["stopped"]
    assert [r["step"] for r in ms.rows] == [0, 1, 2]
    assert all(r["sent_bytes"] > 0 for r in ms.rows)


def test_eval_every_epoch_streams_metrics():
    cfg, master, members = _logreg_case()
    res = run_vfl(cfg, master, members, callbacks=[EvalEveryEpoch()])
    ev = res["master"]["eval_history"]
    assert len(ev) == 1 and ev[0]["epoch"] == 0
    assert 0.0 <= ev[0]["auc"] <= 1.0 and ev[0]["logloss"] > 0
    # the mid-fit eval must not perturb training
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["logreg_he"]["losses"], rtol=0, atol=0)


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case,stop_at,atol", [
    (_linreg_case, 5, 0.0),        # mid-epoch-2 (4 steps/epoch)
    (_splitnn_case, 6, 1e-5),      # f32 state round-trips through numpy
])
def test_checkpoint_resume_mid_epoch(case, stop_at, atol, tmp_path):
    cfg, master, members = case()
    ref = run_vfl(cfg, master, members)
    job = VFLJob(cfg, master, members,
                 callbacks=[Checkpointer(tmp_path, every_steps=1),
                            StopAtStep(stop_at)])
    r1 = job.fit()
    job.shutdown()
    assert len(r1["history"]) == stop_at and r1["stopped"]

    job2 = VFLJob(cfg, master, members, resume_dir=tmp_path)
    r2 = job2.fit()
    res2 = job2.shutdown()
    ref_losses = [h["loss"] for h in ref["master"]["history"]]
    np.testing.assert_allclose([h["loss"] for h in r2["history"]],
                               ref_losses, rtol=0, atol=atol)
    if cfg.protocol == "linreg":
        np.testing.assert_allclose(res2["master"]["w_master"],
                                   ref["master"]["w_master"],
                                   rtol=0, atol=0)
        np.testing.assert_allclose(res2["member0"]["w"],
                                   ref["member0"]["w"], rtol=0, atol=0)


def test_checkpoint_resume_pipelined_with_compression(tmp_path):
    """Resume under ``pipeline_depth=2`` with compression on: the
    checkpoint must carry the error-feedback residuals and the typed
    channel's sequence numbers, so the resumed federation rejoins the
    stream without desync and reproduces the uninterrupted depth-2
    trace."""
    import dataclasses
    cfg, master, members = _splitnn_case()
    cfg = dataclasses.replace(cfg, compress=True)
    ref = run_vfl(cfg, master, members, pipeline_depth=2)
    job = VFLJob(cfg, master, members, pipeline_depth=2,
                 callbacks=[Checkpointer(tmp_path, every_steps=1),
                            StopAtStep(6)])
    r1 = job.fit()
    job.shutdown()
    # the stop request lands with up to depth-1 extra rounds already
    # announced; the master completes every announced round
    assert 6 <= len(r1["history"]) <= 7 and r1["stopped"]

    job2 = VFLJob(cfg, master, members, pipeline_depth=2,
                  resume_dir=tmp_path)
    r2 = job2.fit()
    job2.shutdown()
    got = [h["loss"] for h in r2["history"]]
    want = [h["loss"] for h in ref["master"]["history"]]
    assert len(got) == len(want)
    # the checkpointed prefix is exact; past the cut the member's EF
    # residual legitimately includes the quantization of the round that
    # was in flight at save time, so the continuation tracks the
    # uninterrupted trace tightly but not bit-for-bit
    np.testing.assert_allclose(got[:7], want[:7], rtol=0, atol=1e-5)
    np.testing.assert_allclose(got, want, rtol=0, atol=2e-3)
    assert got[-1] < got[0]


# ---------------------------------------------------------------------------
# predict / evaluate phase
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case,metric", [
    (_linreg_case, "mse"),
    (_logreg_case, "auc"),
    (_splitnn_case, "auc"),
])
def test_predict_roundtrip(case, metric):
    """train -> predict -> metrics on live agents, no retraining."""
    cfg, master, members = case()
    job = VFLJob(cfg, master, members)
    fit = job.fit()
    steps = len(fit["history"])
    s1 = job.predict()
    s2 = job.predict()
    ev = job.evaluate()
    res = job.shutdown()
    n = res["master"]["n_common"]
    assert s1.shape[0] == n
    np.testing.assert_allclose(s1, s2, rtol=0, atol=0)   # serving is pure
    assert len(res["master"]["history"]) == steps        # no extra steps
    assert metric in ev
    if metric == "auc":
        assert ev["auc"] > 0.55                          # actually learned
    assert res["master"]["phase_s"].get("predict", 0) > 0
    ppb = res["master"]["comm"]["per_phase_bytes"]
    assert ppb["match"] > 0 and ppb["fit"] > 0 and ppb["predict"] > 0


def test_predict_row_subset():
    cfg, master, members = _linreg_case()
    with VFLJob(cfg, master, members) as job:
        job.fit()
        full = job.predict()
        sub = job.predict(rows=np.arange(10, 30))
        np.testing.assert_allclose(sub, full[10:30], rtol=0, atol=0)


def test_secure_agg_predict_masks_cancel():
    """Members mask predict-query activations too (the master only ever
    sees the aggregate); pairwise masks cancel in the sum, so scores
    match the unmasked run."""
    ids, x, y = _dataset(n=96, items=2)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[4, 4],
                                         seed=7)
    cfg = VFLConfig(protocol="split_nn", epochs=2, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    with VFLJob(cfg, master, members) as plain_job:
        plain_job.fit()
        plain = plain_job.predict()
    sec_cfg = dataclasses.replace(cfg, secure_agg=True)
    with VFLJob(sec_cfg, master, members) as sec_job:
        sec_job.fit()
        sec1 = sec_job.predict()
        sec2 = sec_job.predict()
    np.testing.assert_allclose(sec1, plain, rtol=1e-3, atol=1e-3)
    # distinct mask streams per query, still canceling
    np.testing.assert_allclose(sec2, sec1, rtol=1e-3, atol=1e-3)


def test_followers_survive_idle_between_phases():
    """A live job can sit idle between fit and predict far longer than
    the transports' per-message timeout; followers must keep waiting for
    the next phase announcement instead of dying."""
    import threading

    from repro.core.party import PartyMaster, PartyMember

    cfg, master_data, member_datas = _linreg_case()
    bus = ThreadBus(["master", "member0", "member1"])
    comms = {w: bus.communicator(w) for w in bus.world}
    for c in comms.values():
        c._timeout = 0.3                   # transport times out fast
    out = {}

    def run_member(name):
        out[name] = PartyMember(comms[name], cfg).serve(member_datas[
            int(name.replace("member", ""))])

    threads = [threading.Thread(target=run_member, args=(m,), daemon=True)
               for m in ("member0", "member1")]
    for t in threads:
        t.start()
    pm = PartyMaster(comms["master"], cfg)
    pm.fit(master_data)
    time.sleep(1.0)                        # idle >> transport timeout
    scores = pm.predict()
    pm.shutdown()
    for t in threads:
        t.join(timeout=60)
    assert scores.shape[0] == pm.driver.n
    assert "w" in out["member0"] and "w" in out["member1"]


def test_call_after_shutdown_fails_fast():
    cfg, master, members = _linreg_case()
    job = VFLJob(cfg, master, members)
    job.fit()
    job.shutdown()
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="already shut down"):
        job.predict()
    assert time.monotonic() - t0 < 5


def test_explicit_role_objects():
    """The deployment-style API: you own the transports, one agent per
    host — PartyMaster drives phases directly, members/arbiter serve."""
    import threading

    from repro.core.party import Arbiter, PartyMaster, PartyMember

    cfg, master_data, member_datas = _logreg_case()
    bus = ThreadBus(["master", "member0", "arbiter"])
    out = {}

    def run_member():
        out["member0"] = PartyMember(bus.communicator("member0"),
                                     cfg).serve(member_datas[0])

    def run_arbiter():
        out["arbiter"] = Arbiter(bus.communicator("arbiter"), cfg).serve()

    threads = [threading.Thread(target=run_member, daemon=True),
               threading.Thread(target=run_arbiter, daemon=True)]
    for t in threads:
        t.start()
    pm = PartyMaster(bus.communicator("master"), cfg)
    fit = pm.fit(master_data)
    scores = pm.predict()
    res = pm.shutdown()
    for t in threads:
        t.join(timeout=60)
    np.testing.assert_allclose([h["loss"] for h in fit["history"]],
                               TRACES["logreg_he"]["losses"],
                               rtol=0, atol=0)
    assert scores.shape == (res["n_common"], 1)
    assert "w" in out["member0"] and "decrypted_values" in out["arbiter"]


# ---------------------------------------------------------------------------
# failure propagation (regression: process mode used to block 600s and
# die with queue.Empty when an agent crashed)
# ---------------------------------------------------------------------------


class FailingMemberProtocol(LinRegProtocol):
    name = "failing_member"

    def setup(self):
        if self.is_member:
            raise RuntimeError("deliberate member failure")
        super().setup()


@pytest.mark.parametrize("mode", ["thread", "process"])
def test_agent_failure_propagates_fast(mode):
    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(
        cfg, protocol="test_lifecycle_api:FailingMemberProtocol")
    t0 = time.monotonic()
    with pytest.raises(RuntimeError) as ei:
        run_vfl(cfg, master, members, mode=mode)
    assert time.monotonic() - t0 < 120        # far below the 600s hang
    assert "deliberate member failure" in str(ei.value.__cause__)


# ---------------------------------------------------------------------------
# tail batches (regression: batches() silently dropped up to
# batch_size-1 matched samples per epoch)
# ---------------------------------------------------------------------------


def test_batch_bounds_cover_all_samples():
    cfg = VFLConfig(batch_size=16)
    b = batch_bounds(70, cfg)
    assert b[-1] == (64, 70)                       # tail kept
    assert sum(hi - lo for lo, hi in b) == 70
    rows = np.concatenate(list(batches(70, cfg, epoch=0)))
    assert sorted(rows.tolist()) == list(range(70))
    b2 = batch_bounds(70, dataclasses.replace(cfg, drop_last=True))
    assert b2[-1] == (48, 64)                      # old behaviour, opt-in
    assert batch_bounds(64, cfg) == batch_bounds(
        64, dataclasses.replace(cfg, drop_last=True))


def test_tail_batch_modes_agree():
    """Every party and every mode derives the identical tail batch."""
    ids, x, y = _dataset(n=100)
    master, members = vertical_partition(ids, x, y, widths=[4],
                                         overlap=1.0, seed=2)
    cfg = VFLConfig(protocol="linreg", epochs=2, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    ref = run_vfl(cfg, master, members, mode="thread")
    assert len(ref["master"]["history"]) == 2 * 3  # 48+48+4 per epoch
    got = run_vfl(cfg, master, members, mode="socket")
    np.testing.assert_allclose(
        [h["loss"] for h in got["master"]["history"]],
        [h["loss"] for h in ref["master"]["history"]], rtol=0, atol=0)
    np.testing.assert_allclose(got["member0"]["w"], ref["member0"]["w"],
                               rtol=0, atol=0)
    # centralized reference with the same batching matches exactly
    w = np.zeros((x.shape[1], y.shape[1]))
    losses = []
    for epoch in range(cfg.epochs):
        for rows in batches(100, cfg, epoch):
            z = x[rows] @ w
            r = (z - y[rows]) / len(rows)
            losses.append(float(0.5 * np.mean((z - y[rows]) ** 2)))
            w -= cfg.lr * (x[rows].T @ r)
    np.testing.assert_allclose(
        [h["loss"] for h in ref["master"]["history"]], losses, rtol=1e-10)


# ---------------------------------------------------------------------------
# typed message schema
# ---------------------------------------------------------------------------

schema.message("t/plain", {"x": Field("float64", 2)})
schema.message("t/stepped", {"x": Field("float64", 1)}, stepped=True)
schema.message("t/wide", {"c": Field("uint8", 2, width_meta="width")})


def _pair():
    bus = ThreadBus(["master", "member0"])
    return (TypedChannel(bus.communicator("master")),
            TypedChannel(bus.communicator("member0")))


def test_schema_rejects_bad_payloads():
    a, _ = _pair()
    with pytest.raises(SchemaError, match="unregistered"):
        a.send("member0", "t/unknown", {"x": np.zeros((2, 2))})
    with pytest.raises(SchemaError, match="fields"):
        a.send("member0", "t/plain", {"y": np.zeros((2, 2))})
    with pytest.raises(SchemaError, match="dtype"):
        a.send("member0", "t/plain", {"x": np.zeros((2, 2), np.float32)})
    with pytest.raises(SchemaError, match="rank"):
        a.send("member0", "t/plain", {"x": np.zeros(3)})


def test_schema_width_validated_at_decode():
    a, b = _pair()
    a.send("member0", "t/wide", {"c": np.zeros((4, 64), np.uint8)},
           meta={"width": "64"})
    assert b.recv("master", "t/wide").tensor("c").shape == (4, 64)
    # sender-side check trips on a mismatched declaration
    with pytest.raises(SchemaError, match="width"):
        a.send("member0", "t/wide", {"c": np.zeros((4, 64), np.uint8)},
               meta={"width": "128"})


def test_schema_auto_steps_sequence_numbers():
    a, b = _pair()
    for i in range(3):
        a.send("member0", "t/stepped", {"x": np.full(2, float(i))})
    for i in range(3):
        msg = b.recv("master", "t/stepped")
        assert msg.tag == f"t/stepped/{i}"
        assert msg.tensor("x")[0] == i
    # non-stepped tags don't accumulate a counter
    a.send("member0", "t/plain", {"x": np.zeros((1, 1))})
    assert b.recv("master", "t/plain").tag == "t/plain"


def test_schema_conflicting_redeclaration_rejected():
    schema.message("t/redecl", {"x": Field("float64")})
    schema.message("t/redecl", {"x": Field("float64")})   # idempotent ok
    with pytest.raises(SchemaError, match="redeclaration"):
        schema.message("t/redecl", {"x": Field("float32")})
