"""Cluster launch subsystem (docs/deploy.md): spec parsing/validation,
``VFLJob.from_spec`` in-process runs, and the two-launcher story —
rendezvous in any order, TLS'd transports, crash propagation across
launchers within seconds (control channel), and SIGKILL detection."""
import json
import os
import pathlib
import signal
import threading
import time

import numpy as np
import pytest

from repro.comm.sock import local_addresses
from repro.core.party import VFLJob
from repro.launch.certs import TestCA, have_openssl
from repro.launch.cluster import (ClusterLauncher, ClusterSpec,
                                  load_spec, parse_toml)

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())

REPO = pathlib.Path(__file__).resolve().parents[1]


def _free_ports(n):
    return [port for _, port in
            local_addresses([f"p{i}" for i in range(n)]).values()]


def _linreg_spec(ports, tls_dir=None, framing="sock", epochs=3,
                 **extra):
    spec = {
        "protocol": {"name": "linreg", "epochs": epochs,
                     "batch_size": 48, "lr": 0.1, "seed": 0,
                     "use_psi": False},
        "run": {"phases": ["fit"]},
        "data": {"provider": "repro.launch.cluster:linreg_demo_data",
                 "seed": 0},
        "comm": {"framing": framing, "timeout": 30.0,
                 "barrier_timeout": 60.0},
        "agents": {"master": f"127.0.0.1:{ports[0]}",
                   "member0": f"127.0.0.1:{ports[1]}",
                   "member1": f"127.0.0.1:{ports[2]}"},
        "hosts": {"alpha": {"control": f"127.0.0.1:{ports[3]}",
                            "agents": ["master", "member0"]},
                  "beta": {"control": f"127.0.0.1:{ports[4]}",
                           "agents": ["member1"]}},
    }
    if tls_dir is not None:
        spec["comm"]["tls"] = {"cert": f"{tls_dir}/{{agent}}.crt",
                               "key": f"{tls_dir}/{{agent}}.key",
                               "ca": f"{tls_dir}/ca.crt"}
    spec.update(extra)
    return spec


@pytest.fixture(scope="session")
def cluster_certs(tmp_path_factory):
    if not have_openssl():
        pytest.skip("openssl CLI required")
    ca = TestCA(tmp_path_factory.mktemp("clcerts"))
    for n in ("master", "member0", "member1", "alpha", "beta"):
        ca.issue(n)
    return ca


def _run_pair(spec: ClusterSpec, log_root, hosts=("alpha", "beta")):
    codes = {}

    def _one(host):
        codes[host] = ClusterLauncher(
            spec, host, log_dir=pathlib.Path(log_root) / host).run()
    ts = [threading.Thread(target=_one, args=(h,)) for h in hosts]
    for t in ts:
        t.start()
    for t in ts:
        t.join(150)
    assert not any(t.is_alive() for t in ts), "launcher wedged"
    return codes


# ---------------------------------------------------------------------------
# spec parsing + validation
# ---------------------------------------------------------------------------


def test_parse_toml_subset():
    doc = parse_toml("""
# comment
[protocol]
name = "linreg"        # trailing comment
epochs = 3
lr = 0.1
use_psi = false
hidden = [16, 8]

[hosts.alpha]
control = "127.0.0.1:1"
agents = [
  "master",      # multi-line array, trailing comma
  "member0",
]
""")
    assert doc["protocol"] == {"name": "linreg", "epochs": 3,
                               "lr": 0.1, "use_psi": False,
                               "hidden": [16, 8]}
    assert doc["hosts"]["alpha"]["agents"] == ["master", "member0"]


def test_committed_example_spec_loads_and_validates():
    spec = load_spec(REPO / "examples" / "cluster"
                     / "quickstart_cluster.toml")
    spec.validate()
    assert spec.world() == ["master", "member0"]
    assert spec.framing == "grpc"
    assert spec.comm.tls is not None
    # relative cert paths resolve against the spec file's directory
    assert os.path.isabs(spec.comm.tls.ca)
    assert spec.agents_of("alpha") == ["master"]
    assert spec.run_phases == ["fit", "evaluate"]


def test_spec_validation_errors():
    spec = load_spec(_linreg_spec(_free_ports(5)))
    spec.validate()
    bad = load_spec(_linreg_spec(_free_ports(5)))
    bad.hosts["beta"].agents = []            # member1 unassigned
    with pytest.raises(ValueError, match="exactly one host"):
        bad.validate()
    with pytest.raises(ValueError, match="unknown VFLConfig fields"):
        load_spec({"protocol": {"name": "linreg", "nope": 1},
                   "agents": {}, "hosts": {}})
    bad2 = load_spec(_linreg_spec(_free_ports(5)))
    # linreg needs no arbiter: an extra one is a world mismatch
    bad2.agents["arbiter"] = ("127.0.0.1", 1)
    with pytest.raises(ValueError, match="exactly the protocol"):
        bad2.validate()


def test_chaos_spec_role_list_and_repeat():
    """[chaos] role accepts a list (correlated faults) and repeat=true
    (re-armed on respawn); every named role must be an agent."""
    from repro.launch.cluster import _chaos_callbacks
    spec = load_spec(_linreg_spec(
        _free_ports(5),
        chaos={"role": ["member0", "member1"], "step": 3,
               "scenario": "crash", "repeat": True}))
    spec.validate()
    assert spec.chaos.roles == ["member0", "member1"]
    assert spec.chaos.repeat is True
    assert _chaos_callbacks(spec, "member0")
    assert _chaos_callbacks(spec, "member1")
    assert _chaos_callbacks(spec, "master") == []
    # a single role string still normalizes and defaults repeat off
    single = load_spec(_linreg_spec(_free_ports(5),
                                    chaos={"role": "member0",
                                           "step": 3}))
    assert single.chaos.roles == ["member0"]
    assert single.chaos.repeat is False
    bad = load_spec(_linreg_spec(
        _free_ports(5), chaos={"role": ["member0", "ghost"],
                               "step": 3}))
    with pytest.raises(ValueError, match="not an agent"):
        bad.validate()
    with pytest.raises(ValueError, match=r"\[chaos\] unknown keys"):
        load_spec(_linreg_spec(_free_ports(5),
                               chaos={"role": "member0", "step": 1,
                                      "nope": True}))


# ---------------------------------------------------------------------------
# VFLJob.from_spec: run a deployment spec in-process
# ---------------------------------------------------------------------------


def test_vfljob_from_spec_matches_seed_trace():
    """The spec's provider/protocol reproduce the recorded linreg seed
    trace bit-identically when run in-process — a deployment spec can
    be verified on one machine before it is distributed."""
    spec = load_spec(_linreg_spec(_free_ports(5)))
    job = VFLJob.from_spec(spec, pipeline_depth=1)
    fit = job.fit()
    res = job.shutdown()
    np.testing.assert_allclose(
        [h["loss"] for h in fit["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# launcher end-to-end (two launchers on localhost, TLS on)
# ---------------------------------------------------------------------------


def test_two_launchers_tls_converge(tmp_path, cluster_certs):
    spec = load_spec(_linreg_spec(_free_ports(5),
                                  tls_dir=cluster_certs.dir))
    codes = _run_pair(spec, tmp_path)
    assert codes == {"alpha": 0, "beta": 0}
    summary = json.loads(
        (tmp_path / "alpha" / "summary.json").read_text())
    fit = summary["agents"]["master"]["fit"]
    assert fit["final_loss"] < fit["first_loss"]
    assert fit["steps"] == 12
    # per-agent logs captured
    assert (tmp_path / "alpha" / "master.log").exists()
    assert (tmp_path / "beta" / "member1.log").exists()


def test_member_crash_fails_both_launchers_with_traceback(
        tmp_path, capfd):
    """A member crash on one host must take down BOTH launchers within
    seconds, each reporting the member's real traceback (local via the
    status queue, remote via the control channel)."""
    spec = load_spec(_linreg_spec(
        _free_ports(5), epochs=100,
        chaos={"role": "member1", "step": 5}))
    t0 = time.monotonic()
    codes = _run_pair(spec, tmp_path)
    dt = time.monotonic() - t0
    assert codes == {"alpha": 1, "beta": 1}
    assert dt < 60.0
    err = capfd.readouterr().err
    assert "chaos: injected crash at step 5" in err
    assert "member1" in err
    assert not (tmp_path / "alpha" / "summary.json").exists()


def test_correlated_member_crashes_fail_both_launchers(
        tmp_path, capfd):
    """Both members crash in the same round (a [chaos] role list):
    each host sees a local death at once, and both launchers must
    still exit non-zero attributed — two simultaneous failure
    broadcasts racing on the control channel must not wedge either
    supervision loop."""
    spec = load_spec(_linreg_spec(
        _free_ports(5), epochs=100,
        chaos={"role": ["member0", "member1"], "step": 5}))
    t0 = time.monotonic()
    codes = _run_pair(spec, tmp_path)
    dt = time.monotonic() - t0
    assert codes == {"alpha": 1, "beta": 1}
    assert dt < 60.0
    err = capfd.readouterr().err
    assert "chaos: injected crash at step 5" in err
    assert not (tmp_path / "alpha" / "summary.json").exists()


def test_sigkilled_member_detected_within_seconds(tmp_path):
    """SIGKILL leaves no traceback and can close sockets cleanly
    between frames — the launcher's process watchdog + control fan-out
    must still fail every launcher fast (no hang to comm timeout)."""
    spec = load_spec(_linreg_spec(
        _free_ports(5), epochs=500,
        comm={"framing": "sock", "timeout": 120.0,
              "barrier_timeout": 60.0,
              "link": {"latency_ms": 25.0}}))
    codes = {}

    def _one(host):
        codes[host] = ClusterLauncher(
            spec, host, log_dir=tmp_path / host).run()
    ts = [threading.Thread(target=_one, args=(h,))
          for h in ("alpha", "beta")]
    for t in ts:
        t.start()
    pids = tmp_path / "beta" / "pids.json"
    deadline = time.monotonic() + 60
    while not pids.exists() and time.monotonic() < deadline:
        time.sleep(0.2)
    assert pids.exists(), "beta never reached readiness"
    time.sleep(3.0)                          # let training get going
    t0 = time.monotonic()
    os.kill(json.loads(pids.read_text())["member1"], signal.SIGKILL)
    for t in ts:
        t.join(30)
    assert not any(t.is_alive() for t in ts), \
        "launchers hung after SIGKILL"
    assert time.monotonic() - t0 < 30.0
    assert codes == {"alpha": 1, "beta": 1}


# ---------------------------------------------------------------------------
# [restart] supervision: spec validation + rejoin end-to-end
# ---------------------------------------------------------------------------


def test_restart_spec_validation():
    # flat keys are the member-wide default, per-role entries override
    spec = load_spec(_linreg_spec(
        _free_ports(5),
        restart={"policy": "on_failure", "backoff_s": 0.1,
                 "member1": {"max_restarts": 7}}))
    spec.validate()
    assert spec.restartable_roles() == ["member0", "member1"]
    assert spec.restart_of("member0").max_restarts == 3
    assert spec.restart_of("member1").max_restarts == 7
    assert spec.restart_of("member1").backoff_s == 0.1  # flat inherited
    assert spec.restart_of("master").policy == "never"  # never implied

    with pytest.raises(ValueError, match="only members"):
        load_spec(_linreg_spec(
            _free_ports(5),
            restart={"master": {"policy": "on_failure"}})).validate()
    with pytest.raises(ValueError, match="unknown policy"):
        load_spec(_linreg_spec(
            _free_ports(5), restart={"policy": "always"})).validate()
    with pytest.raises(ValueError, match="unknown keys"):
        load_spec(_linreg_spec(_free_ports(5),
                               restart={"retries": 3}))
    with pytest.raises(ValueError, match="secure ag"):
        bad = load_spec(_linreg_spec(
            _free_ports(5), restart={"policy": "on_failure"}))
        bad.cfg.secure_agg = True
        bad.validate()
    with pytest.raises(ValueError, match="not an agent"):
        load_spec(_linreg_spec(
            _free_ports(5),
            restart={"member9": {"policy": "on_failure"}})).validate()


def test_restart_never_is_the_default():
    """An unadorned spec must keep PR 5 fail-fast semantics: no role is
    restartable and strict_eof stays off the communicators."""
    spec = load_spec(_linreg_spec(_free_ports(5)))
    assert spec.restartable_roles() == []
    assert spec.restart_of("member0").policy == "never"


def test_restart_policy_rejoins_and_completes(tmp_path):
    """The full supervision loop: the chaos crash kills member1 on host
    beta mid-fit; its launcher respawns it (rejoin entry, resume from
    the role-local checkpoint), the master pauses, accepts the rejoin
    hello, and training completes EVERY announced round. Both launchers
    exit 0 and the summary records the recovery."""
    spec = load_spec(_linreg_spec(
        _free_ports(5), epochs=6,
        chaos={"role": "member1", "step": 5},
        restart={"member1": {"policy": "on_failure",
                             "backoff_s": 0.2, "backoff_max_s": 1.0}}))
    t0 = time.monotonic()
    codes = _run_pair(spec, tmp_path)
    dt = time.monotonic() - t0
    assert codes == {"alpha": 0, "beta": 0}
    summary = json.loads(
        (tmp_path / "alpha" / "summary.json").read_text())
    master = summary["agents"]["master"]
    assert master["fit"]["steps"] == 24          # 6 epochs x 4 batches
    assert master["fit"]["final_loss"] < master["fit"]["first_loss"]
    rec = master["recoveries"]
    assert [r["role"] for r in rec] == ["member1"]
    assert rec[0]["wait_s"] < 15.0               # recovery, not timeout
    assert dt < 120.0
    # the respawned agent reported ready again: pids.json was rewritten
    assert (tmp_path / "beta" / "pids.json").exists()
