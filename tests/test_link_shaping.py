"""WAN-real transport stack (DESIGN.md §8): LinkSpec shaping observes
the configured latency/bandwidth within tolerance (and latency overlaps
across in-flight messages like real propagation delay), isend encode
offload honors the snapshot contract and stays bit-identical to the
seed traces at depth 1, and the gRPC-framed transport passes the same
matrices as the socket transport — framing edge cases included."""
import dataclasses
import json
import pathlib
import socket
import threading
import time

import numpy as np
import pytest

from repro.comm.base import CommCfg, LinkSpec
from repro.comm.grpc import (PREFACE, GrpcCommunicator, hpack_decode,
                             hpack_encode)
from repro.comm.local import ThreadBus
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core.party import run_vfl
from repro.core.protocols.base import VFLConfig
from repro.data.vertical import vertical_partition

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


def _linreg_case():
    rng = np.random.default_rng(0)
    n, d, items = 192, 12, 2
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    return cfg, master, members


def _sock_pair(comm_cls=SocketCommunicator, **cfg_kw):
    addrs = local_addresses(["a", "b"])
    cfg = CommCfg(**cfg_kw) if cfg_kw else None
    ca = comm_cls("a", addrs, comm_cfg=cfg)
    cb = comm_cls("b", addrs)
    return ca, cb


# ---------------------------------------------------------------------------
# LinkSpec shaping
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm_cls", [SocketCommunicator,
                                      GrpcCommunicator])
def test_link_latency_observed(comm_cls):
    """A 60 ms one-way link delivers no earlier than ~60 ms and within
    a loose upper tolerance (the host is 2-core and noisy)."""
    ca, cb = _sock_pair(comm_cls, link=LinkSpec(latency_ms=60))
    try:
        t0 = time.perf_counter()
        ca.send("b", "t", {"x": np.zeros(8)})
        cb.recv("a", "t", timeout=10.0)
        dt = time.perf_counter() - t0
        assert 0.055 <= dt < 1.0, dt
    finally:
        ca.close(); cb.close()


def test_link_latency_overlaps_inflight_messages():
    """Latency is propagation, not occupancy: N back-to-back isends all
    arrive ~latency later, NOT N * latency (the old naive sleep-in-line
    model). FIFO order still holds."""
    ca, cb = _sock_pair(link=LinkSpec(latency_ms=80))
    try:
        t0 = time.perf_counter()
        for i in range(5):
            ca.isend("b", f"t{i}", {"x": np.array([float(i)])})
        seen = [cb.recv("a", f"t{i}", timeout=10.0).tensor("x")[0]
                for i in range(5)]
        dt = time.perf_counter() - t0
        assert seen == [0.0, 1.0, 2.0, 3.0, 4.0]
        assert 0.075 <= dt < 0.35, dt     # ~1x latency, not 5x (0.4s)
    finally:
        ca.close(); cb.close()


def test_link_bandwidth_paces_throughput():
    """1 MiB at 80 Mbit/s must take ~100 ms of serialization on top of
    loopback (which is otherwise instant)."""
    payload = {"x": np.zeros(1 << 17)}            # 1 MiB of float64
    ca, cb = _sock_pair(link=LinkSpec(bandwidth_mbps=80))
    try:
        t0 = time.perf_counter()
        ca.send("b", "big", {"x": payload["x"]})
        cb.recv("a", "big", timeout=10.0)
        dt = time.perf_counter() - t0
        assert 0.09 <= dt < 1.0, dt
    finally:
        ca.close(); cb.close()


def test_link_jitter_preserves_fifo():
    rng_arrivals = []
    ca, cb = _sock_pair(link=LinkSpec(latency_ms=5, jitter_ms=20))
    try:
        for i in range(8):
            ca.isend("b", "j", {"x": np.array([float(i)])})
        for i in range(8):
            rng_arrivals.append(
                cb.recv("a", "j", timeout=10.0).tensor("x")[0])
        assert rng_arrivals == [float(i) for i in range(8)]
    finally:
        ca.close(); cb.close()


def test_unshaped_default_has_no_sleep_path():
    """CommCfg() with no link must keep the inline fast path (blocking
    sends do not detour through the sender thread)."""
    bus = ThreadBus(["m", "p"])
    cm = bus.communicator("m", comm_cfg=CommCfg())
    cp = bus.communicator("p")
    cm.send("p", "t", {"x": np.zeros(1)})
    cp.recv("m", "t")
    assert cm.stats.async_sends == 0      # inline fast path taken


def test_link_shaped_vfl_trains_and_is_bit_identical():
    """Shaping delays delivery but never reorders or corrupts: a
    shaped depth-1 linreg run still reproduces the seed trace exactly
    (socket mode, small link so the test stays fast)."""
    cfg, master, members = _linreg_case()
    res = run_vfl(cfg, master, members, mode="socket",
                  comm_cfg=CommCfg(link=LinkSpec(latency_ms=2)))
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)


# ---------------------------------------------------------------------------
# encode offload: snapshot contract + bit identity
# ---------------------------------------------------------------------------


def test_encode_offload_snapshot_contract():
    """A writeable array mutated right after isend must hit the wire
    with its enqueue-time contents (copy-on-enqueue)."""
    bus = ThreadBus(["m", "p"])
    cm = bus.communicator("m", comm_cfg=CommCfg(encode_offload=True))
    cp = bus.communicator("p")
    x = np.arange(16.0)
    fut = cm.isend("p", "snap", {"x": x})
    x[:] = -1.0                           # mutate immediately
    fut.result(5.0)
    np.testing.assert_array_equal(cp.recv("m", "snap").tensor("x"),
                                  np.arange(16.0))


def test_encode_offload_readonly_view_of_writeable_base_is_copied():
    """A read-only VIEW over a writeable base is still mutable through
    the base — the snapshot must copy it, or an in-place weight update
    after isend would change the bytes on the wire."""
    bus = ThreadBus(["m", "p"])
    cm = bus.communicator("m", comm_cfg=CommCfg(encode_offload=True))
    cp = bus.communicator("p")
    w = np.arange(8.0)
    view = w.view()
    view.flags.writeable = False
    fut = cm.isend("p", "view", {"x": view})
    w += 100.0                            # mutate through the base
    fut.result(5.0)
    np.testing.assert_array_equal(cp.recv("m", "view").tensor("x"),
                                  np.arange(8.0))


def test_link_jitter_seed_is_stable_across_interpreters():
    """Jitter must be reproducible run-to-run (hash() is salted per
    interpreter; spawned agent processes would otherwise jitter
    differently every rep, breaking min-over-reps comparisons)."""
    import subprocess
    import sys
    code = ("import sys; sys.path.insert(0, 'src');"
            "from repro.comm.local import ThreadBus;"
            "c = ThreadBus(['m']).communicator('m');"
            "print(c._link_rng.random())")
    outs = {subprocess.run([sys.executable, "-c", code], cwd=str(
        pathlib.Path(__file__).parents[1]), capture_output=True,
        text=True, check=True).stdout.strip() for _ in range(2)}
    assert len(outs) == 1, outs


def test_encode_offload_readonly_arrays_not_copied():
    """Read-only buffers (jax exports, received tensors) satisfy the
    snapshot contract for free and must not be copied."""
    bus = ThreadBus(["m", "p"])
    cm = bus.communicator("m", comm_cfg=CommCfg(encode_offload=True))
    x = np.arange(16.0)
    x.setflags(write=False)
    msg, raw = cm._make("p", "t", {"x": x}, None, encode=False)
    assert raw is None
    assert msg.payload["x"] is x          # no defensive copy


def test_encode_offload_error_is_not_sticky():
    """An encode failure (unsupported dtype) surfaces on the future but
    never touched the wire, so the engine keeps working."""
    bus = ThreadBus(["m", "p"])
    cm = bus.communicator("m", comm_cfg=CommCfg(encode_offload=True))
    cp = bus.communicator("p")
    bad = np.array([object()], dtype=object)
    fut = cm.isend("p", "bad", {"x": bad})
    with pytest.raises(TypeError):
        fut.result(5.0)
    cm.send("p", "ok", {"x": np.zeros(1)})       # engine still alive
    assert cp.recv("m", "ok").tensor("x")[0] == 0.0


@pytest.mark.parametrize("offload", [False, True])
def test_encode_offload_bit_identical_depth1(offload):
    """The tentpole's correctness bar: offloaded encode (the default)
    and caller-side encode both reproduce the recorded seed traces
    bit-identically at pipeline depth 1."""
    cfg, master, members = _linreg_case()
    res = run_vfl(cfg, master, members, mode="thread",
                  comm_cfg=CommCfg(encode_offload=offload))
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# gRPC transport: framing specifics (the mode matrix runs in
# test_async_engine.py via parametrization)
# ---------------------------------------------------------------------------


def test_hpack_roundtrip_including_long_values():
    hdrs = [(":path", "/repro.Party/Exchange"), ("grpc-agent", "m" * 300)]
    assert hpack_decode(hpack_encode(hdrs)) == dict(hdrs)


def test_grpc_wire_is_http2_shaped():
    """The bytes a GrpcCommunicator puts on the wire start with the
    HTTP/2 connection preface, a SETTINGS frame, and an HPACK hello."""
    srv = socket.create_server(("127.0.0.1", 0))
    ca = GrpcCommunicator("a", {"a": local_addresses(["a"])["a"],
                                "b": srv.getsockname()})
    try:
        ca.send("b", "t", {"x": np.zeros(2)})
        conn, _ = srv.accept()
        conn.settimeout(5.0)
        buf = b""
        while len(buf) < len(PREFACE) + 9:
            buf += conn.recv(4096)
        assert buf.startswith(PREFACE)
        frame = buf[len(PREFACE):]
        assert frame[3] == 0x4             # SETTINGS first
        conn.close()
    finally:
        ca.close()


# ---------------------------------------------------------------------------
# HTTP/2 flow control (RFC 7540 §5.2 / §6.9): the server advertises
# SETTINGS_INITIAL_WINDOW_SIZE and replenishes with WINDOW_UPDATE; the
# client blocks DATA writes on the advertised credit
# ---------------------------------------------------------------------------


def test_grpc_flow_control_large_payload_roundtrips():
    """A payload larger than the server's 16 MiB advertised window only
    crosses because WINDOW_UPDATE replenishment keeps granting credit;
    a client that ignored flow control would overrun, one that never
    saw credit would stall."""
    addrs = local_addresses(["a", "b"])
    ca = GrpcCommunicator("a", addrs, timeout=60.0)
    cb = GrpcCommunicator("b", addrs, timeout=60.0)
    try:
        big = np.random.default_rng(0).normal(size=(3 << 20,))  # 24 MiB
        ca.send("b", "big", {"x": big})
        np.testing.assert_array_equal(
            cb.recv("a", "big").tensor("x"), big)
        cb.send("a", "big2", {"x": big[: 1 << 20]})
        np.testing.assert_array_equal(
            ca.recv("b", "big2").tensor("x"), big[: 1 << 20])
    finally:
        ca.close()
        cb.close()


def test_grpc_client_honors_server_settings_window():
    """The per-connection reader applies the server's
    SETTINGS_INITIAL_WINDOW_SIZE advertisement and connection-level
    WINDOW_UPDATE — the flow state must not sit at the RFC default."""
    from repro.comm.grpc import DEFAULT_WINDOW, RECV_WINDOW
    addrs = local_addresses(["a", "b"])
    ca = GrpcCommunicator("a", addrs, timeout=30.0)
    cb = GrpcCommunicator("b", addrs, timeout=30.0)
    try:
        ca.send("b", "t", {"x": np.zeros(2)})
        cb.recv("a", "t")
        deadline = time.monotonic() + 5.0
        fc = None
        while time.monotonic() < deadline:
            fc = next(iter(ca._fc.values()), None)
            if fc is not None and fc.initial_window == RECV_WINDOW \
                    and fc.conn_window > DEFAULT_WINDOW:
                break
            time.sleep(0.01)
        assert fc is not None
        assert fc.initial_window == RECV_WINDOW
        assert fc.conn_window > DEFAULT_WINDOW
    finally:
        ca.close()
        cb.close()


def test_flow_state_blocks_until_credit_and_stall_is_attributed():
    from repro.comm.grpc import _FlowState
    fs = _FlowState()
    fs.open_stream(1)
    fs.conn_window = 8
    fs.consume(1, 8, timeout=1.0, who="b")          # exact fit
    timer = threading.Timer(0.2, lambda: fs.window_update(0, 64))
    timer.start()
    t0 = time.monotonic()
    fs.consume(1, 10, timeout=5.0, who="b")         # blocks, then passes
    assert time.monotonic() - t0 >= 0.15
    with pytest.raises(ConnectionError, match="flow-control stall"):
        fs.consume(1, 1 << 30, timeout=0.2, who="b")
    fs.close()
    with pytest.raises(ConnectionError, match="connection lost"):
        fs.consume(1, 1, timeout=0.2, who="b")


def test_flow_state_settings_delta_adjusts_open_streams():
    """RFC 7540 §6.9.2: a mid-connection SETTINGS change shifts every
    open stream window by the delta; the connection window is
    untouched."""
    from repro.comm.grpc import DEFAULT_WINDOW, _FlowState
    fs = _FlowState()
    fs.open_stream(1)
    fs.consume(1, 100, timeout=1.0, who="b")
    fs.apply_settings(70000)
    assert fs.initial_window == 70000
    assert fs.streams[1] == DEFAULT_WINDOW - 100 + (70000 - DEFAULT_WINDOW)
    assert fs.conn_window == DEFAULT_WINDOW - 100
    fs.open_stream(3)                               # new stream: new initial
    assert fs.streams[3] == 70000


def test_grpc_midstream_drop_attributed_and_raises():
    """A peer dying with an open stream fails waiters fast (the hello
    HEADERS on stream 1 attributed the connection)."""
    addrs = local_addresses(["a", "b"])
    cb = GrpcCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        hello = hpack_encode([(":path", "/repro.Party/Hello"),
                              ("grpc-agent", "a")])
        from repro.comm.grpc import (FLAG_END_HEADERS, FLAG_END_STREAM,
                                     FT_HEADERS, FT_SETTINGS, _frame)
        conn.sendall(PREFACE + _frame(FT_SETTINGS, 0, 0, b"")
                     + _frame(FT_HEADERS,
                              FLAG_END_HEADERS | FLAG_END_STREAM, 1,
                              hello))
        # open a data stream, then die without END_STREAM
        conn.sendall(_frame(FT_HEADERS, FLAG_END_HEADERS, 3,
                            hpack_encode([(":path",
                                           "/repro.Party/Exchange"),
                                          ("grpc-agent", "a")])))
        time.sleep(0.1)
        conn.close()
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never")
        assert time.monotonic() - t0 < 5
    finally:
        cb.close()


def test_grpc_corrupt_message_prefix_attributed():
    """A stream whose gRPC length prefix disagrees with the delivered
    body is a protocol violation from a known sender: waiters fail
    fast instead of hanging out the timeout."""
    from repro.comm.grpc import (FLAG_END_HEADERS, FLAG_END_STREAM,
                                 FT_DATA, FT_HEADERS, FT_SETTINGS,
                                 _frame)
    addrs = local_addresses(["a", "b"])
    cb = GrpcCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        hello = hpack_encode([(":path", "/repro.Party/Hello"),
                              ("grpc-agent", "a")])
        conn.sendall(PREFACE + _frame(FT_SETTINGS, 0, 0, b"")
                     + _frame(FT_HEADERS,
                              FLAG_END_HEADERS | FLAG_END_STREAM, 1,
                              hello))
        conn.sendall(_frame(FT_HEADERS, FLAG_END_HEADERS, 3,
                            hpack_encode([(":path",
                                           "/repro.Party/Exchange"),
                                          ("grpc-agent", "a")])))
        # prefix claims 999 bytes, delivers 3, then END_STREAM
        conn.sendall(_frame(FT_DATA, FLAG_END_STREAM, 3,
                            b"\x00" + (999).to_bytes(4, "big") + b"xyz"))
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never")
        assert time.monotonic() - t0 < 5
        conn.close()
    finally:
        cb.close()


def test_grpc_truncated_hpack_attributed_not_thread_killing():
    """Garbled HEADERS (HPACK block cut mid-integer) must mark the
    sender down — not kill the listener thread unhandled."""
    from repro.comm.grpc import (FLAG_END_HEADERS, FLAG_END_STREAM,
                                 FT_HEADERS, FT_SETTINGS, _frame)
    with pytest.raises(ValueError, match="HPACK"):
        hpack_decode(b"\x00\x7f")          # length continuation cut off
    addrs = local_addresses(["a", "b"])
    cb = GrpcCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        hello = hpack_encode([(":path", "/repro.Party/Hello"),
                              ("grpc-agent", "a")])
        conn.sendall(PREFACE + _frame(FT_SETTINGS, 0, 0, b"")
                     + _frame(FT_HEADERS,
                              FLAG_END_HEADERS | FLAG_END_STREAM, 1,
                              hello))
        conn.sendall(_frame(FT_HEADERS, FLAG_END_HEADERS, 3,
                            b"\x00\x7f"))  # truncated HPACK
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never")
        assert time.monotonic() - t0 < 5
        conn.close()
    finally:
        cb.close()


def test_commcfg_without_timeout_keeps_transport_default():
    """A CommCfg passed only for shaping must not silently replace a
    transport's deliberate timeout default (process mode runs 240 s
    for slow spawn imports) or an explicit constructor timeout."""
    from repro.comm.local import ThreadBus
    from repro.comm.process import ProcessBus

    bus = ProcessBus(["a", "b"])
    c = bus.communicator("a", comm_cfg=CommCfg(link=LinkSpec(
        latency_ms=1)))
    assert c._timeout == 240.0
    tb = ThreadBus(["a", "b"])
    c2 = tb.communicator("a", timeout=33.0,
                         comm_cfg=CommCfg(encode_offload=False))
    assert c2._timeout == 33.0
    c3 = tb.communicator("a", comm_cfg=CommCfg(timeout=7.0))
    assert c3._timeout == 7.0


def test_grpc_clean_close_between_streams_is_silent():
    addrs = local_addresses(["a", "b"])
    ca = GrpcCommunicator("a", addrs)
    cb = GrpcCommunicator("b", addrs)
    try:
        ca.send("b", "t0", {"x": np.ones(3)})
        ca.close()                          # boundary close
        assert cb.recv("a", "t0").tensor("x")[0] == 1.0
    finally:
        cb.close()


@pytest.mark.parametrize("depth", [2, 4])
def test_grpc_pipelined_convergence(depth):
    """The async-engine depth matrix on the gRPC transport: bounded
    staleness training converges the same as on sockets."""
    cfg, master, members = _linreg_case()
    sync = run_vfl(cfg, master, members, mode="grpc")
    res = run_vfl(cfg, master, members, mode="grpc",
                  pipeline_depth=depth)
    h = [r["loss"] for r in res["master"]["history"]]
    h_sync = [r["loss"] for r in sync["master"]["history"]]
    assert len(h) == len(h_sync)
    assert h[-1] < 0.25 * h[0], h
    assert h[-1] < 2.0 * h_sync[-1]
