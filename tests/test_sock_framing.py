"""Socket transport framing edge cases: partial reads are reassembled,
a connection dropping mid-frame surfaces a clean ``ConnectionError``
(instead of hanging until the timeout), the per-message timeout is
configurable and honored, and TCP_NODELAY is set on outbound links."""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.comm import codec
from repro.comm.sock import (SocketCommunicator, _recv_exact,
                             local_addresses)


def _wire_blob(sender: str, tag: str, payload) -> bytes:
    raw = codec.encode({k: np.asarray(v) for k, v in payload.items()},
                       {"sender": sender, "tag": tag})
    return struct.pack("<Q", len(raw)) + raw


def _hello(sender: str) -> bytes:
    """Connection hello: first frame on a link is the peer's agent id."""
    b = sender.encode()
    return struct.pack("<Q", len(b)) + b


def test_partial_reads_reassembled():
    """A frame dribbled in tiny chunks with pauses must still decode:
    _recv_exact loops until the byte count is satisfied."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=10.0)
    try:
        blob = _hello("a") + _wire_blob("a", "slow", {"x": np.arange(64.0)})
        conn = socket.create_connection(addrs["b"])

        def dribble():
            for i in range(0, len(blob), 7):
                conn.sendall(blob[i:i + 7])
                time.sleep(0.001)
        t = threading.Thread(target=dribble)
        t.start()
        msg = cb.recv("a", "slow")
        t.join()
        conn.close()
        np.testing.assert_array_equal(msg.tensor("x"), np.arange(64.0))
    finally:
        cb.close()


def test_recv_exact_raises_on_midframe_close():
    srv = socket.create_server(("127.0.0.1", 0))
    host, port = srv.getsockname()
    out = socket.create_connection((host, port))
    conn, _ = srv.accept()
    out.sendall(b"abc")
    out.close()
    with pytest.raises(ConnectionError, match="mid-frame"):
        _recv_exact(conn, 10)
    conn.close()
    srv.close()


def test_connection_drop_midframe_raises_not_hangs():
    """An established peer dying with half a frame on the wire must
    fail the pending recv quickly and cleanly."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        conn.sendall(_hello("a"))
        conn.sendall(_wire_blob("a", "ok", {"x": np.zeros(2)}))
        assert cb.recv("a", "ok").tag == "ok"       # sender established
        # half a frame, then the peer dies
        conn.sendall(struct.pack("<Q", 1 << 20) + b"only-the-start")
        conn.close()
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never")
        assert time.monotonic() - t0 < 5            # not the 30s timeout
    finally:
        cb.close()


def test_drop_during_first_data_frame_attributed_via_hello():
    """Even a peer that dies mid-way through its VERY FIRST message is
    identified (the connection hello names it) and fails waiters fast."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        conn.sendall(_hello("a"))
        conn.sendall(struct.pack("<Q", 1 << 20) + b"partial-first")
        conn.close()
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "anything")
        assert time.monotonic() - t0 < 5
    finally:
        cb.close()


def test_drop_inside_length_prefix_raises_not_hangs():
    """A drop with only part of the 8-byte length prefix delivered is
    still a mid-frame death, not a clean close."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=30.0)
    try:
        conn = socket.create_connection(addrs["b"])
        conn.sendall(_hello("a"))
        conn.sendall(_wire_blob("a", "ok", {"x": np.zeros(2)}))
        assert cb.recv("a", "ok").tag == "ok"
        conn.sendall(b"\x03\x00\x00")               # 3 of 8 prefix bytes
        conn.close()
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never")
        assert time.monotonic() - t0 < 5
    finally:
        cb.close()


def test_clean_close_between_frames_is_not_an_error():
    """A peer closing its socket at a frame boundary (normal shutdown)
    must not poison recvs of already-delivered messages."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=5.0)
    try:
        conn = socket.create_connection(addrs["b"])
        conn.sendall(_hello("a"))
        conn.sendall(_wire_blob("a", "t0", {"x": np.ones(3)}))
        conn.sendall(_wire_blob("a", "t1", {"x": np.ones(3) * 2}))
        conn.close()                                # boundary close
        assert cb.recv("a", "t0").tensor("x")[0] == 1
        assert cb.recv("a", "t1").tensor("x")[0] == 2
    finally:
        cb.close()


def test_timeout_configurable_and_honored():
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs, timeout=0.3)
    try:
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            cb.recv("a", "nothing")
        dt = time.monotonic() - t0
        assert 0.2 <= dt < 2.0, dt
        # per-call override beats the constructor default
        t0 = time.monotonic()
        with pytest.raises(TimeoutError):
            cb.recv("a", "nothing", timeout=0.8)
        assert time.monotonic() - t0 >= 0.7
    finally:
        cb.close()


def test_tcp_nodelay_set_on_outbound():
    addrs = local_addresses(["a", "b"])
    ca = SocketCommunicator("a", addrs)
    cb = SocketCommunicator("b", addrs)
    try:
        ca.send("b", "t", {"x": np.zeros(1)})
        assert ca._out["b"].getsockopt(socket.IPPROTO_TCP,
                                       socket.TCP_NODELAY) == 1
        cb.recv("a", "t")
        off = SocketCommunicator("a", local_addresses(["a"]),
                                 nodelay=False)
        off.close()
    finally:
        ca.close(); cb.close()


def test_large_frame_two_part_send_roundtrips():
    """Bodies above the inline threshold go out as prefix + body (no
    concat copy); the receiver sees one coherent frame."""
    addrs = local_addresses(["a", "b"])
    ca = SocketCommunicator("a", addrs)
    cb = SocketCommunicator("b", addrs)
    try:
        big = np.random.default_rng(0).normal(size=(256, 256))  # 512 KiB
        ca.send("b", "big", {"x": big})
        np.testing.assert_array_equal(cb.recv("a", "big").tensor("x"),
                                      big)
    finally:
        ca.close(); cb.close()
