"""Batched-HE correctness at scale: packing round-trips, homomorphic
ops on packed ciphertexts, CRT == plain decryption, randomness-pool
encryptions, the packed matvec vs numpy, variable-width ciphertext
transport, and packed-vs-unpacked end-to-end logreg_he equivalence."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.comm import codec
from repro.core import he

_KEYS = he.keygen(256)


# ---------------------------------------------------------------------------
# balanced-digit packing
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 24), st.integers(8, 60))
def test_pack_unpack_roundtrip_property(seed, count, slot_bits):
    rng = np.random.default_rng(seed)
    half = 1 << (slot_bits - 1)
    vals = [int(rng.integers(-half + 1, half)) for _ in range(count)]
    assert he.unpack_signed(he.pack_signed(vals, slot_bits),
                            slot_bits, count) == vals


def test_pack_handles_borrow_chains():
    """Adjacent negative slots exercise big-int borrow propagation."""
    vals = [-1, -1, 7, -3, 0, -(2**15) + 1, 2**15 - 1]
    p = he.pack_signed(vals, 17)
    assert he.unpack_signed(p, 17, len(vals)) == vals


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
def test_packed_encrypt_roundtrip(seed, count):
    pub, priv = _KEYS
    rng = np.random.default_rng(seed)
    vals = [int(v) for v in rng.integers(-2**40, 2**40, count)]
    cts = he.encrypt_packed(pub, vals, slot_bits=50)
    assert len(cts) < count or count == 1      # actually packs
    assert he.decrypt_packed(priv, cts, 50, count) == vals


def test_packed_homomorphic_add_and_scalar_mul():
    """pack -> encrypt -> add / mul_scalar -> decrypt -> unpack exactness
    vs numpy, within the guard-bit budget."""
    pub, priv = _KEYS
    rng = np.random.default_rng(1)
    a = rng.integers(-2**30, 2**30, 12)
    b = rng.integers(-2**30, 2**30, 12)
    slot = 44                                   # 31 value bits + guard
    ca = he.encrypt_packed(pub, [int(v) for v in a], slot)
    cb = he.encrypt_packed(pub, [int(v) for v in b], slot)
    summed = [pub.add(x, y) for x, y in zip(ca, cb)]
    assert he.decrypt_packed(priv, summed, slot, 12) == list(a + b)
    scaled = [pub.mul_scalar(x, 5) for x in ca]
    assert he.decrypt_packed(priv, scaled, slot, 12) == list(a * 5)


# ---------------------------------------------------------------------------
# CRT decryption
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(-10**12, 10**12))
def test_crt_decrypt_equals_plain(m):
    pub, priv = _KEYS
    c = pub.encrypt_int(m)
    assert priv.decrypt_int_crt(c) == priv.decrypt_int_plain(c) == m


def test_crt_decrypt_edges():
    pub, priv = _KEYS
    half = pub.n // 2
    for m in (0, 1, -1, half, -half + 1):
        c = pub.encrypt_int(m)
        assert priv.decrypt_int_crt(c) == priv.decrypt_int_plain(c) == m


def test_privatekey_without_factors_still_decrypts():
    pub, priv = _KEYS
    legacy = he.PrivateKey(pub, priv.lam, priv.mu)   # no p/q: plain path
    c = pub.encrypt_int(-31337)
    assert legacy.decrypt_int(c) == -31337


# ---------------------------------------------------------------------------
# randomness pool
# ---------------------------------------------------------------------------


def test_pool_encryptions_decrypt_identically():
    pub, priv = _KEYS
    pool = he.RandomnessPool(pub)
    pool.prefill(8)
    cts = [pool.encrypt_int(4242) for _ in range(10)]  # 8 pooled + 2 inline
    assert len(set(cts)) == len(cts), "blindings must be fresh"
    assert all(priv.decrypt_int(c) == 4242 for c in cts)
    # pooled and cold-path ciphertexts are interchangeable under ops
    c = pub.add(cts[0], pub.encrypt_int(-42))
    assert priv.decrypt_int(c) == 4200


def test_pool_background_fill_and_stop():
    pub, _ = _KEYS
    pool = he.RandomnessPool(pub)
    pool.start(target=6)
    deadline = threading.Event()
    for _ in range(100):
        if len(pool) >= 6:
            break
        deadline.wait(0.05)
    assert len(pool) >= 6
    pool.stop()
    assert pool._thread is None


def test_pooled_vector_encrypt_matches_plain_decrypt():
    pub, priv = _KEYS
    pool = he.RandomnessPool(pub)
    x = np.array([0.5, -1.25, 3.75, 0.0])
    c = he.encrypt_vector(pub, x, pool=pool)
    np.testing.assert_allclose(he.decrypt_vector(priv, c), x, atol=1e-8)


# ---------------------------------------------------------------------------
# multi-exponentiation + packed matvec
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
def test_multi_pow_matches_naive(seed, nbases):
    pub, _ = _KEYS
    rng = np.random.default_rng(seed)
    bases = [int.from_bytes(rng.bytes(8), "big") + 2
             for _ in range(nbases)]
    exps = [int.from_bytes(rng.bytes(10), "big") for _ in range(nbases)]
    tabs = he.pow_tables(bases, pub.n_sq)
    want = 1
    for b, e in zip(bases, exps):
        want = want * pow(b, e, pub.n_sq) % pub.n_sq
    assert he.multi_pow(exps, pub.n_sq, tabs) == want


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 24), st.integers(1, 24))
def test_packed_matvec_exact_vs_numpy(seed, b, d):
    """Packed homomorphic X^T r equals the exact integer matvec."""
    pub, priv = _KEYS
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, d)) * 3
    r = rng.normal(size=b) / b
    x_int = he.encode_fixed(x).reshape(b, d)
    r_int = he.encode_fixed(r)
    ciphers = [pub.encrypt_int(int(v)) for v in r_int]
    cts, info = he.packed_matvec(pub, x_int, ciphers,
                                 int(np.abs(r_int).max()))
    plains = [priv.decrypt_int(c) for c in cts]
    got = he.unpack_matvec(plains, info["slot_bits"], info["k"],
                           info["off_bits"], d)
    want = [int(sum(int(xv) * int(rv) for xv, rv in
                    zip(x_int[:, j], r_int))) for j in range(d)]
    assert got == want
    # ...and therefore matches numpy to fixed-point precision
    g = he.decode_fixed(got, (d,), scale_bits=2 * he.SCALE_BITS)
    np.testing.assert_allclose(g, x.T @ r, atol=1e-7)
    # the packing actually batches: fewer ciphertexts than features
    if d > info["k"] >= 2:
        assert len(cts) < d


def test_packed_matvec_matches_scalar_matvec():
    """Same integers out of both member gradient paths."""
    pub, priv = _KEYS
    rng = np.random.default_rng(3)
    x = rng.normal(size=(8, 5))
    r = rng.normal(size=8) / 8
    r_int = he.encode_fixed(r)
    ciphers = [pub.encrypt_int(int(v)) for v in r_int]
    x_int = he.encode_fixed(x).reshape(8, 5)
    cts, info = he.packed_matvec(pub, x_int, ciphers,
                                 int(np.abs(r_int).max()))
    packed = he.unpack_matvec([priv.decrypt_int(c) for c in cts],
                              info["slot_bits"], info["k"],
                              info["off_bits"], 5)
    scalar_cts = he.matvec_cipher(pub, x, np.array(ciphers, dtype=object))
    scalar = [priv.decrypt_int(int(c)) for c in scalar_cts]
    assert packed == scalar


# ---------------------------------------------------------------------------
# variable-width wire transport
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 20), st.integers(1, 96))
def test_cipher_wire_roundtrip(seed, count, width):
    rng = np.random.default_rng(seed)
    vals = [int.from_bytes(rng.bytes(width), "big") for _ in range(count)]
    arr = codec.ints_to_u8(vals, width)
    assert arr.shape == (count, width)
    assert codec.u8_to_ints(arr) == vals
    # survives the safetensors codec (the actual wire)
    out, _ = codec.decode(codec.encode({"c": arr}))
    assert codec.u8_to_ints(out["c"]) == vals


def test_encode_fixed_rejects_nan_inf():
    """NaN must fail fast (the seed's int(round()) raised too), not be
    silently cast to INT64_MIN and encrypted."""
    with pytest.raises(ValueError):
        he.encode_fixed(np.array([np.nan, 1.0]))
    with pytest.raises(ValueError):
        he.encode_fixed(np.array([np.inf]))
    with pytest.raises(OverflowError):
        he.encode_fixed(np.array([2.0 ** 40]))   # overflows at scale 32


def test_packed_matvec_rejects_oversized_slots():
    """A key too small for even one slot raises instead of silently
    wrapping past n/2 (and the protocol degrades to the scalar path)."""
    pub, _ = he.keygen(64)       # 62-bit capacity < one ~74-bit slot
    rng = np.random.default_rng(0)
    x_int = he.encode_fixed(rng.normal(size=(4, 3))).reshape(4, 3)
    ciphers = [pub.encrypt_int(1)] * 4
    with pytest.raises(ValueError):
        he.packed_matvec(pub, x_int, ciphers, 1 << he.SCALE_BITS)


def test_logreg_he_k1_boundary_key():
    """128-bit keys fit exactly one slot (K=1): packing still correct."""
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition

    rng = np.random.default_rng(2)
    n, d = 64, 6
    x = rng.normal(size=(n, d))
    y = (x @ rng.normal(size=(d, 1)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[3], seed=1)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=128, he_packed=True)
    res = run_vfl(cfg, master, members, mode="thread")
    h = [e["loss"] for e in res["master"]["history"]]
    assert h[-1] < h[0]


def test_member_falls_back_to_scalar_when_packing_impossible(monkeypatch):
    """If packed_matvec reports the key can't fit a slot, the member
    silently degrades to the scalar path and training proceeds."""
    import repro.core.protocols.logreg as logreg_mod
    from repro.core.party import run_vfl
    from repro.core.protocols.base import VFLConfig
    from repro.data.vertical import vertical_partition

    def no_fit(*args, **kwargs):
        raise ValueError("slot does not fit key")
    monkeypatch.setattr(logreg_mod.he, "packed_matvec", no_fit)

    rng = np.random.default_rng(3)
    n, d = 64, 6
    x = rng.normal(size=(n, d))
    y = (x @ rng.normal(size=(d, 1)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[3], seed=1)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256, he_packed=True)
    res = run_vfl(cfg, master, members, mode="thread")
    h = [e["loss"] for e in res["master"]["history"]]
    assert h[-1] < h[0]
    # scalar fallback: one decryption per gradient value
    assert res["arbiter"]["decrypted_values"] \
        == res["arbiter"]["recovered_values"]


def test_width_derived_from_key_handles_large_keys():
    """The seed's hardcoded 256-byte width truncated >=2048-bit keys;
    the derived width must cover n^2 exactly."""
    pub, _ = _KEYS
    assert pub.cipher_bytes == (2 * pub.n.bit_length() + 7) // 8
    big = he.PublicKey((1 << 2047) + 1)         # 2048-bit modulus stand-in
    assert big.cipher_bytes > 256
    c_max = big.n_sq - 1
    back = codec.u8_to_ints(codec.ints_to_u8([c_max], big.cipher_bytes))
    assert back == [c_max]


# ---------------------------------------------------------------------------
# end-to-end: packed vs unpacked training equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["thread"])
def test_logreg_he_packed_equals_unpacked(mode):
    import dataclasses

    from repro.core.party import run_vfl
    from repro.core.protocols.base import MasterData, VFLConfig
    from repro.data.vertical import vertical_partition

    rng = np.random.default_rng(0)
    n, d = 64, 12
    x = rng.normal(size=(n, d))
    y = (x @ rng.normal(size=(d, 1)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[8], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256, he_packed=True)
    packed = run_vfl(cfg, master, members, mode=mode)
    unpacked = run_vfl(dataclasses.replace(cfg, he_packed=False),
                       master, members, mode=mode)

    # identical training trajectory within fixed-point tolerance (the
    # packed path computes the *same integers*, so this is exact)
    np.testing.assert_allclose(
        [h["loss"] for h in packed["master"]["history"]],
        [h["loss"] for h in unpacked["master"]["history"]], atol=1e-6)
    np.testing.assert_allclose(packed["member0"]["w"],
                               unpacked["member0"]["w"], atol=1e-6)
    np.testing.assert_allclose(packed["master"]["w_master"],
                               unpacked["master"]["w_master"], atol=1e-6)

    # the arbiter decrypted ~K x fewer ciphertexts for the same values
    assert packed["arbiter"]["recovered_values"] \
        == unpacked["arbiter"]["recovered_values"]
    assert packed["arbiter"]["decrypted_values"] \
        <= unpacked["arbiter"]["decrypted_values"] / 2
