"""Composable tower factory (DESIGN.md §12): spec parsing/validation,
bit-identity of the default MLP path with the recorded seed traces,
transformer-tower convergence under pipelining, pallas-vs-reference
kernel parity, mesh sharding, roofline accounting, and the per-link
``[comm.a.b]`` CommCfg overrides that ride the same PR."""
import dataclasses
import json
import pathlib
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.core.party import run_vfl
from repro.core.protocols.base import VFLConfig
from repro.core.protocols.split_nn import (SplitNNProtocol, bottom_spec,
                                           mlp_init, top_spec)
from repro.data.vertical import vertical_partition
from repro.launch.roofline import step_account
from repro.models import tower as twr

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


def _dataset(n=128, d=12, items=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, (y > 0).astype(np.float64)


def _splitnn_case(**over):
    ids, x, yb = _dataset()
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    kw = dict(protocol="split_nn", epochs=3, batch_size=32, lr=0.1,
              seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    kw.update(over)
    return VFLConfig(**kw), master, members


TINY_TOWER = ("embed:tokens=4,dim=16", "attn_block:heads=2", "quantize",
              "mlp:hidden=16")


# ---------------------------------------------------------------------------
# spec parsing / validation
# ---------------------------------------------------------------------------


def test_parse_block_dsl():
    b = twr.parse_block("mlp:hidden=64|32,final_act=0")
    assert b == {"kind": "mlp", "hidden": (64, 32), "final_act": 0}
    assert twr.parse_block("attn:heads=2")["kind"] == "attn_block"
    assert twr.parse_block({"kind": "quantize"}) == {"kind": "quantize"}


@pytest.mark.parametrize("blocks,msg", [
    ((), "at least one block"),
    (("mlp", "embed"), "'embed' must be the first"),
    (("attn_block:heads=2", "mlp"), "needs an 'embed' block first"),
    (("embed", "mlp", "attn_block:heads=2", "mlp"),
     "must come before any 'mlp'"),
    (("embed", "mlp", "embed:tokens=2"), "'embed' must be the first"),
    (("embed",), "must be 'mlp'"),
    (("embed", "mlp", "quantize"), None),        # trailing quantize OK
    (("mlp:widht=3",), "unknown keys"),
    (("wat",), "unknown tower block kind"),
    (("mlp:hidden",), "expected key=val"),
    (("embed", "attn_block:heads=2,kernel=cuda", "mlp"),
     "kernel must be"),
    ((3,), "must be str or dict"),
    (({"hidden": (4,)},), "no 'kind'"),
])
def test_check_blocks_rejects(blocks, msg):
    if msg is None:
        twr.check_blocks(blocks)
        return
    with pytest.raises(ValueError, match=msg):
        twr.check_blocks(blocks)


def test_resolve_threads_widths():
    spec = twr.resolve(TINY_TOWER, in_dim=5, out_dim=8)
    assert spec.kinds == ("embed", "attn_block", "quantize", "mlp")
    e, a, _, m = spec.blocks
    assert e["tokens"] == 4 and e["chunk"] == 2      # ceil(5/4)
    assert a["dim"] == 16 and a["seq"] == 4 and a["mlp"] == 64
    assert m["dims"] == (16, 16, 8)
    assert (spec.in_dim, spec.out_dim) == (5, 8)


def test_resolve_rejects_indivisible_heads():
    with pytest.raises(ValueError, match="not divisible"):
        twr.resolve(("embed:dim=10", "attn_block:heads=4", "mlp"), 5, 8)


def test_legacy_dims_tower_warns_once():
    twr._warned_dims = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s1 = twr.legacy_dims_tower((5, 16, 8))
        twr.legacy_dims_tower((8, 4, 3))
    assert len(w) == 1 and issubclass(w[0].category, DeprecationWarning)
    assert s1.blocks[0]["dims"] == (5, 16, 8)
    # equivalent to the explicit mlp tower
    assert s1 == twr.mlp_tower(5, (16,), 8)


def test_recsys_config_dims_shims():
    from repro.configs.vfl_recsys import VFLRecsysConfig
    cfg = VFLRecsysConfig().reduced()
    bt = cfg.bottom_tower(64)
    assert bt.blocks[0]["dims"] == (64, 32, cfg.embedding_dim)
    tt = cfg.top_tower()
    assert tt.blocks[0]["dims"] == (16, 16, 8, cfg.n_items)
    assert tt.blocks[0]["final_act"] is False


# ---------------------------------------------------------------------------
# bit-identity: the default path IS the legacy MLP
# ---------------------------------------------------------------------------


def test_mlp_tower_params_match_legacy_mlp_init():
    key = jax.random.PRNGKey(7)
    legacy = mlp_init(key, (5, 16, 8))
    spec = twr.mlp_tower(5, (16,), 8)
    params = twr.init(spec, key)
    assert len(params) == 1
    for lp, tp in zip(legacy, params[0]):
        np.testing.assert_array_equal(np.asarray(lp["w"]),
                                      np.asarray(tp["w"]))
        np.testing.assert_array_equal(np.asarray(lp["b"]),
                                      np.asarray(tp["b"]))


def test_default_cfg_resolves_to_mlp_tower():
    cfg, master, members = _splitnn_case()
    bs = bottom_spec(cfg, 5)
    assert bs == twr.mlp_tower(5, cfg.hidden, cfg.embedding_dim)
    ts = top_spec(cfg, 3)
    assert ts.blocks[0]["final_act"] is False


def test_depth1_tower_path_matches_seed_trace():
    """The TowerSpec-backed split-NN at depth 1 reproduces the recorded
    seed losses bit-for-bit (same assertion as the legacy engine test,
    now exercising the factory path end to end)."""
    cfg, master, members = _splitnn_case()
    res = run_vfl(cfg, master, members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["split_nn"]["losses"], rtol=1e-6)


def test_checkpoint_migrates_legacy_flat_layers():
    """Pre-tower checkpoints stored the bottom/top as a flat layer list;
    load_state_dict must lift them into the one-block tower shape."""
    key = jax.random.PRNGKey(3)
    flat = mlp_init(key, (5, 16, 8))
    tower = SplitNNProtocol._as_tower(
        [{"w": np.asarray(l["w"]), "b": np.asarray(l["b"])}
         for l in flat])
    assert len(tower) == 1 and len(tower[0]) == 2
    np.testing.assert_array_equal(np.asarray(tower[0][0]["w"]),
                                  np.asarray(flat[0]["w"]))
    # already-nested state passes through unchanged
    again = SplitNNProtocol._as_tower(tower)
    assert again is tower or again == tower


def test_checkpoint_roundtrip_preserves_tower_blocks():
    """New-format checkpoints of embed-first towers must NOT trip the
    legacy flat-MLP migration: the embed block's param dict contains
    'w' too, and wrapping the whole tree as [state] collapses a 4-block
    tower to 1 entry whose apply() silently pairs wrong params."""
    spec = twr.resolve(TINY_TOWER, in_dim=5, out_dim=8)
    params = twr.init(spec, jax.random.PRNGKey(2))
    state = jax.tree.map(np.asarray, params)      # what state_dict saves
    back = SplitNNProtocol._as_tower(state)
    assert len(back) == len(spec.blocks) == 4
    assert set(back[0]) == {"w", "table", "pos"}  # embed stayed block 0
    x = np.random.default_rng(0).normal(size=(4, 5)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(twr.apply(spec, back, x)),
        np.asarray(twr.apply(spec, params, x)), rtol=1e-6)


# ---------------------------------------------------------------------------
# transformer tower: convergence + pipelining
# ---------------------------------------------------------------------------


def test_transformer_tower_converges_at_depth2():
    cfg, master, members = _splitnn_case(tower=TINY_TOWER,
                                         pipeline_depth=2)
    res = run_vfl(cfg, master, members, mode="thread")
    losses = [h["loss"] for h in res["master"]["history"]]
    assert losses[-1] < losses[0]
    roof = res["master"]["roofline"]
    assert roof["steps"] == len(losses)
    assert roof["model_flops_per_step"] > 0
    assert res["member0"]["roofline"]["model_bytes_per_step"] > 0


def test_tower_depths_agree_on_final_loss():
    """Bounded staleness: depth 2 converges to the neighborhood of the
    lock-step run (not bit-identical — gradients are stale)."""
    cfg, master, members = _splitnn_case(tower=TINY_TOWER, epochs=4)
    r1 = run_vfl(cfg, master, members, mode="thread")
    cfg2 = dataclasses.replace(cfg, pipeline_depth=2)
    r2 = run_vfl(cfg2, master, members, mode="thread")
    l1 = r1["master"]["history"][-1]["loss"]
    l2 = r2["master"]["history"][-1]["loss"]
    assert abs(l1 - l2) < 0.1


def test_top_tower_cfg_is_honored():
    cfg, master, members = _splitnn_case(
        top_tower=("mlp:hidden=8|4,final_act=0",), epochs=1)
    res = run_vfl(cfg, master, members, mode="thread")
    assert np.isfinite(res["master"]["history"][-1]["loss"])


# ---------------------------------------------------------------------------
# kernels: pallas (interpret) forward == reference forward
# ---------------------------------------------------------------------------


def test_attention_pallas_matches_ref():
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (2, 2, 4, 8), jnp_dtype())
               for i in range(3))
    ref = twr._attention(q, k, v, "ref")
    pal = twr._attention(q, k, v, "pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fake_quant_pallas_matches_ref_and_is_ste():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (8, 16), jnp_dtype())
    ref = twr.fake_quant(x, "ref")
    pal = twr.fake_quant(x, "pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # straight-through gradient: d(sum(fq(x)))/dx == 1
    g = jax.grad(lambda t: twr.fake_quant(t, "ref").sum())(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(g))


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


# ---------------------------------------------------------------------------
# sharding: sharded == unsharded (subprocess: needs >1 host device)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.models import tower as twr

spec = twr.resolve(("embed:tokens=4,dim=16", "attn_block:heads=2",
                    "mlp:hidden=16"), in_dim=5, out_dim=8)
key = jax.random.PRNGKey(0)
params = twr.init(spec, key)
x = jax.random.normal(jax.random.fold_in(key, 99), (32, 5))
plain = twr.apply(spec, params, x)

rules = twr.make_tower_rules(4)
sh = twr.shard_tower(params, spec, rules)
out = twr.apply(spec, sh, x, rules=rules)
np.testing.assert_allclose(np.asarray(out), np.asarray(plain),
                           rtol=1e-5, atol=1e-6)
print("SHARD_OK", float(np.abs(np.asarray(out) - np.asarray(plain)).max()))
"""


def test_sharded_tower_matches_unsharded():
    env = dict(__import__("os").environ)
    env["PYTHONPATH"] = str(pathlib.Path(__file__).parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARD_OK" in r.stdout


def test_make_tower_rules_guards_device_count():
    assert twr.make_tower_rules(1) is None
    if len(jax.devices()) < 64:
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            twr.make_tower_rules(64)


# ---------------------------------------------------------------------------
# roofline accounting
# ---------------------------------------------------------------------------


def test_step_account_splits_wall():
    acc = step_account(
        10.0, 100,
        {"recv_wait_s": 2.0, "send_s": 1.0, "queued_s": 0.5,
         "wire_s": 1.5, "sent_bytes": 1000.0},
        profile={"flops_per_step": 2e6, "bytes_per_step": 1e3,
                 "params_bytes": 4096})
    assert acc["steps"] == 100
    assert acc["compute_s_per_step"] == pytest.approx(0.07)
    assert acc["wire_s_per_step"] == pytest.approx(0.03)
    assert acc["stall_s_per_step"] == pytest.approx(0.02)
    assert acc["dominant"] == "compute"
    assert acc["exchange_intensity"] == pytest.approx(2000.0)
    assert acc["params_bytes"] == 4096


def test_driver_result_carries_roofline():
    cfg, master, members = _splitnn_case(epochs=1)
    res = run_vfl(cfg, master, members, mode="thread")
    for role in ("master", "member0"):
        roof = res[role]["roofline"]
        assert roof["steps"] > 0
        assert roof["wall_s_per_step"] > 0
        assert 0.0 <= roof["stall_frac"]
        assert roof["model_flops_per_step"] > 0


def test_roofline_profile_counts_tower_flops():
    cfg, master, members = _splitnn_case(tower=TINY_TOWER)
    spec = bottom_spec(cfg, 5)
    per_fwd = twr.tower_flops(spec, cfg.batch_size)
    proto = SplitNNProtocol.__new__(SplitNNProtocol)
    proto.cfg, proto.role = cfg, "member0"
    proto._spec = spec
    proto.params = twr.init(spec, jax.random.PRNGKey(0))
    prof = proto.roofline_profile()
    assert prof["flops_per_step"] == pytest.approx(3.0 * per_fwd)
    assert prof["bytes_per_step"] == pytest.approx(
        2.0 * cfg.batch_size * cfg.embedding_dim * 4)


# ---------------------------------------------------------------------------
# per-link CommCfg ([comm.a.b] edge overrides)
# ---------------------------------------------------------------------------


def _edge_spec_dict(comm):
    return {
        "protocol": {"name": "split_nn", "epochs": 1},
        "agents": {"master": "127.0.0.1:7001",
                   "member0": "127.0.0.1:7002",
                   "member1": "127.0.0.1:7003"},
        "hosts": {"h0": {"control": "127.0.0.1:7100",
                         "agents": ["master", "member0", "member1"]}},
        "comm": comm,
    }


def test_spec_edge_overrides_resolve_per_role():
    from repro.launch.cluster import _spec_from_dict
    spec = _spec_from_dict(_edge_spec_dict({
        "framing": "sock", "timeout": 30.0,
        "link": {"latency_ms": 1.0},
        "master": {"member0": {"latency_ms": 50.0,
                               "bandwidth_mbps": 10.0},
                   "member1": {"timeout": 5.0}},
    }), pathlib.Path("."))
    spec.validate()
    cm = spec.comm_for("master")
    assert cm.peer_overrides["member0"].link.latency_ms == 50.0
    # link-only edge: timeout unset, the transport falls back to the
    # world-level 30.0 (so a job-level comm_timeout still reaches it)
    assert cm.peer_overrides["member0"].timeout is None
    # timeout-only edge: link unset — it rides the shared world link
    # (and its "*" clock / runtime set_link swaps), not a pinned copy
    assert cm.peer_overrides["member1"].link is None
    assert cm.peer_overrides["member1"].timeout == 5.0
    # symmetric: the member sees the same edge toward the master
    c0 = spec.comm_for("member0")
    assert set(c0.peer_overrides) == {"master"}
    assert c0.peer_overrides["master"].link.bandwidth_mbps == 10.0
    # roles with no edges resolve to the plain cfg
    spec2 = _spec_from_dict(_edge_spec_dict({"framing": "sock"}),
                            pathlib.Path("."))
    assert spec2.comm_for("master") is spec2.comm


@pytest.mark.parametrize("comm,msg", [
    ({"master": {"member0": {"tls": {}}}}, "unknown keys"),
    ({"master": {"member0": 5}}, "per-peer tables"),
    ({"master": {"nobody": {"loss": 0.1}}}, "not an agent"),
    ({"master": {"master": {"loss": 0.1}}}, "self"),
    ({"master": {"member0": {"latency_ms": 1.0}},
      "member0": {"master": {"latency_ms": 2.0}}}, "symmetric"),
])
def test_spec_edge_overrides_reject(comm, msg):
    from repro.launch.cluster import _spec_from_dict
    with pytest.raises(ValueError, match=msg):
        spec = _spec_from_dict(_edge_spec_dict(comm), pathlib.Path("."))
        spec.validate()


def test_spec_validates_tower_blocks():
    from repro.launch.cluster import _spec_from_dict
    raw = _edge_spec_dict({"framing": "sock"})
    raw["protocol"]["tower"] = ["embed", "attn_block:heads=0,heads=2"]
    with pytest.raises(ValueError, match=r"\[protocol\] tower"):
        _spec_from_dict(raw, pathlib.Path(".")).validate()
    raw["protocol"]["tower"] = ["embed", "mlp"]
    raw["protocol"]["tower_shard"] = 0
    with pytest.raises(ValueError, match="tower_shard"):
        _spec_from_dict(raw, pathlib.Path(".")).validate()


def test_engine_honors_peer_link_overrides():
    """Only the overridden edge is shaped; the default edge stays
    fast. (ThreadBus + CommCfg.peer_overrides, no cluster involved.)"""
    import time

    from repro.comm.base import CommCfg, LinkSpec
    from repro.comm.local import ThreadBus, ThreadCommunicator

    bus = ThreadBus(["a", "b", "c"])
    cfg = CommCfg(peer_overrides={
        "b": CommCfg(link=LinkSpec(latency_ms=80.0))})
    ca = ThreadCommunicator("a", bus, comm_cfg=cfg)
    cb = ThreadCommunicator("b", bus)
    cc = ThreadCommunicator("c", bus)
    x = {"x": np.zeros(4)}
    t0 = time.monotonic()
    ca.send("c", "t", x)
    cc.recv("a", "t")
    fast = time.monotonic() - t0
    t0 = time.monotonic()
    ca.send("b", "t", x)
    cb.recv("a", "t")
    slow = time.monotonic() - t0
    assert slow >= 0.07
    assert fast < slow
    for c in (ca, cb, cc):
        c.close()


def test_engine_peer_timeout_override():
    from repro.comm.base import CommCfg
    from repro.comm.local import ThreadBus, ThreadCommunicator

    bus = ThreadBus(["a", "b"])
    cfg = CommCfg(timeout=60.0,
                  peer_overrides={"b": CommCfg(timeout=0.2)})
    ca = ThreadCommunicator("a", bus, comm_cfg=cfg)
    with pytest.raises(TimeoutError):
        ca.recv("b", "never")
    ca.close()


def test_timeout_only_override_follows_set_link():
    """A [comm.a.b] edge that only customizes its timeout must not be
    pinned: scripted chaos (set_link partition/slow) still shapes it."""
    import time

    from repro.comm.base import CommCfg, LinkSpec
    from repro.comm.local import ThreadBus, ThreadCommunicator

    bus = ThreadBus(["a", "b"])
    cfg = CommCfg(timeout=60.0,
                  peer_overrides={"b": CommCfg(timeout=30.0)})
    ca = ThreadCommunicator("a", bus, comm_cfg=cfg)
    cb = ThreadCommunicator("b", bus)
    assert "b" not in ca._peer_links          # not pinned
    ca.set_link(LinkSpec(latency_ms=80.0))
    x = {"x": np.zeros(4)}
    t0 = time.monotonic()
    ca.send("b", "t", x)
    cb.recv("a", "t")
    assert time.monotonic() - t0 >= 0.07      # chaos swap reached it
    for c in (ca, cb):
        c.close()


def test_comm_timeout_overrides_edge_pinned_timeouts():
    """VFLJob's comm_timeout rewrites the per-message wait everywhere,
    including timeouts pinned by [comm.a.b] peer_overrides."""
    from repro.comm.base import CommCfg, LinkSpec
    from repro.core.party import _force_comm_timeout

    cfg = CommCfg(timeout=60.0, peer_overrides={
        "member0": CommCfg(timeout=5.0,
                           link=LinkSpec(latency_ms=3.0)),
        "member1": CommCfg(timeout=5.0)})
    out = _force_comm_timeout(cfg, 0.5)
    assert out.timeout == 0.5
    assert all(o.timeout == 0.5 for o in out.peer_overrides.values())
    # link pins survive — only the waits are rewritten
    assert out.peer_overrides["member0"].link.latency_ms == 3.0


def test_vfljob_honors_comm_cfgs():
    """VFLJob plumbs per-role resolved CommCfgs (what from_spec builds
    from [comm.a.b] edges) down to each agent's communicator; the run
    still trains and carries the roofline account."""
    from repro.comm.base import CommCfg, LinkSpec
    from repro.core.party import VFLJob
    cfg, master, members = _splitnn_case(epochs=1)
    edge = CommCfg(peer_overrides={
        "member0": CommCfg(link=LinkSpec(latency_ms=2.0))})
    cfgs = {"master": edge,
            "member0": CommCfg(peer_overrides={
                "master": CommCfg(link=LinkSpec(latency_ms=2.0))})}
    job = VFLJob(cfg, master, members, mode="thread", comm_cfgs=cfgs)
    try:
        fit = job.fit()
        assert np.isfinite(fit["history"][-1]["loss"])
    finally:
        res = job.shutdown()
    assert res["master"]["roofline"]["steps"] > 0
    # the shaped link actually metered wire time
    assert res["master"]["comm"]["wire_s"] > 0
