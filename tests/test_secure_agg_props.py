"""Property tests for pairwise-masked secure aggregation.

The defense claim in docs/privacy.md rests on two exactness
properties of :class:`~repro.core.secure_agg_protocol.PairwiseMasker`:

1. **Telescoping** — summed over the member set, masks cancel *bit
   for bit* (not approximately): the PRG emits values on a fixed
   dyadic grid (multiples of 2^-10, |z| <= 8 clipped), so every mask
   entry and every bounded partial sum is exactly representable in
   float32 and the +/- streams of each pair annihilate in ANY
   summation order.
2. **Transparency** — when the member data itself sums exactly (also
   grid-valued), the masked sum equals the plain sum bit-for-bit, so
   secure aggregation costs exactly zero utility (the privacy.json
   ``secure_agg`` rows report utility_delta 0.0 by construction).

Runs under real hypothesis when installed, else the deterministic
shim (tests/_hypothesis_compat.py).
"""
import threading

import numpy as np

from _hypothesis_compat import given, settings, strategies as st
from repro.comm.local import ThreadBus
from repro.core.secure_agg_protocol import PairwiseMasker


def _mesh(n_members):
    """Full pairwise key agreement between n members over a ThreadBus
    (each masker's DH exchange blocks on its peers, hence threads)."""
    names = [f"member{i}" for i in range(n_members)]
    bus = ThreadBus(names)
    out = {}

    def mk(me):
        out[me] = PairwiseMasker(bus.communicator(me), me, names)

    ts = [threading.Thread(target=mk, args=(m,)) for m in names]
    [t.start() for t in ts]
    [t.join() for t in ts]
    return [out[m] for m in names]


@given(st.integers(2, 5), st.integers(0, 10_000),
       st.integers(1, 7), st.integers(1, 9))
@settings(max_examples=8, deadline=None)
def test_masks_cancel_bit_exact(n_members, rnd, rows, cols):
    """Sum of all members' round-``rnd`` masks is exactly 0.0 — and in
    reversed order too, because grid values make fp32 addition exact."""
    masks = [m.mask(rnd, (rows, cols)) for m in _mesh(n_members)]
    fwd = np.zeros((rows, cols), np.float32)
    for m in masks:
        fwd = fwd + m
    rev = np.zeros((rows, cols), np.float32)
    for m in reversed(masks):
        rev = rev + m
    assert fwd.dtype == np.float32 and rev.dtype == np.float32
    assert np.all(fwd == 0.0)
    assert np.all(rev == 0.0)
    # ... and the masks are not trivially zero (masking actually hides)
    assert max(float(np.abs(m).max()) for m in masks) > 0.1


@given(st.integers(2, 4), st.integers(0, 500), st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_masked_sum_equals_plain_sum(n_members, rnd, data_seed):
    """Grid-valued member tensors: sum(u_i + mask_i) == sum(u_i)
    bit-for-bit — the aggregate the master computes under secure_agg
    is *identical* to the unmasked aggregate."""
    shape = (6, 8)
    rng = np.random.default_rng(data_seed)
    us = [(rng.integers(-8192, 8192, shape) / 1024.0).astype(np.float32)
          for _ in range(n_members)]
    maskers = _mesh(n_members)
    masked = [u + m.mask(rnd, shape) for u, m in zip(us, maskers)]
    plain_sum = np.zeros(shape, np.float32)
    masked_sum = np.zeros(shape, np.float32)
    for u, mu in zip(us, masked):
        plain_sum = plain_sum + u
        masked_sum = masked_sum + mu
    assert masked_sum.dtype == plain_sum.dtype == np.float32
    assert np.array_equal(masked_sum, plain_sum)
    # each individual wire tensor differs from the raw one
    for u, mu in zip(us, masked):
        assert not np.array_equal(mu, u)


@given(st.integers(2, 5), st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_pair_streams_equal_and_opposite(n_members, rnd):
    """Each pair (i, j) derives the same DH seed, and i's signed PRG
    contribution is the exact negation of j's — the telescoping is
    per-pair, so ANY subset of complete pairs cancels."""
    maskers = _mesh(n_members)
    by_name = {m.me: m for m in maskers}
    shape = (3, 5)
    for a in maskers:
        for other, seed in a.seeds.items():
            b = by_name[other]
            assert b.seeds[a.me] == seed
            sa = 1.0 if a.me < other else -1.0
            sb = 1.0 if b.me < a.me else -1.0
            pa = sa * a._prg(seed, rnd, shape)
            pb = sb * b._prg(b.seeds[a.me], rnd, shape)
            assert np.array_equal(pa, -pb)


@given(st.integers(0, 10_000), st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_prg_grid_quantized(rnd, cols):
    """Every PRG value sits on the 2^-10 dyadic grid within [-8, 8] —
    the invariant the exact-cancellation argument rests on."""
    m0, _ = _mesh(2)
    seed = next(iter(m0.seeds.values()))
    z = m0._prg(seed, rnd, (16, cols))
    assert z.dtype == np.float32
    assert float(np.abs(z).max()) <= 8.0
    scaled = z.astype(np.float64) * 1024.0
    assert np.array_equal(scaled, np.round(scaled))
