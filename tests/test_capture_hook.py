"""Exchange-capture hook (cfg.capture_exchanges): the adversarial
harness's tap must be measurement-grade — OFF it leaves no trace and
the protocols reproduce the recorded seed fixtures bit-for-bit; ON it
records what crossed the wire without perturbing a single loss value,
at pipeline depth 1 and under async overlap (depth >= 2).
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core.party import run_vfl
from repro.core.protocols.base import VFLConfig
from repro.core.protocols.driver import OP_RUN
from repro.data.vertical import vertical_partition

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


def _dataset(n=192, d=12, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, y


def _logreg_case():
    ids, x, y = _dataset(n=64, d=8, items=1)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[3], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32,
                    lr=0.5, seed=0, use_psi=False, he_bits=256)
    return cfg, master, members


def _splitnn_case():
    ids, x, y = _dataset(n=128, d=12, items=3)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=32,
                    lr=0.1, seed=0, use_psi=False, embedding_dim=8,
                    hidden=(16,))
    return cfg, master, members


def test_capture_off_is_seed_identical_and_exports_nothing():
    """The default (capture off) run still reproduces the recorded
    seed trace bit-for-bit and leaves no capture key in any result —
    the hook is free when unused."""
    cfg, master, members = _logreg_case()
    assert cfg.capture_exchanges is False
    res = run_vfl(cfg, master, members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["logreg_he"]["losses"], rtol=0, atol=0)
    for role, r in res.items():
        assert "capture" not in r, role


def test_capture_on_logreg_bit_identical_to_trace():
    """Capture ON: the f64 HE-logreg path must stay bit-identical to
    the seed fixture — recording is observation, not intervention."""
    cfg, master, members = _logreg_case()
    cfg = dataclasses.replace(cfg, capture_exchanges=True)
    res = run_vfl(cfg, master, members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["logreg_he"]["losses"], rtol=0, atol=0)
    np.testing.assert_allclose(res["master"]["w_master"],
                               TRACES["logreg_he"]["w_master"],
                               rtol=0, atol=0)
    np.testing.assert_allclose(res["member0"]["w"],
                               TRACES["logreg_he"]["w_members"][0],
                               rtol=0, atol=0)
    # every role exported a capture dict
    for role in ("master", "member0", "arbiter"):
        assert "capture" in res[role], role


@pytest.mark.parametrize("depth", [1, 2])
def test_capture_on_does_not_perturb_splitnn(depth):
    """Same split-NN run with and without capture: loss histories are
    equal float-for-float, at depth 1 and under async overlap."""
    cfg, master, members = _splitnn_case()
    cfg = dataclasses.replace(cfg, pipeline_depth=depth)
    plain = run_vfl(cfg, master, members, mode="thread")
    tapped = run_vfl(dataclasses.replace(cfg, capture_exchanges=True),
                     master, members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in tapped["master"]["history"]],
        [h["loss"] for h in plain["master"]["history"]],
        rtol=0, atol=0)
    if depth == 1:
        np.testing.assert_allclose(
            [h["loss"] for h in tapped["master"]["history"]],
            TRACES["split_nn"]["losses"], rtol=1e-6)


def test_capture_records_both_vantage_points():
    """Record structure: the member's capture holds its received
    ``ctrl/step`` announcements (op/epoch/lo/hi), the master's holds
    each member's ``splitnn/u`` activations — the two vantage points
    the label-inference attacks replay."""
    cfg, master, members = _splitnn_case()
    cfg = dataclasses.replace(cfg, capture_exchanges=True)
    res = run_vfl(cfg, master, members, mode="thread")

    mcap = res["member0"]["capture"]
    steps = [r for r in mcap["records"] if r["name"] == "ctrl/step"
             and r["dir"] == "recv" and r["peer"] == "master"]
    assert steps, "member captured no step announcements"
    runs = [r for r in steps
            if int(np.asarray(r["payload"]["op"])[0]) == OP_RUN]
    assert len(runs) == len(res["master"]["history"])
    for r in runs:
        lo = int(np.asarray(r["payload"]["lo"])[0])
        hi = int(np.asarray(r["payload"]["hi"])[0])
        assert 0 <= lo < hi <= 128

    cap = res["master"]["capture"]
    us = [r for r in cap["records"] if r["name"] == "splitnn/u"
          and r["dir"] == "recv" and r["peer"] == "member0"]
    assert len(us) == len(res["master"]["history"])
    for r in us:
        u = np.asarray(r["payload"]["u"])
        assert u.ndim == 2 and u.shape[1] == cfg.embedding_dim
    # payloads are defensive copies, not views of live buffers
    u0 = us[0]["payload"]["u"]
    assert isinstance(u0, np.ndarray) and u0.flags.owndata
