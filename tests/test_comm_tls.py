"""TLS on the TCP transports (DESIGN.md §9): mutual-TLS wrapping of
both framings, clean attributed failures for misconfigured peers (no
hang-to-timeout), and bit-identity of TLS'd depth-1 runs with the
recorded seed traces — including composed with WAN link shaping and
encode offload (the snapshot contract)."""
import json
import pathlib
import time

import numpy as np
import pytest

from repro.comm.base import CommCfg, LinkSpec, TLSSpec
from repro.comm.grpc import GrpcCommunicator
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core.party import run_vfl
from repro.core.protocols.base import VFLConfig
from repro.data.vertical import vertical_partition
from repro.launch.certs import TestCA, have_openssl

pytestmark = pytest.mark.skipif(
    not have_openssl(), reason="openssl CLI required to mint test certs")

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


@pytest.fixture(scope="session")
def certs(tmp_path_factory):
    ca = TestCA(tmp_path_factory.mktemp("certs"))
    for n in ("a", "b", "master", "member0", "member1"):
        ca.issue(n)
    return ca


@pytest.fixture(scope="session")
def other_ca(tmp_path_factory):
    ca = TestCA(tmp_path_factory.mktemp("certs2"))
    ca.issue("a")
    return ca


def _pair(cls, cfg_a, cfg_b):
    addrs = local_addresses(["a", "b"])
    return cls("a", addrs, comm_cfg=cfg_a), cls("b", addrs,
                                                comm_cfg=cfg_b)


@pytest.mark.parametrize("cls", [SocketCommunicator, GrpcCommunicator])
def test_tls_roundtrip_both_framings(cls, certs):
    cfg = CommCfg(timeout=20.0, tls=certs.templated_spec())
    ca_, cb = _pair(cls, cfg, cfg)
    try:
        cb.send("a", "t", {"x": np.arange(4.0)})
        msg = ca_.recv("b", "t")
        np.testing.assert_array_equal(msg.tensor("x"), np.arange(4.0))
        ca_.send("b", "r", {"x": np.ones(2)}, meta={"k": "v"})
        assert cb.recv("a", "r").meta["k"] == "v"
    finally:
        ca_.close()
        cb.close()


def test_wrong_ca_fails_fast_with_peer_attribution(certs, other_ca):
    """An untrusted server certificate must surface as an immediate
    ConnectionError naming the peer — not a retry loop or a hang."""
    good = CommCfg(timeout=30.0, tls=certs.templated_spec())
    # client trusts the WRONG CA: server cert verification fails
    bad = CommCfg(timeout=30.0, tls=TLSSpec(
        cert=str(certs.dir / "a.crt"), key=str(certs.dir / "a.key"),
        ca=other_ca.ca_cert))
    ca_, cb = _pair(SocketCommunicator, bad, good)
    try:
        t0 = time.monotonic()
        with pytest.raises(ConnectionError) as ei:
            ca_.send("b", "t", {"x": np.zeros(1)})
        assert time.monotonic() - t0 < 10.0      # no hang-to-timeout
        assert "'b'" in str(ei.value)
        assert "TLS handshake" in str(ei.value)
    finally:
        ca_.close()
        cb.close()


def test_plaintext_client_rejected_by_tls_server(certs):
    """A plaintext client against a TLS server must get a clean
    ConnectionError, not a silent hang: the server drops the
    connection when the hello frame fails the TLS handshake."""
    srv_cfg = CommCfg(timeout=20.0, tls=certs.templated_spec())
    addrs = local_addresses(["a", "b"])
    srv = SocketCommunicator("b", addrs, comm_cfg=srv_cfg)
    cli = SocketCommunicator("a", addrs, timeout=10.0)   # no TLS
    try:
        with pytest.raises(ConnectionError):
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                cli.send("b", "t", {"x": np.zeros(8)})
                time.sleep(0.05)
            pytest.fail("plaintext sends kept succeeding against a "
                        "TLS server")
    finally:
        cli.close()
        srv.close()


def test_tls_client_against_plaintext_server_times_out_cleanly(certs):
    """The inverse mismatch: the TLS client's handshake never gets a
    ServerHello; it must fail as an attributed ConnectionError within
    the configured timeout."""
    cli_cfg = CommCfg(timeout=2.0, tls=certs.templated_spec())
    addrs = local_addresses(["a", "b"])
    srv = SocketCommunicator("b", addrs, timeout=5.0)    # no TLS
    cli = SocketCommunicator("a", addrs, comm_cfg=cli_cfg)
    try:
        t0 = time.monotonic()
        with pytest.raises(ConnectionError) as ei:
            cli.send("b", "t", {"x": np.zeros(1)})
        assert time.monotonic() - t0 < 10.0
        assert "'b'" in str(ei.value)
    finally:
        cli.close()
        srv.close()


# ---------------------------------------------------------------------------
# bit-identity: TLS wraps the wire only
# ---------------------------------------------------------------------------


def _linreg_case():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(192, 12))
    w = rng.normal(size=(12, 2))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(192, 2))
    ids = [f"u{i:05d}" for i in range(192)]
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False, pipeline_depth=1)
    return cfg, master, members


def _assert_matches_seed_trace(res):
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)
    np.testing.assert_allclose(res["master"]["w_master"],
                               TRACES["linreg"]["w_master"],
                               rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


@pytest.mark.parametrize("mode", ["socket", "grpc", "grpc_proc"])
def test_depth1_linreg_bit_identical_over_tls(mode, certs):
    """TLS changes the wire bytes, nothing above them: depth-1 runs
    over both TLS'd framings (threads and one-process-per-agent) must
    reproduce the recorded seed traces bit-identically."""
    cfg, master, members = _linreg_case()
    comm = CommCfg(timeout=60.0, tls=certs.templated_spec())
    res = run_vfl(cfg, master, members, mode=mode, comm_cfg=comm)
    _assert_matches_seed_trace(res)


def test_grpc_tls_link_shaping_composes_bit_identical(certs):
    """TLS + LinkSpec WAN shaping + sender-thread encode offload all
    compose: the shaped, encrypted, offloaded depth-1 run still equals
    the seed trace exactly (the snapshot contract holds under TLS)."""
    cfg, master, members = _linreg_case()
    comm = CommCfg(timeout=60.0, tls=certs.templated_spec(),
                   link=LinkSpec(latency_ms=2.0), encode_offload=True)
    res = run_vfl(cfg, master, members, mode="grpc", comm_cfg=comm)
    _assert_matches_seed_trace(res)
