"""Elastic clusters (docs/deploy.md): connect backoff with a named
deadline error, link loss / blackhole shaping, strict-EOF drop
attribution, straggler tolerance via stale substitution at depth >= 2,
per-peer channel reset, and the full crash -> restart -> rejoin
handshake run in-process over real sockets."""
import threading
import time

import numpy as np
import pytest

from repro.comm import schema
from repro.comm.base import CommCfg, LinkSpec
from repro.comm.local import ThreadBus
from repro.comm.schema import Field, TypedChannel
from repro.comm.sock import SocketCommunicator, local_addresses
from repro.core.party import PartyMaster, PartyMember, run_vfl
from repro.core.protocols.base import VFLConfig
from repro.core.protocols.driver import (Callback, Checkpointer,
                                         ElasticCfg)
from repro.data.vertical import vertical_partition

schema.message("el/z", {"z": Field("float64", 1)}, stepped=True)


def _linreg_case(epochs=3):
    rng = np.random.default_rng(0)
    n, d, items = 192, 12, 2
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=epochs, batch_size=48,
                    lr=0.1, seed=0, use_psi=False)
    return cfg, master, members


def _sock_pair(**cfg_kw):
    addrs = local_addresses(["a", "b"])
    ca = SocketCommunicator("a", addrs,
                            comm_cfg=CommCfg(**cfg_kw) if cfg_kw else None)
    cb = SocketCommunicator("b", addrs)
    return ca, cb


# ---------------------------------------------------------------------------
# connect backoff
# ---------------------------------------------------------------------------


def test_connect_deadline_error_names_peer_and_attempts():
    """A peer that never comes up fails the connect with an error that
    names WHO was unreachable, WHERE, and for how long — and the
    backed-off retry loop makes far fewer attempts than the old
    20 Hz busy-loop would."""
    addrs = local_addresses(["a", "b"])       # nobody listens on b
    ca = SocketCommunicator("a", addrs, comm_cfg=CommCfg(timeout=1.2))
    try:
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="could not connect "
                                                  "to 'b'") as ei:
            ca.send("b", "t", {"x": np.zeros(1)})
        dt = time.monotonic() - t0
        assert 1.0 <= dt < 6.0, dt
        assert "attempts" in str(ei.value)
        # exponential backoff: 1.2s of retries fits in ~7 attempts
        # (0.05 + 0.1 + 0.2 + ...), not the ~24 a fixed 50 ms loop makes
        import re
        n = int(re.search(r"\((\d+) attempts\)", str(ei.value)).group(1))
        assert n <= 12, n
    finally:
        ca.close()


# ---------------------------------------------------------------------------
# link loss / blackhole
# ---------------------------------------------------------------------------


def test_link_full_loss_blackholes_and_recovers():
    """loss=1.0 is the partition scenario: every message vanishes (the
    sender believes its writes succeeded), the drop count is recorded,
    and clearing the link restores delivery."""
    ca, cb = _sock_pair(link=LinkSpec(loss=1.0))
    try:
        futs = [ca.isend("b", f"t{i}", {"x": np.zeros(2)})
                for i in range(3)]
        for f in futs:
            f.result(5.0)                     # resolve OK: blackholed
        ca.flush_sends(5.0)
        assert ca.stats.link_dropped == 3
        with pytest.raises(TimeoutError):
            cb.recv("a", "t0", timeout=0.3)
        ca.set_link(None)                     # partition heals
        ca.send("b", "after", {"x": np.ones(1)})
        assert cb.recv("a", "after", timeout=10.0).tensor("x")[0] == 1.0
    finally:
        ca.close(); cb.close()


def test_link_partial_loss_preserves_fifo():
    """Lossy links drop messages but never reorder the survivors."""
    ca, cb = _sock_pair(link=LinkSpec(loss=0.5))
    try:
        n = 40
        for i in range(n):
            ca.isend("b", "s", {"x": np.array([float(i)])})
        ca.flush_sends(10.0)
        dropped = ca.stats.link_dropped
        assert 0 < dropped < n                # deterministic seeded rng
        got = []
        while True:
            try:
                got.append(cb.recv("a", "s",
                                   timeout=0.5).tensor("x")[0])
            except TimeoutError:
                break
        assert len(got) == n - dropped
        assert got == sorted(got)             # FIFO among survivors
    finally:
        ca.close(); cb.close()


# ---------------------------------------------------------------------------
# strict-EOF drop attribution
# ---------------------------------------------------------------------------


def test_strict_eof_attributes_clean_close():
    """With strict_eof (elastic clusters), even a tidy close from an
    identified peer — what a SIGKILL'd process's kernel produces — is a
    drop: waiters fail fast instead of hanging out the timeout."""
    addrs = local_addresses(["a", "b"])
    cb = SocketCommunicator("b", addrs,
                            comm_cfg=CommCfg(strict_eof=True,
                                             timeout=30.0))
    ca = SocketCommunicator("a", addrs)
    try:
        ca.send("b", "hello", {"x": np.zeros(1)})     # identifies a
        cb.recv("a", "hello")
        ca.close()                                    # clean EOF
        t0 = time.monotonic()
        with pytest.raises(ConnectionError, match="dropped"):
            cb.recv("a", "never", timeout=30.0)
        assert time.monotonic() - t0 < 5.0
        assert "a" in cb.suspects()
    finally:
        cb.close()


def test_default_eof_stays_clean_close_silent():
    """Without strict_eof (the default), PR 5 semantics are untouched:
    a clean close between frames is a normal boundary, not a drop."""
    ca, cb = _sock_pair()
    try:
        ca.send("b", "hello", {"x": np.zeros(1)})
        cb.recv("a", "hello")
        ca.close()
        time.sleep(0.3)                       # let the EOF land
        with pytest.raises(TimeoutError):
            cb.recv("a", "never", timeout=0.5)
        assert "a" not in cb.suspects()
    finally:
        cb.close()


# ---------------------------------------------------------------------------
# typed-channel elastic machinery (down peers, stale gather, reset)
# ---------------------------------------------------------------------------


def _chan_pair():
    bus = ThreadBus(["master", "member0"])
    return (TypedChannel(bus.communicator("master")),
            TypedChannel(bus.communicator("member0")))


def test_gather_straggler_substitutes_stale_then_drains():
    cm, c0 = _chan_pair()
    c0.send("master", "el/z", {"z": np.array([10.0])})
    cm.round_deadline = 0.3
    # round 0: on time
    [m] = cm.gather(["member0"], "el/z")
    assert m.tensor("z")[0] == 10.0
    # round 1: member0 straggles past the deadline — its round-0
    # contribution is substituted and the straggle is recorded
    [m] = cm.gather(["member0"], "el/z")
    assert m.tensor("z")[0] == 10.0
    assert cm.stats.straggles == {"member0": 1}
    # the late round-1 message and round 2 both arrive: the parked
    # future drains round 1 into the stale cache, round 2 is delivered
    c0.send("master", "el/z", {"z": np.array([11.0])})
    c0.send("master", "el/z", {"z": np.array([12.0])})
    [m] = cm.gather(["member0"], "el/z")
    assert m.tensor("z")[0] == 12.0
    assert not cm._stale_futs                 # nothing left parked


def test_gather_without_stale_cache_raises():
    cm, _ = _chan_pair()
    cm.down.add("member0")
    with pytest.raises(ConnectionError, match="no stale"):
        cm.gather(["member0"], "el/z")


def test_channel_send_to_down_peer_is_dropped_without_seq_advance():
    cm, c0 = _chan_pair()
    cm.down.add("member0")
    cm.send("member0", "el/z", {"z": np.zeros(1)})
    assert cm.isend("member0", "el/z", {"z": np.zeros(1)}) is None
    assert not cm._send_seq                   # no counter advanced
    cm.down.clear()
    cm.send("member0", "el/z", {"z": np.ones(1)})
    msg = c0.recv("master", "el/z")
    assert msg.tag == "el/z/0"                # stream starts at 0


def test_channel_reset_peer_zeroes_counters_and_residuals():
    from repro.core.compression import ErrorFeedback
    cm, c0 = _chan_pair()
    for v in (1.0, 2.0):
        cm.send("member0", "el/z", {"z": np.array([v])})
        c0.recv("master", "el/z")
    cm.error_feedback = ErrorFeedback()
    cm.error_feedback.residuals = {
        "member0/splitnn/u/u": np.ones(2), "other/x/y": np.ones(2)}
    cm._last_msg[("member0", "el/z")] = object()
    cm.reset_peer("member0")
    assert not any(k[0] == "member0" for k in cm._send_seq)
    assert not cm._last_msg
    assert list(cm.error_feedback.residuals) == ["other/x/y"]
    # the stream restarts from 0 for the peer's restarted process
    cm.send("member0", "el/z", {"z": np.array([3.0])})
    c0_fresh = TypedChannel(c0.comm)          # fresh counters, like a
    assert c0_fresh.recv("master", "el/z").tag == "el/z/0"   # respawn


# ---------------------------------------------------------------------------
# straggler tolerance end-to-end (depth >= 2 + round_deadline_s)
# ---------------------------------------------------------------------------


class _SleepAt(Callback):
    """Stalls one role once at a given step — a scripted straggler."""

    def __init__(self, role: str, step: int, sleep_s: float):
        self.role = role
        self.step = step
        self.sleep_s = sleep_s

    def on_batch_end(self, driver, step, epoch, loss):
        if driver.role == self.role and step == self.step:
            time.sleep(self.sleep_s)


def test_round_deadline_tolerates_straggler():
    """With pipeline_depth=2 and a round deadline, a member stalled for
    many times the deadline does NOT stall the master: its stale
    contribution is substituted, the straggle is counted, and training
    still runs every announced round and converges."""
    cfg, master, members = _linreg_case()
    import dataclasses
    cfg = dataclasses.replace(cfg, round_deadline_s=0.3)
    res = run_vfl(cfg, master, members, mode="thread", pipeline_depth=2,
                  callbacks=[_SleepAt("member1", 4, 1.5)])
    h = [r["loss"] for r in res["master"]["history"]]
    assert len(h) == 12                       # every round computed
    assert h[-1] < h[0]
    straggles = res["master"]["comm"]["straggles"]
    assert straggles.get("member1", 0) >= 1


def test_round_deadline_off_by_default():
    """round_deadline_s=0 (default) must leave the synchronous gather
    untouched — bit-identical linreg traces are asserted elsewhere;
    here: no straggle machinery ever arms."""
    cfg, master, members = _linreg_case()
    res = run_vfl(cfg, master, members, mode="thread", pipeline_depth=2)
    assert res["master"]["comm"]["straggles"] == {}


# ---------------------------------------------------------------------------
# crash -> restart -> rejoin, in-process over real sockets
# ---------------------------------------------------------------------------


class _CrashAt(Callback):
    def __init__(self, role: str, step: int):
        self.role = role
        self.step = step

    def on_batch_end(self, driver, step, epoch, loss):
        if driver.role == self.role and step == self.step:
            raise RuntimeError(f"chaos: injected crash at step {step}")


def test_member_crash_restart_rejoin_completes_fit(tmp_path):
    """The full elastic story without the launcher: member0 crashes
    mid-fit (its sockets close), the master pauses announcements,
    substitutes stale contributions for the in-flight window, resets
    member0's comm/channel state, and waits; a fresh member0 process
    (here: thread + fresh communicator) restores from the checkpoint,
    rejoins via the ctrl/rejoin handshake, and fit completes with every
    round computed. Survivor member1 never notices."""
    cfg, master_data, member_datas = _linreg_case(epochs=3)
    world = ["master", "member0", "member1"]
    addrs = local_addresses(world)
    ccfg = CommCfg(strict_eof=True, timeout=30.0)
    comms = {w: SocketCommunicator(w, addrs, comm_cfg=ccfg)
             for w in world}
    ckpt = tmp_path / "ckpt"
    out = {}

    def run_survivor():
        out["member1"] = PartyMember(comms["member1"], cfg).serve(
            member_datas[1])

    def run_victim():
        try:
            PartyMember(comms["member0"], cfg,
                        callbacks=[Checkpointer(ckpt, save_on_start=True),
                                   _CrashAt("member0", 5)]
                        ).serve(member_datas[0])
        except RuntimeError:
            pass
        finally:
            comms["member0"].close()          # the dead process's FIN

    t_survivor = threading.Thread(target=run_survivor, daemon=True)
    t_victim = threading.Thread(target=run_victim, daemon=True)
    t_survivor.start()
    t_victim.start()

    def run_rejoin():
        t_victim.join(60)
        c = SocketCommunicator("member0", addrs, comm_cfg=ccfg)
        out["member0"] = PartyMember(c, cfg, resume_dir=str(ckpt)).serve(
            member_datas[0], rejoin=True)

    t_rejoin = threading.Thread(target=run_rejoin, daemon=True)
    t_rejoin.start()

    pm = PartyMaster(comms["master"], cfg,
                     elastic=ElasticCfg(roles=frozenset({"member0"}),
                                        wait_s=60.0))
    t0 = time.monotonic()
    fit = pm.fit(master_data)
    recovery_s = time.monotonic() - t0
    res = pm.shutdown()
    for t in (t_survivor, t_rejoin):
        t.join(60)

    assert [r["role"] for r in fit["recoveries"]] == ["member0"]
    assert fit["recoveries"][0]["wait_s"] < 15.0
    assert len(fit["history"]) == 12          # every announced round ran
    assert fit["history"][-1]["loss"] < fit["history"][0]["loss"]
    assert "w" in out["member0"] and "w" in out["member1"]
    assert res["n_common"] == 192
    assert recovery_s < 60.0


def test_master_without_elastic_cfg_still_fails_fast(tmp_path):
    """restart='never' semantics at the driver level: no ElasticCfg
    means a dead member is a hard ConnectionError, exactly PR 5."""
    cfg, master_data, member_datas = _linreg_case(epochs=3)
    world = ["master", "member0", "member1"]
    addrs = local_addresses(world)
    ccfg = CommCfg(strict_eof=True, timeout=20.0)
    comms = {w: SocketCommunicator(w, addrs, comm_cfg=ccfg)
             for w in world}

    def run_survivor():
        try:
            PartyMember(comms["member1"], cfg).serve(member_datas[1])
        except (ConnectionError, TimeoutError, RuntimeError):
            pass

    def run_victim():
        try:
            PartyMember(comms["member0"], cfg,
                        callbacks=[_CrashAt("member0", 3)]).serve(
                member_datas[0])
        except RuntimeError:
            pass
        finally:
            comms["member0"].close()

    ts = [threading.Thread(target=run_survivor, daemon=True),
          threading.Thread(target=run_victim, daemon=True)]
    for t in ts:
        t.start()
    pm = PartyMaster(comms["master"], cfg)    # no elastic
    t0 = time.monotonic()
    with pytest.raises(ConnectionError):
        pm.fit(master_data)
    assert time.monotonic() - t0 < 30.0
    comms["master"].close()
    comms["member1"].close()
