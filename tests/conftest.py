import os

# smoke tests run on the single real CPU device; ONLY dryrun.py sets the
# 512-device flag (see system design). Keep math on fp32 for tests.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
