"""Fallback property-testing shim: re-exports `hypothesis` when it is
installed; otherwise provides a minimal deterministic replacement so the
property tests still execute (with seeded pseudo-random examples rather
than shrinking search) instead of erroring the whole collection.

Only the small surface our tests use is implemented: ``given``,
``settings(max_examples=, deadline=)`` and the ``integers`` / ``floats``
/ ``booleans`` / ``sampled_from`` strategies.
"""
try:
    from hypothesis import given, settings, strategies          # noqa: F401
except ImportError:
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:                                           # noqa: N801
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(
                lambda rng: items[int(rng.integers(0, len(items)))])

    def given(*strats):
        def deco(fn):
            def wrapper():
                seed = int.from_bytes(fn.__name__.encode(), "little")
                rng = _np.random.default_rng(seed % (2 ** 32))
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    fn(*[s.draw(rng) for s in strats])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._max_examples = 10
            return wrapper
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn
        return deco
