"""Record reference training traces (loss history + final weights) for
the three built-in protocols.

The fixture pins the numerical behaviour of the protocol layer: the
lifecycle API (core/protocols/driver.py) must reproduce these traces
bit-for-bit (f64 paths) / to float32 tolerance (split-NN), which is how
we know the refactor away from monolithic role functions changed zero
arithmetic. The file checked in at tests/fixtures/seed_traces.json was
generated against the pre-lifecycle seed code (commit ae0d7bc).

Configs use n divisible by batch_size so the traces are invariant to the
drop_last default.

  PYTHONPATH=src python tests/fixtures/record_seed_traces.py
"""
import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

from repro.core.party import run_vfl                      # noqa: E402
from repro.core.protocols.base import VFLConfig           # noqa: E402
from repro.data.vertical import vertical_partition        # noqa: E402

OUT = pathlib.Path(__file__).resolve().parent / "seed_traces.json"


def dataset(n=192, d=12, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, y


def main():
    traces = {}

    ids, x, y = dataset()
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    res = run_vfl(cfg, master, members, mode="thread")
    traces["linreg"] = {
        "losses": [h["loss"] for h in res["master"]["history"]],
        "w_master": res["master"]["w_master"].tolist(),
        "w_members": [res[f"member{j}"]["w"].tolist() for j in range(2)],
    }

    ids, x, y = dataset(n=64, d=8, items=1)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[3], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256)
    res = run_vfl(cfg, master, members, mode="thread")
    traces["logreg_he"] = {
        "losses": [h["loss"] for h in res["master"]["history"]],
        "w_master": res["master"]["w_master"].tolist(),
        "w_members": [res["member0"]["w"].tolist()],
    }

    ids, x, y = dataset(n=128, d=12, items=3)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    res = run_vfl(cfg, master, members, mode="thread")
    traces["split_nn"] = {
        "losses": [h["loss"] for h in res["master"]["history"]],
    }

    OUT.write_text(json.dumps(traces, indent=1))
    print(f"wrote {OUT}")
    for k, v in traces.items():
        print(f"  {k}: {len(v['losses'])} steps, "
              f"loss {v['losses'][0]:.6f} -> {v['losses'][-1]:.6f}")


if __name__ == "__main__":
    main()
