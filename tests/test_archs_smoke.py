"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of the same family and runs one forward /
train step on CPU asserting output shapes + no NaNs; decode-capable
archs also run a serve step and a prefill/decode consistency check.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models import params as PRM, transformer as T
from repro.train import optimizer as O

ARCHS = list_archs()


def _reduced(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # generous capacity so smoke batches never drop tokens
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    return cfg


def _batch(cfg, b=2, s=32):
    batch = {"tokens": np.full((b, s), 3, np.int32),
             "labels": np.full((b, s), 5, np.int32)}
    rng = np.random.default_rng(0)
    batch["tokens"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    batch["labels"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        batch["patches"] = rng.normal(
            size=(b, cfg.frontend.num_tokens, cfg.d_model)
        ).astype(np.float32) * 0.02
    if cfg.encoder is not None:
        batch["frames"] = rng.normal(
            size=(b, cfg.encoder.n_frames, cfg.d_model)
        ).astype(np.float32) * 0.02
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = _reduced(arch)
            spec = T.model_spec(cfg)
            cache[arch] = (cfg, PRM.init_tree(spec, jax.random.key(0),
                                              jnp.float32))
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, params_cache):
    cfg, params = params_cache(arch)
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: T.forward(cfg, p, b, jnp.float32))(params, batch)
    b, s = batch["tokens"].shape
    total = s + (cfg.frontend.num_tokens
                 if cfg.frontend and cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (b, total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_shape(arch, params_cache):
    cfg, params = params_cache(arch)
    opt = O.make_optimizer("sgdm")
    state = opt.init(params)
    batch = _batch(cfg)

    def step(p, s):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: T.loss_fn(cfg, q, batch, jnp.float32),
            has_aux=True)(p)
        p2, s2 = opt.update(grads, s, p, jnp.float32(0.1))
        return p2, s2, loss

    step = jax.jit(step)
    p1, s1, l0 = step(params, state)
    p2, _, l1 = step(p1, s1)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0) + 0.5  # one step on the same batch


DECODE_OK = [a for a in ARCHS]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, params_cache):
    """Teacher-forced decode logits must equal the parallel forward."""
    cfg, params = params_cache(arch)
    b, s = 1, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    memory = None
    if cfg.encoder is not None:
        frames = jnp.asarray(
            rng.normal(size=(b, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32) * 0.02
        batch["frames"] = frames
        memory = T.encode(cfg, params, frames)
    if cfg.frontend is not None and cfg.frontend.kind == "vision":
        pytest.skip("vlm decode exercises text path only (covered below)")
    ref_logits, _ = T.forward(cfg, params, batch, jnp.float32)

    cache = T.init_cache(cfg, b, s, jnp.float32)
    step = jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i,
                                                    memory, jnp.float32))
    for i in range(s):
        logits, cache = step(params, toks[:, i:i + 1], cache, i)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref_logits[:, i]),
            rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_beyond_window(params_cache):
    """h2o-danube ring cache: decoding past the window stays finite and
    the cache never grows beyond `window` slots."""
    cfg, params = params_cache("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, window=8)
    spec = T.model_spec(cfg)
    params = PRM.init_tree(spec, jax.random.key(0), jnp.float32)
    cache = T.init_cache(cfg, 1, 64, jnp.float32)
    k_shape = cache["blocks"]["pos0"]["k"].shape
    assert k_shape[2] == 8  # (layers, batch, slots, kv, hd) -> slots dim
    step = jax.jit(lambda p, t, c, i: T.decode_step(cfg, p, t, c, i, None,
                                                    jnp.float32))
    tok = jnp.ones((1, 1), jnp.int32)
    for i in range(20):
        logits, cache = step(params, tok, cache, i)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_applicability_matrix(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        ok, why = shape_applicable(cfg, shape)
        if name == "long_500k" and not cfg.subquadratic:
            assert not ok and "sub-quadratic" in why
        else:
            assert ok


def test_param_counts_match_nominal():
    expect = {"glm4-9b": 9.4, "qwen3-14b": 14.8, "jamba-1.5-large-398b": 398.5,
              "deepseek-v2-lite-16b": 15.7, "internvl2-76b": 70.5}
    for arch, nominal in expect.items():
        got = get_config(arch).param_count() / 1e9
        assert abs(got - nominal) / nominal < 0.02, (arch, got)
