"""VFL protocol correctness: VFL == centralized equivalence, execution-
mode equivalence (the paper's seamless-switching claim), arbitered HE
flow, and the mesh-mode step."""
import numpy as np
import pytest

from repro.core.party import run_vfl
from repro.core.protocols.base import (MasterData, MemberData, VFLConfig,
                                       batches)
from repro.data.vertical import vertical_partition


def _dataset(n=192, d=12, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    # zero-padded so sorted(id) order == row order (the matching phase
    # sorts the common ids; centralized references rely on this)
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, y


def _centralized_linreg(x, y, cfg):
    """Plain GD with the same batching — must match VFL exactly."""
    n = x.shape[0]
    w = np.zeros((x.shape[1], y.shape[1]))
    losses = []
    for epoch in range(cfg.epochs):
        for rows in batches(n, cfg, epoch):
            z = x[rows] @ w
            r = (z - y[rows]) / len(rows)
            losses.append(float(0.5 * np.mean((z - y[rows]) ** 2)))
            w -= cfg.lr * (x[rows].T @ r)
    return w, losses


def test_vfl_linreg_equals_centralized():
    ids, x, y = _dataset()
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    res = run_vfl(cfg, master, members, mode="thread")
    # centralized on the SAME column split order [master | m0 | m1]
    w_c, losses_c = _centralized_linreg(x, y, cfg)
    vfl_losses = [h["loss"] for h in res["master"]["history"]]
    np.testing.assert_allclose(vfl_losses, losses_c, rtol=1e-10)
    # weight slices match
    np.testing.assert_allclose(res["master"]["w_master"], w_c[:5],
                               atol=1e-10)


@pytest.mark.parametrize("mode", ["thread", "socket"])
def test_mode_equivalence(mode):
    """Identical training traces across execution modes (paper claim)."""
    ids, x, y = _dataset(n=128)
    master, members = vertical_partition(ids, x, y, widths=[4], overlap=0.9,
                                         seed=2)
    cfg = VFLConfig(protocol="linreg", epochs=2, batch_size=32, lr=0.1,
                    seed=0, use_psi=False)
    ref = run_vfl(cfg, master, members, mode="thread")
    got = run_vfl(cfg, master, members, mode=mode)
    ref_l = [h["loss"] for h in ref["master"]["history"]]
    got_l = [h["loss"] for h in got["master"]["history"]]
    np.testing.assert_allclose(got_l, ref_l, rtol=0, atol=0)
    assert (got["master"]["comm"]["sent_bytes"]
            == ref["master"]["comm"]["sent_bytes"])


def test_splitnn_trains_and_modes_agree():
    ids, x, y = _dataset(n=128, items=3)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    res_t = run_vfl(cfg, master, members, mode="thread")
    res_s = run_vfl(cfg, master, members, mode="socket")
    ht = [h["loss"] for h in res_t["master"]["history"]]
    hs = [h["loss"] for h in res_s["master"]["history"]]
    np.testing.assert_allclose(ht, hs, rtol=1e-6)
    assert ht[-1] < ht[0]


def test_logreg_he_matches_plaintext_gradients():
    """The arbitered-HE protocol must train exactly like plaintext
    logistic regression (HE is exact up to fixed-point quantization)."""
    ids, x, y = _dataset(n=64, d=8, items=1)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[3], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=1, batch_size=32, lr=0.5,
                    seed=0, use_psi=False, he_bits=256)
    res = run_vfl(cfg, master, members, mode="thread")

    # plaintext reference with identical batching and column order
    w = np.zeros((x.shape[1], 1))
    losses = []
    for epoch in range(cfg.epochs):
        for rows in batches(64, cfg, epoch):
            z = x[rows] @ w
            p = 1 / (1 + np.exp(-z))
            eps = 1e-9
            losses.append(float(-np.mean(
                yb[rows] * np.log(p + eps)
                + (1 - yb[rows]) * np.log(1 - p + eps))))
            r = (p - yb[rows]) / len(rows)
            w -= cfg.lr * (x[rows].T @ r)
    vfl_losses = [h["loss"] for h in res["master"]["history"]]
    np.testing.assert_allclose(vfl_losses, losses, atol=1e-6)
    # member weight slice agrees with plaintext (fixed-point tolerance)
    np.testing.assert_allclose(res["member0"]["w"], w[5:], atol=1e-5)


def test_psi_restricts_to_overlap():
    ids, x, y = _dataset(n=100)
    master, members = vertical_partition(ids, x, y, widths=[4], overlap=0.7,
                                         seed=5)
    cfg = VFLConfig(protocol="linreg", epochs=1, batch_size=16, lr=0.1,
                    use_psi=True)
    res = run_vfl(cfg, master, members, mode="thread")
    assert res["master"]["n_common"] == 70


def test_comm_stats_are_logged():
    ids, x, y = _dataset(n=64)
    master, members = vertical_partition(ids, x, y, widths=[4], seed=6)
    cfg = VFLConfig(protocol="linreg", epochs=1, batch_size=32, lr=0.1,
                    use_psi=False)
    res = run_vfl(cfg, master, members, mode="thread")
    stats = res["master"]["comm"]
    assert stats["sent_messages"] > 0
    assert stats["sent_bytes"] > 0
    assert any(k.startswith("linreg/resid") for k in stats["per_tag_bytes"])


def test_secure_agg_masks_cancel_and_hide():
    """Bonawitz-style masked aggregation over the communicator: the
    training trace equals plain split-NN (masks cancel in the sum) while
    each member's transmitted tensor is masked (master never sees raw
    embeddings)."""
    import dataclasses

    from repro.core.secure_agg_protocol import PairwiseMasker
    ids, x, y = _dataset(n=128, items=2)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[4, 4], seed=7)
    cfg = VFLConfig(protocol="split_nn", epochs=2, batch_size=32, lr=0.1,
                    use_psi=False, embedding_dim=8, hidden=(16,))
    plain = run_vfl(cfg, master, members, mode="thread")
    sec = run_vfl(dataclasses.replace(cfg, secure_agg=True), master,
                  members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in sec["master"]["history"]],
        [h["loss"] for h in plain["master"]["history"]],
        rtol=1e-4, atol=1e-4)

    # the mask itself is non-trivial and pairwise-canceling
    from repro.comm.local import ThreadBus
    import threading
    bus = ThreadBus(["member0", "member1"])
    out = {}

    def mk(me):
        out[me] = PairwiseMasker(bus.communicator(me), me,
                                 ["member0", "member1"])
    ts = [threading.Thread(target=mk, args=(m,))
          for m in ("member0", "member1")]
    [t.start() for t in ts]
    [t.join() for t in ts]
    m0 = out["member0"].mask(3, (5, 4))
    m1 = out["member1"].mask(3, (5, 4))
    assert np.abs(m0).max() > 0.1              # masks are substantial
    np.testing.assert_allclose(m0 + m1, 0, atol=1e-6)   # and cancel


def test_secure_agg_as_first_class_protocol():
    """``protocol="secure_agg"`` (no extra flag) is split-NN with
    masking always on: the convergence trace equals plain split-NN
    (masks cancel in the master's sum), and the compress combination —
    quantizing each mask independently would break cancellation — is
    rejected at setup."""
    import dataclasses
    ids, x, y = _dataset(n=128, items=2)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[4, 4],
                                         seed=7)
    cfg = VFLConfig(protocol="secure_agg", epochs=2, batch_size=32,
                    lr=0.1, use_psi=False, embedding_dim=8,
                    hidden=(16,))
    sec = run_vfl(cfg, master, members, mode="thread")
    plain = run_vfl(dataclasses.replace(cfg, protocol="split_nn"),
                    master, members, mode="thread")
    np.testing.assert_allclose(
        [h["loss"] for h in sec["master"]["history"]],
        [h["loss"] for h in plain["master"]["history"]],
        rtol=1e-4, atol=1e-4)
    assert sec["master"]["history"][-1]["loss"] \
        < sec["master"]["history"][0]["loss"]

    with pytest.raises((ValueError, RuntimeError)) as ei:
        run_vfl(dataclasses.replace(cfg, compress=True, epochs=1),
                master, members, mode="thread")
    assert "compress" in str(ei.value) or "compress" in \
        str(ei.value.__cause__)
