"""Adversarial harness unit tests: the label-inference attacks work
where they must (undefended exchanges), the measured defenses actually
defend, and the privacy CI gate holds on the committed matrix.

The full-size measurement lives in ``repro.attacks.runner`` (CI's
privacy job); these tests run shrunken cases so tier-1 stays fast.
"""
import dataclasses
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro.attacks import label_inference as li
from repro.attacks.harness import AttackHarness
from repro.attacks.runner import logreg_case
from repro.core.protocols import base
from repro.core.protocols.driver import OP_END, OP_RUN
from repro.train.evals import auc

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# offline attack math (no VFL run)
# ---------------------------------------------------------------------------


def test_run_rounds_rederives_batches():
    """ctrl/step (op, epoch, lo, hi) records -> exact batch rows via
    the shared deterministic batch_order; END steps are skipped."""
    cfg = base.VFLConfig(seed=11)
    n = 40

    def rec(op, epoch, lo, hi):
        return {"dir": "recv", "peer": "master", "name": "ctrl/step",
                "payload": {"op": np.array([op]),
                            "epoch": np.array([epoch]),
                            "lo": np.array([lo]), "hi": np.array([hi])}}

    cap = {"names": ["ctrl/step"],
           "records": [rec(OP_RUN, 0, 0, 16), rec(OP_RUN, 0, 16, 32),
                       rec(OP_RUN, 1, 0, 16), rec(OP_END, 0, 0, 0)]}
    rounds = li.run_rounds(cap, cfg, n, peer="master",
                           direction="recv")
    assert len(rounds) == 3
    np.testing.assert_array_equal(rounds[0],
                                  base.batch_order(n, cfg, 0)[0:16])
    np.testing.assert_array_equal(rounds[2],
                                  base.batch_order(n, cfg, 1)[0:16])


def test_gradient_direction_attack_exact_solve():
    """batch <= member width: X_b^T r = g is determined, the residual
    sign (negative iff y=1) is recovered outright -> AUC 1.0."""
    rng = np.random.default_rng(0)
    n, d = 48, 8
    x = rng.normal(size=(n, d))
    y = rng.integers(0, 2, n).astype(np.float64)
    p = 1.0 / (1.0 + np.exp(-rng.normal(size=n)))
    rounds, grads = [], []
    for lo in range(0, n, 6):
        rows = np.arange(lo, lo + 6)
        r = (p[rows] - y[rows]) / len(rows)
        rounds.append(rows)
        grads.append(x[rows].T @ r)
    scores = li.gradient_direction_attack(x, rounds, grads)
    assert auc(scores, y) == 1.0


def test_embedding_attacks_read_separable_embeddings():
    """Synthetic linearly-separable 'activations': both the
    unsupervised cluster attack and the aux-label probe recover the
    labels from per-round mean embeddings."""
    rng = np.random.default_rng(1)
    n, d = 120, 8
    y = rng.integers(0, 2, n).astype(np.float64)
    centers = np.where(y[:, None] > 0, 1.0, -1.0)
    u_true = centers * rng.uniform(0.5, 1.5, (n, d))
    rounds = [rng.permutation(n)[:30] for _ in range(12)]
    embeds = [u_true[r] + 0.3 * rng.normal(size=(len(r), d))
              for r in rounds]
    u_bar, seen = li.mean_embeddings(rounds, embeds, n, late_frac=0.5)
    a = auc(li.cluster_attack(u_bar[seen]), y[seen])
    assert max(a, 1.0 - a) > 0.9
    aux = np.zeros(n, bool)
    aux[rng.permutation(n)[:20]] = True
    scores = li.probe_attack(u_bar[seen], y[seen], aux[seen])
    hold = ~aux[seen]
    assert auc(scores[hold], y[seen][hold]) > 0.9


def test_defense_noise_deterministic_and_scaled():
    """defense_noise is a pure function of (seed, step, key) with rms
    scaling — reruns reproduce it exactly; distinct steps/keys do not."""
    cfg = base.VFLConfig(noise_sigma=1.5, seed=3)
    g = np.linspace(-2.0, 2.0, 64)
    n1 = base.defense_noise(cfg, g, 7, "arbiter/member0")
    n2 = base.defense_noise(cfg, g, 7, "arbiter/member0")
    np.testing.assert_allclose(n1, n2, rtol=0, atol=0)
    assert not np.array_equal(n1,
                              base.defense_noise(cfg, g, 8,
                                                 "arbiter/member0"))
    assert not np.array_equal(n1,
                              base.defense_noise(cfg, g, 7,
                                                 "arbiter/member1"))
    rms = float(np.sqrt(np.mean(g ** 2)))
    assert 0.5 * 1.5 * rms < n1.std() < 2.0 * 1.5 * rms


# ---------------------------------------------------------------------------
# harness end-to-end (shrunken logreg case)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def logreg_reports():
    cfg, master, members = logreg_case(n=96)
    plain = AttackHarness(cfg, master, members,
                          mode="thread").run().grad_attack()
    noised = AttackHarness(
        dataclasses.replace(cfg, noise_sigma=2.0), master, members,
        mode="thread").run().grad_attack()
    return plain, noised


def test_undefended_logreg_leaks(logreg_reports):
    plain, _ = logreg_reports
    assert plain["attack"] == "grad_direction"
    assert plain["adversary"] == "member0"
    assert plain["rounds"] > 0
    # exact solve regime: labels leak outright
    assert plain["leakage_auc"] >= 0.75


def test_noise_defense_breaks_the_attack(logreg_reports):
    plain, noised = logreg_reports
    assert noised["leakage_auc"] < 0.7
    assert noised["leakage_auc"] < plain["leakage_auc"] - 0.2
    # gradient-level noise is averaged out by SGD: utility survives
    assert abs(noised["utility_auc"] - plain["utility_auc"]) < 0.1


# ---------------------------------------------------------------------------
# CI gate on the committed matrix
# ---------------------------------------------------------------------------


def _load_check_regression():
    path = REPO / "benchmarks" / "check_regression.py"
    spec = importlib.util.spec_from_file_location("check_regression",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_committed_privacy_json_passes_the_gate():
    """The checked-in privacy.json satisfies every PRIVACY_GATES cell —
    the same check CI's privacy job runs on fresh rows."""
    mod = _load_check_regression()
    out = REPO / "benchmarks" / "results" / "privacy.json"
    assert out.exists(), "benchmarks/results/privacy.json not committed"
    assert mod.check_privacy(str(out)) == []


def test_privacy_gate_flags_violations(tmp_path):
    """A broken attack (undefended leakage at chance) and a broken
    defense (leakage above threshold) must both fail the gate."""
    mod = _load_check_regression()
    rows = json.loads(
        (REPO / "benchmarks" / "results" / "privacy.json").read_text())
    bad = []
    for r in rows:
        r = dict(r)
        if r["defense"] == "none":
            r["leakage_auc"] = 0.5          # attack "stopped working"
        if r["defense"] == "secure_agg":
            r["leakage_auc"] = 0.9          # defense "stopped working"
        bad.append(r)
    p = tmp_path / "privacy.json"
    p.write_text(json.dumps(bad))
    failures = mod.check_privacy(str(p))
    assert any("attack must work" in f for f in failures)
    assert any("secure_agg" in f for f in failures)
    # and a missing cell is itself a failure
    p.write_text(json.dumps(bad[1:]))
    assert any("missing" in f for f in mod.check_privacy(str(p)))
