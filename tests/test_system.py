"""End-to-end behaviour tests for the paper's system: the full VFL demo
loop, trainer + serving integration, the analytic roofline model, the
HLO collective parser, and (in a subprocess, to keep this process at one
device) the mesh-mode VFL step and a reduced dry-run."""
import json
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_vfl_recsys_demo_end_to_end():
    from repro.configs.vfl_recsys import VFLRecsysConfig
    from repro.core.party import run_vfl
    from repro.core.protocols.base import MasterData, MemberData, VFLConfig
    from repro.data.synthetic import make_recsys_silos
    dcfg = VFLRecsysConfig().reduced()
    data = make_recsys_silos(dcfg, seed=0)
    master = MasterData(data.ids, data.labels.astype(np.float64),
                        data.features)
    members = [MemberData(i, x) for i, x in
               zip(data.member_ids, data.member_features)]
    # lr tuned for the reduced demo scale: at 0.05 the 12-step run never
    # escapes per-batch loss noise (seed flake); 0.3 trains monotonically
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=64, lr=0.3,
                    use_psi=True, embedding_dim=16)
    res = run_vfl(cfg, master, members, mode="thread")
    h = res["master"]["history"]
    assert h[-1]["loss"] < h[0]["loss"]
    assert res["master"]["n_common"] == int(dcfg.id_overlap * dcfg.n_users) \
        + (0 if dcfg.id_overlap < 1 else 0)


def test_trainer_and_engine_integration():
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.synthetic import make_lm_batches
    from repro.serve.engine import ServeEngine
    from repro.train.trainer import TrainJob, train
    cfg = get_config("h2o-danube-1.8b").reduced()
    job = TrainJob(cfg=cfg, steps=20, lr=3e-3, log_every=5)
    res = train(job, make_lm_batches(cfg.vocab, 4, 64, 25))
    assert res["history"][-1]["loss"] < res["history"][0]["loss"]
    eng = ServeEngine(cfg, res["params"], max_seq=32)
    out = eng.generate(np.ones((2, 4), np.int32), 6)
    assert out.shape == (2, 10)


def test_analytic_flops_sane():
    from repro.configs import SHAPES, get_config
    from repro.launch import flops as F
    cfg = get_config("glm4-9b")
    sh = SHAPES["train_4k"]
    fwd = F.step_flops(cfg, sh)
    model = F.model_flops(cfg, sh)      # 6 N D
    # forward ~= 2ND + attention; train = 3x fwd; ratio in [1.0, 1.6]
    ratio = 3 * fwd / model
    assert 0.95 < ratio < 1.7, ratio
    # decode flops per token ~ 2N + cache reads
    dec = F.step_flops(cfg, SHAPES["decode_32k"])
    assert dec / SHAPES["decode_32k"].global_batch > \
        2 * cfg.param_count() * 0.8


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import analyze_hlo
    hlo = textwrap.dedent("""\
    HloModule test

    %cond (p: (s32[], f32[4])) -> pred[] {
      %p = (s32[], f32[4]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(24)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
      %p = (s32[], f32[4]) parameter(0)
      %x = f32[4]{0} get-tuple-element(%p), index=1
      %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %ag = f32[8,16]{1,0} all-gather(%a), dimensions={0}
      %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
      ROOT %r = f32[8,16]{1,0} add(%ag, %ag)
    }
    """)
    rep = analyze_hlo(hlo)
    by = rep.by_op()
    assert by["all-gather"] == 8 * 16 * 4
    # all-reduce: 4 floats * 4B * 2 (AR convention) * 24 loop trips
    assert by["all-reduce"] == 4 * 4 * 2 * 24
    assert rep.loop_trip_counts.get("body") == 24


@pytest.mark.slow
def test_mesh_vfl_and_dryrun_subprocess():
    """Multi-device pieces run in a subprocess so this test process keeps
    the single-CPU-device view required by the other tests."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.vfl_step import make_mesh_vfl_step, init_party_params
        from repro.core.protocols.split_nn import mlp_init
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("pod", "data"))
        key = jax.random.key(0)
        bottoms = init_party_params(key, 2, 6, (8,), 4)
        top = mlp_init(jax.random.fold_in(key, 1), (4, 8, 2))
        x = jax.random.normal(jax.random.fold_in(key, 5), (2, 16, 6))
        y = (jax.random.normal(jax.random.fold_in(key, 6), (16, 2)) > 0
             ).astype(jnp.float32)
        step = make_mesh_vfl_step(mesh, 2, lr=0.1)
        with mesh:
            b, t = bottoms, top
            losses = []
            for i in range(10):
                b, t, loss = step(b, t, x, y, jax.random.fold_in(key, i))
                losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        print("MESH_VFL_OK", losses[0], losses[-1])
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root", "JAX_PLATFORMS": "cpu"},
                         capture_output=True, text=True, timeout=560)
    assert "MESH_VFL_OK" in out.stdout, out.stderr[-2000:]


def test_dryrun_records_exist_and_fit():
    """The sweep results (deliverable e) must exist, compile, and fit
    the 16 GB/chip budget."""
    d = pathlib.Path(__file__).resolve().parents[1] \
        / "benchmarks" / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not yet executed")
    recs = [json.loads(f.read_text()) for f in d.glob("*__single.json")]
    assert len(recs) >= 40
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [(r["arch"], r["shape"]) for r in bad]
    for r in recs:
        if r["status"] != "ok":
            continue
        est = r["memory"].get("per_device_gib_estimate", 0)
        assert est < 16.0, (r["arch"], r["shape"], est)
