"""Compressed VFL exchange: quantization properties (hypothesis), the
fused Pallas kernel vs oracle, error feedback, and end-to-end compressed
split-NN training with payload accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import compression
from repro.core.party import run_vfl
from repro.core.protocols.base import VFLConfig
from repro.data.vertical import vertical_partition
from repro.kernels import ops, ref


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(1, 40),
       st.floats(0.01, 100.0))
def test_quantize_roundtrip_bound(seed, rows, cols, scale):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(rows, cols)) * scale).astype(np.float32)
    q, s = compression.quantize_int8(x, axis=1)
    back = compression.dequantize_int8(q, s)
    # per-row error bounded by half an int8 step
    bound = np.abs(x).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-6
    assert (np.abs(back - x) <= bound + 1e-6).all()


def test_error_feedback_is_unbiased_over_rounds():
    """Accumulated transmitted signal converges to accumulated truth."""
    rng = np.random.default_rng(0)
    ef = compression.ErrorFeedback()
    total_true = np.zeros((8, 4), np.float32)
    total_sent = np.zeros((8, 4), np.float32)
    for _ in range(50):
        x = rng.normal(size=(8, 4)).astype(np.float32)
        q, s = ef.compress("t", x)
        total_true += x
        total_sent += compression.dequantize_int8(q, s)
    # residual is bounded by one quantization step, not growing
    resid = np.abs(total_true - total_sent)
    assert resid.max() < 0.2, resid.max()


def test_quantize_kernel_matches_ref():
    for rows, d in [(256, 64), (512, 96), (128, 128)]:
        x = jax.random.normal(jax.random.key(rows), (rows, d)) * 2.5
        q1, s1 = ops.quantize_int8(x, interpret=True)
        q2, s2 = ref.quantize_int8_ref(x)
        assert bool((q1 == q2).all())
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-6)


def test_compressed_splitnn_trains_with_smaller_payload():
    rng = np.random.default_rng(0)
    n, d = 192, 12
    x = rng.normal(size=(n, d))
    y = (x @ rng.normal(size=(d, 3)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[5], seed=1)

    base_cfg = VFLConfig(protocol="split_nn", epochs=4, batch_size=48,
                         lr=0.1, use_psi=False, embedding_dim=8,
                         hidden=(16,))
    plain = run_vfl(base_cfg, master, members, mode="thread")

    import dataclasses
    comp_cfg = dataclasses.replace(base_cfg, compress=True)
    comp = run_vfl(comp_cfg, master, members, mode="thread")

    hp = [h["loss"] for h in plain["master"]["history"]]
    hc = [h["loss"] for h in comp["master"]["history"]]
    assert hc[-1] < hc[0], "compressed run must still train"
    assert abs(hc[-1] - hp[-1]) < 0.1, (hc[-1], hp[-1])

    # payload accounting: the member's activation bytes shrink ~4x
    bp = plain["member0"]["comm"]["per_tag_bytes"]
    bc = comp["member0"]["comm"]["per_tag_bytes"]
    up = sum(v for k, v in bp.items() if k.startswith("splitnn/u/"))
    uc = sum(v for k, v in bc.items() if k.startswith("splitnn/u/"))
    assert uc < up / 2.5, (uc, up)
