"""Federated serving engine (repro.serve.federated + the driver's
serve session): served scores are bit-identical to offline predict on
the same rows, concurrent queries coalesce into shared rounds and demux
correctly, duplicate rows cross the wire once, the member embed cache
hits on hot rows and is invalidated by refit, admission control sheds
load instead of queueing unboundedly, and the TCP frontend + serve
sessions hold up over grpc + TLS and at pipeline_depth >= 2."""
import threading
import time

import numpy as np
import pytest

from repro.comm.base import CommCfg
from repro.core.party import VFLJob
from repro.core.protocols.base import VFLConfig
from repro.core.protocols.driver import EmbedCache
from repro.data.vertical import vertical_partition
from repro.serve.federated import (AdmissionError, FederatedServer,
                                   ServeCfg, ServeClient, ServeFrontend)


def _dataset(n=96, d=10, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return vertical_partition(ids, x, y, widths=[4, 3], overlap=1.0,
                              seed=1)


def _linreg_cfg(**kw):
    return VFLConfig(protocol="linreg", epochs=2, batch_size=32, lr=0.1,
                     seed=0, use_psi=False, **kw)


def _splitnn_case(**kw):
    rng = np.random.default_rng(0)
    n, d = 96, 12
    x = rng.normal(size=(n, d))
    y = (x @ rng.normal(size=(d, 2)) > 0).astype(np.float64)
    ids = [f"u{i:05d}" for i in range(n)]
    master, members = vertical_partition(ids, x, y, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=2, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,),
                    **kw)
    return cfg, master, members


# ---------------------------------------------------------------------------
# serve session == offline predict
# ---------------------------------------------------------------------------


def test_serve_query_bit_identical_to_offline_predict():
    """A serve-session round on a row batch returns exactly what
    ``predict`` returns for the same batch (same wire, same math)."""
    master, members = _dataset()
    rows = np.array([3, 17, 40, 8, 77, 21])
    with VFLJob(_linreg_cfg(), master, members) as job:
        job.fit()
        offline = job.predict(rows=rows, batch_size=len(rows))
        job.serve_open()
        served1 = job.serve_query(rows=rows)
        served2 = job.serve_query(rows=rows)
        job.serve_close()
        np.testing.assert_array_equal(served1, offline)
        np.testing.assert_array_equal(served2, offline)
        # the session is over: plain phases still work afterwards
        np.testing.assert_array_equal(
            job.predict(rows=rows, batch_size=len(rows)), offline)


def test_predict_dedupes_duplicate_rows_on_the_wire():
    """Duplicate row ids inside one batch are computed once and
    re-expanded in request order — exactly equal to querying the
    sorted unique rows and indexing back."""
    master, members = _dataset()
    dup = np.array([5, 1, 5, 5, 2, 1, 40])
    uniq, inv = np.unique(dup, return_inverse=True)
    with VFLJob(_linreg_cfg(), master, members) as job:
        job.fit()
        got = job.predict(rows=dup, batch_size=len(dup))
        ref = job.predict(rows=uniq, batch_size=len(uniq))
        np.testing.assert_array_equal(got, ref[inv])


# ---------------------------------------------------------------------------
# FederatedServer: admission -> coalesce -> demux
# ---------------------------------------------------------------------------


def test_server_coalesces_concurrent_queries_and_demuxes():
    master, members = _dataset()
    with VFLJob(_linreg_cfg(), master, members) as job:
        job.fit()
        full = job.predict()
        scfg = ServeCfg(max_batch=64, max_wait_ms=50.0)
        with FederatedServer(job, scfg) as server:
            queries = [np.arange(i * 6, i * 6 + 6) for i in range(12)]
            results = [None] * len(queries)

            def run(i):
                results[i] = server.query(queries[i])

            ts = [threading.Thread(target=run, args=(i,))
                  for i in range(len(queries))]
            for t in ts:
                t.start()
            for t in ts:
                t.join(60)
            for i, q in enumerate(queries):
                np.testing.assert_array_equal(results[i], full[q])
            stats = server.stats.as_dict()
        assert stats["requests"] == 12
        assert stats["rows_in"] == 72
        assert stats["batches"] < 12          # coalescing happened
        assert stats["avg_batch_rows"] > 6
        assert stats["queue_s"] >= 0.0
        assert stats["exchange_s"] > 0.0
        assert stats["p50_ms"] > 0.0 and stats["p99_ms"] >= stats["p50_ms"]


def test_request_trace_stamps_are_ordered():
    master, members = _dataset()
    with VFLJob(_linreg_cfg(), master, members) as job:
        job.fit()
        with FederatedServer(job, ServeCfg(max_wait_ms=0.0)) as server:
            p = server.submit(np.arange(5))
            assert p.done.wait(30)
        assert p.t_admit <= p.t_coalesce <= p.t_exchange <= p.t_done
        t = p.trace()
        assert t["queue_s"] >= 0.0 and t["exchange_s"] > 0.0
        assert t["total_s"] >= t["exchange_s"]


def test_admission_limit_sheds_load():
    server = FederatedServer(object(), ServeCfg(admission_limit=8))
    # no batcher started: the queue cannot drain, so the limit is hit
    server.submit(np.arange(5))
    with pytest.raises(AdmissionError):
        server.submit(np.arange(4))
    assert server.stats.rejected == 1
    server.submit(np.arange(3))               # exactly at the limit


def test_round_failure_propagates_to_callers():
    class Broken:
        def serve_open(self):
            pass

        def serve_query(self, rows):
            raise RuntimeError("boom")

        def serve_close(self):
            pass

    server = FederatedServer(Broken(), ServeCfg(max_wait_ms=0.0))
    server.start()
    with pytest.raises(RuntimeError, match="federated round failed"):
        server.query(np.arange(3), timeout=30)
    with pytest.raises(RuntimeError):
        server.submit(np.arange(3))
    server.stop()


# ---------------------------------------------------------------------------
# member-side embed cache
# ---------------------------------------------------------------------------


def test_embed_cache_lru_and_invalidate():
    cache = EmbedCache(capacity=3)
    rows = np.array([1, 2, 3])
    found, missing = cache.lookup(rows)
    assert not found and list(missing) == [1, 2, 3]
    cache.insert(missing, np.arange(6.0).reshape(3, 2))
    found, missing = cache.lookup(np.array([2, 3, 4]))
    assert set(found) == {2, 3} and list(missing) == [4]
    cache.insert(missing, np.zeros((1, 2)))   # evicts LRU row 1
    found, missing = cache.lookup(np.array([1]))
    assert not found and list(missing) == [1]
    assert cache.evictions == 1
    cache.invalidate()
    found, missing = cache.lookup(np.array([2]))
    assert not found and cache.invalidations == 1
    d = cache.as_dict()
    assert d["capacity"] == 3 and d["hits"] == 2


def test_serve_cache_hits_and_scores_unchanged():
    cfg, master, members = _splitnn_case(serve_cache_rows=32)
    rows = np.arange(16)
    with VFLJob(cfg, master, members) as job:
        job.fit()
        job.serve_open()
        first = job.serve_query(rows=rows)
        second = job.serve_query(rows=rows)    # all rows hot
        job.serve_close()
        np.testing.assert_array_equal(first, second)
        res = job.shutdown()
    cache = res["member0"]["embed_cache"]
    assert cache["hits"] >= len(rows)          # second pass was cached
    assert cache["rows"] == len(rows)


def test_refit_invalidates_member_cache():
    """fit -> serve -> fit -> serve must match the same sequence with
    the cache off: stale embeddings surviving the refit would poison
    the second session's scores."""
    rows = np.arange(12)

    def run(cache_rows):
        cfg, master, members = _splitnn_case(
            serve_cache_rows=cache_rows)
        with VFLJob(cfg, master, members) as job:
            job.fit()
            job.serve_open()
            job.serve_query(rows=rows)         # populate the cache
            job.serve_close()
            job.fit()                          # params change
            job.serve_open()
            scores = job.serve_query(rows=rows)
            job.serve_close()
            res = job.shutdown()
        return scores, res["member0"].get("embed_cache")

    cached, cstats = run(cache_rows=32)
    plain, _ = run(cache_rows=0)
    np.testing.assert_array_equal(cached, plain)
    assert cstats["invalidations"] >= 1


# ---------------------------------------------------------------------------
# TCP frontend
# ---------------------------------------------------------------------------


def test_frontend_roundtrip_and_stats():
    master, members = _dataset()
    with VFLJob(_linreg_cfg(), master, members) as job:
        job.fit()
        ref = job.predict()
        with FederatedServer(job, ServeCfg(max_wait_ms=1.0)) as server:
            fe = ServeFrontend(server, host="127.0.0.1", port=0)
            try:
                with ServeClient("127.0.0.1", fe.port) as cli:
                    rows = np.array([4, 9, 4, 30])
                    np.testing.assert_array_equal(cli.query(rows),
                                                  ref[rows])
                    stats = cli.stats()
                    assert stats["requests"] == 1
                    from repro.comm import codec
                    _, meta = cli._roundtrip(
                        codec.encode({}, {"op": "nope"}))
                    assert "unknown op" in meta.get("error", "")
            finally:
                fe.close()


def test_frontend_reports_admission_rejects():
    class Slow:
        def serve_open(self):
            pass

        def serve_query(self, rows):
            time.sleep(0.3)
            return np.zeros((len(rows), 1))

        def serve_close(self):
            pass

    server = FederatedServer(Slow(), ServeCfg(admission_limit=4,
                                              max_wait_ms=0.0))
    server.start()
    fe = ServeFrontend(server, host="127.0.0.1", port=0)
    try:
        c1 = ServeClient("127.0.0.1", fe.port)
        c2 = ServeClient("127.0.0.1", fe.port)
        t = threading.Thread(
            target=lambda: c1.query(np.arange(4)))
        t.start()
        deadline = time.monotonic() + 5.0     # round in flight, queue empty
        while (server.stats.batches < 1 or server._queued_rows) \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        server.submit(np.arange(4))           # fills the queue
        with pytest.raises(AdmissionError):
            c2.query(np.arange(2))
        t.join(30)
        c1.close()
        c2.close()
    finally:
        fe.close()
        server.stop()
    assert server.stats.rejected >= 1


# ---------------------------------------------------------------------------
# serve sessions across engines: depth >= 2 and grpc + TLS
# ---------------------------------------------------------------------------


def test_serve_session_at_pipeline_depth_2():
    """A pipelined fit drains cleanly into a serve session, and predict
    at depth >= 2 answers row subsets exactly like the full pass."""
    cfg, master, members = _splitnn_case(pipeline_depth=2)
    rows = np.array([7, 3, 50, 11])
    with VFLJob(cfg, master, members) as job:
        job.fit()
        offline = job.predict(rows=rows, batch_size=len(rows))
        job.serve_open()
        served = job.serve_query(rows=rows)
        job.serve_close()
        np.testing.assert_array_equal(served, offline)
        job.fit()                              # refit after serving
        assert job.predict().shape[0] > 0


def test_serve_session_over_grpc_tls():
    from repro.launch.certs import TestCA, have_openssl
    if not have_openssl():
        pytest.skip("openssl CLI required to mint test certs")
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        ca = TestCA(td)
        for n in ("master", "member0", "member1"):
            ca.issue(n)
        comm = CommCfg(timeout=60.0, tls=ca.templated_spec())
        master, members = _dataset()
        rows = np.array([2, 44, 2, 19])
        with VFLJob(_linreg_cfg(), master, members, mode="grpc",
                    comm_cfg=comm) as job:
            job.fit()
            offline = job.predict(rows=rows, batch_size=len(rows))
            with FederatedServer(job, ServeCfg(max_wait_ms=5.0)) \
                    as server:
                outs = [None, None]

                def run(i):
                    outs[i] = server.query(rows)

                ts = [threading.Thread(target=run, args=(i,))
                      for i in range(2)]
                for t in ts:
                    t.start()
                for t in ts:
                    t.join(60)
            np.testing.assert_array_equal(outs[0], offline)
            np.testing.assert_array_equal(outs[1], offline)
