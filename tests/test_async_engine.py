"""Async exchange engine (DESIGN.md §7): transport futures and FIFO
ordering, schema-level frame coalescing + reorder, channel-declared
compression, and the pipelined driver — depth 1 must reproduce the
recorded seed traces bit-identically in every execution mode, depth
>= 2 must honor the bounded-staleness guarantee and still converge."""
import dataclasses
import json
import pathlib
import time

import numpy as np
import pytest

from repro.comm import schema
from repro.comm.local import ThreadBus
from repro.comm.schema import Field, TypedChannel
from repro.core.party import VFLJob, run_vfl
from repro.core.protocols.base import VFLConfig, register
from repro.core.protocols.driver import EarlyStopping, StopAtStep
from repro.core.protocols.linreg import LinRegProtocol
from repro.core.protocols.split_nn import SplitNNProtocol
from repro.data.vertical import vertical_partition

TRACES = json.loads(
    (pathlib.Path(__file__).parent / "fixtures" / "seed_traces.json")
    .read_text())


def _dataset(n=192, d=12, items=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    w = rng.normal(size=(d, items))
    y = x @ w * 0.4 + rng.normal(scale=0.05, size=(n, items))
    ids = [f"u{i:05d}" for i in range(n)]
    return ids, x, y


def _linreg_case():
    ids, x, y = _dataset()
    master, members = vertical_partition(ids, x, y, widths=[4, 3],
                                         overlap=1.0, seed=1)
    cfg = VFLConfig(protocol="linreg", epochs=3, batch_size=48, lr=0.1,
                    seed=0, use_psi=False)
    return cfg, master, members


def _splitnn_case():
    ids, x, y = _dataset(n=128, d=12, items=3)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[5], seed=3)
    cfg = VFLConfig(protocol="split_nn", epochs=3, batch_size=32, lr=0.1,
                    seed=0, use_psi=False, embedding_dim=8, hidden=(16,))
    return cfg, master, members


# ---------------------------------------------------------------------------
# transport layer: isend/irecv futures, FIFO, stats
# ---------------------------------------------------------------------------


def test_isend_futures_resolve_and_meter():
    bus = ThreadBus(["a", "b"])
    ca, cb = bus.communicator("a"), bus.communicator("b")
    futs = [ca.isend("b", f"t{i}", {"x": np.full(3, float(i))})
            for i in range(4)]
    for f in futs:
        f.result(5.0)
        assert f.done()
    for i in range(4):
        assert cb.recv("a", f"t{i}").tensor("x")[0] == i
    s = ca.stats.as_dict()
    assert s["async_sends"] == 4 and s["sent_messages"] == 4
    assert s["wire_s"] >= 0 and s["queued_s"] >= 0


def test_blocking_send_interleaves_fifo_with_isend():
    """A blocking send issued while async sends are queued must land
    AFTER them on the wire (one FIFO per transport)."""
    bus = ThreadBus(["a", "b"])
    ca, cb = bus.communicator("a"), bus.communicator("b")
    for i in range(20):
        ca.isend("b", "s", {"x": np.array([float(i)])})
    ca.send("b", "last", {"x": np.array([99.0])})
    seen = [cb.recv("a", "s").tensor("x")[0] for _ in range(20)]
    assert seen == list(map(float, range(20)))
    assert cb.recv("a", "last").tensor("x")[0] == 99.0


def test_irecv_is_lazy_and_peekable():
    bus = ThreadBus(["a", "b"])
    ca, cb = bus.communicator("a"), bus.communicator("b")
    fut = cb.irecv("a", "later")
    assert not fut.done()
    ca.send("b", "later", {"x": np.array([1.0])})
    deadline = time.monotonic() + 5
    while not fut.done() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert fut.done()
    assert fut.result(1.0).tensor("x")[0] == 1.0
    # result is idempotent
    assert fut.result(1.0).tensor("x")[0] == 1.0


def test_send_error_surfaces_on_next_op():
    class Boom(Exception):
        pass

    bus = ThreadBus(["a", "b"])
    ca = bus.communicator("a")

    def bad_send(msg, raw):
        raise Boom("wire down")
    ca._send = bad_send
    fut = ca.isend("b", "t", {"x": np.zeros(1)})
    with pytest.raises(Boom):
        fut.result(5.0)
    with pytest.raises(Boom):        # sticky: the engine never rearms
        ca.isend("b", "t2", {"x": np.zeros(1)})
    with pytest.raises(Boom):
        ca.send("b", "t3", {"x": np.zeros(1)})


# ---------------------------------------------------------------------------
# schema layer: frames, reordering, channel compression
# ---------------------------------------------------------------------------

schema.message("ae/a", {"v": Field("float64", 1)}, stepped=True)
schema.message("ae/b", {"w": Field("int64", 1)}, stepped=True)
schema.message("ae/comp", {"u": Field("float32", 2)}, stepped=True,
               compress=True)


def _pair(compress=False):
    bus = ThreadBus(["m", "p"])
    return (TypedChannel(bus.communicator("m"), compress=compress),
            TypedChannel(bus.communicator("p"), compress=compress))


def test_frame_coalesces_one_wire_message():
    a, b = _pair()
    with a.frame("p"):
        a.send("p", "ae/a", {"v": np.array([1.0])})
        a.send("p", "ae/b", {"w": np.array([7], np.int64)})
    assert a.stats.sent_messages == 1          # ONE wire frame
    # receiver unpacks transparently, in any recv order
    assert b.recv("m", "ae/b").tensor("w")[0] == 7
    assert b.recv("m", "ae/a").tensor("v")[0] == 1.0


def test_frame_reorders_across_bare_messages():
    a, b = _pair()
    with a.frame("p"):
        a.send("p", "ae/a", {"v": np.array([0.0])})   # seq 0 in frame
        a.send("p", "ae/b", {"w": np.array([5], np.int64)})
    a.send("p", "ae/a", {"v": np.array([1.0])})       # seq 1 bare
    # sequence order is preserved per channel even though seq 0 rides a
    # frame and seq 1 rides bare
    assert b.recv("m", "ae/a").tensor("v")[0] == 0.0
    assert b.recv("m", "ae/a").tensor("v")[0] == 1.0
    assert b.recv("m", "ae/b").tensor("w")[0] == 5


def test_single_message_frame_stays_bare():
    a, b = _pair()
    with a.frame("p"):
        a.send("p", "ae/a", {"v": np.array([2.0])})
    msg = b.recv("m", "ae/a")
    assert msg.tag == "ae/a/0" and msg.tensor("v")[0] == 2.0


def test_channel_compression_roundtrip_and_exemption():
    a, b = _pair(compress=True)
    u = np.linspace(-2, 2, 64 * 32).reshape(64, 32).astype(np.float32)
    a.send("p", "ae/comp", {"u": u})
    got = b.recv("m", "ae/comp").tensor("u")
    assert got.dtype == np.float32
    assert np.abs(got - u).max() <= np.abs(u).max() / 127.0 * 0.5 + 1e-6
    # non-declared channels are exempt even on a compressing channel
    a.send("p", "ae/a", {"v": np.array([0.125])})
    assert b.recv("m", "ae/a").tensor("v")[0] == 0.125
    # compressing channel is ~4x smaller on the wire than a plain one
    ap, bp = _pair(compress=False)
    ap.send("p", "ae/comp", {"u": u})
    bp.recv("m", "ae/comp")
    assert a.stats.per_tag_bytes["ae/comp/0"] < \
        ap.stats.per_tag_bytes["ae/comp/0"] / 2.5


def test_compression_error_feedback_accumulates_on_channel():
    a, b = _pair(compress=True)
    rng = np.random.default_rng(0)
    total_true = np.zeros((4, 4), np.float32)
    total_got = np.zeros((4, 4), np.float32)
    for _ in range(40):
        u = rng.normal(size=(4, 4)).astype(np.float32)
        a.send("p", "ae/comp", {"u": u})
        total_true += u
        total_got += b.recv("m", "ae/comp").tensor("u")
    assert a.error_feedback is not None
    # error feedback keeps the accumulated signal unbiased
    assert np.abs(total_true - total_got).max() < 0.2


# ---------------------------------------------------------------------------
# driver: depth-1 trace equivalence in all three execution modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["thread", "socket", "process",
                                  "socket_proc", "grpc", "grpc_proc"])
def test_depth1_linreg_bit_identical_all_modes(mode):
    """pipeline_depth=1 must reproduce the recorded seed traces
    bit-identically — the async engine under the hood changes nothing
    about lock-step arithmetic."""
    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(cfg, pipeline_depth=1)
    res = run_vfl(cfg, master, members, mode=mode)
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)
    np.testing.assert_allclose(res["master"]["w_master"],
                               TRACES["linreg"]["w_master"],
                               rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


@pytest.mark.parametrize("mode", ["thread", "socket", "grpc"])
def test_depth1_splitnn_matches_trace(mode):
    cfg, master, members = _splitnn_case()
    cfg = dataclasses.replace(cfg, pipeline_depth=1)
    res = run_vfl(cfg, master, members, mode=mode)
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["split_nn"]["losses"], rtol=1e-6)


# ---------------------------------------------------------------------------
# driver: bounded staleness at depth >= 2
# ---------------------------------------------------------------------------


@register
class _StalenessProbe(LinRegProtocol):
    """Records, at each send stage, how many gradient applications the
    member is behind the step it is computing."""

    name = "staleness_probe"

    def setup(self):
        super().setup()
        self.applied = 0
        self.staleness = []

    def member_stage_send(self, rows, step):
        # a synchronous member would have applied `step` updates by now
        self.staleness.append(step - self.applied)
        return super().member_stage_send(rows, step)

    def member_stage_recv(self, rows, step, ctx):
        super().member_stage_recv(rows, step, ctx)
        self.applied += 1

    def finalize(self):
        out = super().finalize()
        if self.is_member:
            out["staleness"] = list(self.staleness)
        return out


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_staleness_bounded_by_depth_minus_one(depth):
    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(cfg, protocol="staleness_probe")
    res = run_vfl(cfg, master, members, pipeline_depth=depth)
    for j in range(2):
        st = res[f"member{j}"]["staleness"]
        assert len(st) == len(res["master"]["history"])
        assert max(st) <= depth - 1, (depth, st)
        if depth > 1:
            assert max(st) == depth - 1      # pipeline actually fills


@pytest.mark.parametrize("depth", [2, 4])
def test_bounded_staleness_convergence(depth):
    """The documented async-VFL scenario: training with gradients up to
    depth-1 steps stale still converges on both protocol families."""
    cfg, master, members = _linreg_case()
    sync = run_vfl(cfg, master, members)
    res = run_vfl(cfg, master, members, pipeline_depth=depth)
    h = [r["loss"] for r in res["master"]["history"]]
    h_sync = [r["loss"] for r in sync["master"]["history"]]
    assert len(h) == len(h_sync)
    assert h[-1] < 0.25 * h[0], h              # trains
    assert h[-1] < 2.0 * h_sync[-1]            # comparable to sync

    cfg2, m2, mem2 = _splitnn_case()
    res2 = run_vfl(cfg2, m2, mem2, pipeline_depth=depth)
    h2 = [r["loss"] for r in res2["master"]["history"]]
    sync2 = run_vfl(cfg2, m2, mem2)
    hs2 = [r["loss"] for r in sync2["master"]["history"]]
    assert h2[-1] < h2[0]
    assert abs(h2[-1] - hs2[-1]) < 0.1, (h2[-1], hs2[-1])


def test_logreg_he_pipelined_with_arbiter():
    """The arbitered HE protocol runs at depth 2: the master's
    encryption of round t+1 overlaps the members' homomorphic matvec
    and the arbiter's decryption of round t."""
    ids, x, y = _dataset(n=64, d=8, items=1)
    yb = (y > 0).astype(np.float64)
    master, members = vertical_partition(ids, x, yb, widths=[3], seed=4)
    cfg = VFLConfig(protocol="logreg_he", epochs=2, batch_size=32,
                    lr=0.5, seed=0, use_psi=False, he_bits=256)
    res = run_vfl(cfg, master, members, pipeline_depth=2)
    h = [r["loss"] for r in res["master"]["history"]]
    assert h[-1] < h[0]
    assert res["arbiter"]["decrypted_values"] > 0


# ---------------------------------------------------------------------------
# driver: stop semantics, eval-during-fit, predict at depth >= 2
# ---------------------------------------------------------------------------


def test_early_stop_overshoot_bounded_by_window():
    """A stop request only halts NEW announcements: every announced
    round still runs (so no follower hangs), which bounds the overshoot
    at depth-1 extra steps."""
    cfg, master, members = _linreg_case()
    res = run_vfl(cfg, master, members, callbacks=[StopAtStep(5)],
                  pipeline_depth=4)
    n_steps = len(res["master"]["history"])
    assert 5 <= n_steps <= 5 + 3, n_steps
    assert res["master"]["stopped"]


def test_early_stopping_callback_completes_at_depth():
    cfg, master, members = _linreg_case()
    t0 = time.monotonic()
    res = run_vfl(cfg, master, members,
                  callbacks=[EarlyStopping(patience=2, min_delta=10.0)],
                  pipeline_depth=4)
    assert time.monotonic() - t0 < 120
    assert "early-stop" in res["master"]["stopped"]
    assert 3 <= len(res["master"]["history"]) <= 6


def test_predict_after_pipelined_fit_drains_cleanly():
    """END drains every in-flight round, so a predict right after a
    pipelined fit sees fully-updated members and serving stays pure."""
    cfg, master, members = _splitnn_case()
    with VFLJob(cfg, master, members, pipeline_depth=3) as job:
        job.fit()
        s1 = job.predict()
        s2 = job.predict()
    np.testing.assert_allclose(s1, s2, rtol=0, atol=0)
    assert s1.shape[0] > 0


def test_eval_during_pipelined_fit_no_deadlock():
    from repro.core.protocols.driver import EvalEveryEpoch
    cfg, master, members = _splitnn_case()
    res = run_vfl(cfg, master, members, callbacks=[EvalEveryEpoch()],
                  pipeline_depth=3)
    assert len(res["master"]["eval_history"]) == cfg.epochs


def test_depth1_via_stage_hooks_equals_on_batch_member():
    """on_batch_member == stage_send + stage_recv by construction: the
    probe protocol (pipelined hooks) at depth 1 reproduces the linreg
    seed trace exactly."""
    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(cfg, protocol="staleness_probe")
    res = run_vfl(cfg, master, members, pipeline_depth=1)
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"], rtol=0, atol=0)
    for j in range(2):
        np.testing.assert_allclose(res[f"member{j}"]["w"],
                                   TRACES["linreg"]["w_members"][j],
                                   rtol=0, atol=0)


@pytest.mark.parametrize("mode", ["socket", "grpc"])
def test_pipelined_socket_mode_trains(mode):
    """TCP transports + depth 2 end-to-end (threads-in-one-process
    deployment): arithmetic unaffected by the transport or framing."""
    cfg, master, members = _splitnn_case()
    ref = run_vfl(cfg, master, members, mode="thread", pipeline_depth=2)
    got = run_vfl(cfg, master, members, mode=mode, pipeline_depth=2)
    np.testing.assert_allclose(
        [h["loss"] for h in got["master"]["history"]],
        [h["loss"] for h in ref["master"]["history"]], rtol=1e-6)


def test_sender_stops_writing_after_wire_error():
    """After one failed write the engine must never write again (a
    partial frame would corrupt the length-prefixed stream): queued
    sends fail fast with the original error."""
    class Boom(Exception):
        pass

    bus = ThreadBus(["a", "b"])
    ca = bus.communicator("a")
    writes = []
    orig = ca._send

    def fail_once(msg, raw):
        if not writes:
            writes.append(msg.tag)
            raise Boom("partial write")
        orig(msg, raw)
    ca._send = fail_once
    f1 = ca.isend("b", "t1", {"x": np.zeros(1)})
    f2 = ca.isend("b", "t2", {"x": np.zeros(1)})
    with pytest.raises(Boom):
        f1.result(5.0)
    with pytest.raises(Boom):
        f2.result(5.0)
    assert writes == ["t1"]            # t2 never hit the wire


def test_pending_and_reorder_buffers_do_not_leak():
    """Stepped tags are unique per step: drained bookkeeping entries
    must be deleted, or a long fit/serve leaks one per step."""
    a, b = _pair()
    for i in range(50):
        with a.frame("p"):
            a.send("p", "ae/a", {"v": np.array([float(i)])})
            a.send("p", "ae/b", {"w": np.array([i], np.int64)})
        b.recv("m", "ae/a")
        b.recv("m", "ae/b")
    assert sum(len(v) for v in b._reorder.values()) == 0
    assert len(b.comm._pending) == 0


def test_mid_fit_eval_with_sync_protocol_at_depth():
    """A non-pipeline protocol at pipeline_depth>=2 must not deadlock
    when a callback runs a mid-fit eval: the master's window collapses
    to 1 for protocols without stage hooks."""
    from repro.core.protocols.driver import EvalEveryEpoch

    @register
    class _SyncOnly(LinRegProtocol):
        name = "sync_only"
        supports_pipeline = False

        def on_batch_member(self, rows, step):
            ctx = self.member_stage_send(rows, step)
            self.member_stage_recv(rows, step, ctx)

    cfg, master, members = _linreg_case()
    cfg = dataclasses.replace(cfg, protocol="sync_only", epochs=2)
    t0 = time.monotonic()
    res = run_vfl(cfg, master, members, callbacks=[EvalEveryEpoch()],
                  pipeline_depth=4)
    assert time.monotonic() - t0 < 60
    assert len(res["master"]["eval_history"]) == 2
    # collapsed to lock-step: the first two epochs match the seed trace
    np.testing.assert_allclose(
        [h["loss"] for h in res["master"]["history"]],
        TRACES["linreg"]["losses"][:8], rtol=0, atol=0)


def test_unsupported_protocol_falls_back_synchronous():
    """A protocol without stage hooks keeps working at depth >= 2: its
    members simply execute each round in place (no run-ahead)."""

    @register
    class _LegacyMember(SplitNNProtocol):
        name = "legacy_member"
        supports_pipeline = False

        def on_batch_member(self, rows, step):
            xb = self.member_stage_send(rows, step)
            self.member_stage_recv(rows, step, xb)

    cfg, master, members = _splitnn_case()
    cfg = dataclasses.replace(cfg, protocol="legacy_member")
    res = run_vfl(cfg, master, members, pipeline_depth=4)
    h = [r["loss"] for r in res["master"]["history"]]
    np.testing.assert_allclose(h, TRACES["split_nn"]["losses"],
                               rtol=1e-6)
